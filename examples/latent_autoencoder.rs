//! End-to-end driver (paper §4.2): serve batched latent-sampling requests
//! against the discrete autoencoder's ARM prior, decode the sampled
//! latents to images, and report the paper's metrics.
//!
//! Pipeline per sample, all in rust on the PJRT CPU client:
//!   ε ~ Gumbel  →  FPI predictive sampling of z ~ P(z) (4×8×8 latents)
//!              →  decoder G(z) → 16×16 RGB image → results/*.ppm
//!
//!     cargo run --release --example latent_autoencoder [-- --model latent_cifar --n 32]

use predsamp::coordinator::config::Method;
use predsamp::coordinator::engine::Engine;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::cli::Args;
use predsamp::substrate::image::Image;
use predsamp::substrate::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get("model", "latent_cifar");
    let n = args.num::<usize>("n", 32);
    let manifest = Manifest::load(predsamp::artifacts_dir())?;
    let engine = Engine::load(&manifest, &model)?;
    let info = engine.info.clone();
    println!(
        "latent ARM {model}: {}x{}x{} latents, K={}, prior bpd {:.3}",
        info.channels, info.height, info.width, info.categories, info.bpd
    );

    // Sample latents with FPI vs baseline — same ε, identical z, far fewer calls.
    let batch = *engine.batch_sizes().last().unwrap();
    let mut all_imgs = Vec::new();
    let mut total_calls = 0usize;
    let mut total_base = 0usize;
    let mut wall = 0.0;
    let mut done = 0usize;
    let mut batch_idx = 0u64;
    while done < n {
        let take = (n - done).min(batch);
        let fpi = engine.sample_batch(Method::Fpi, batch, batch_idx)?;
        total_calls += fpi.arm_calls;
        total_base += info.dim;
        wall += fpi.wall_secs;
        let zs: Vec<Vec<i32>> = fpi.jobs[..take].iter().map(|j| j.x.clone()).collect();
        let imgs = engine.decode(&zs)?;
        all_imgs.extend(imgs);
        done += take;
        batch_idx += 1;
    }
    println!(
        "sampled {n} latents in {} ARM calls ({:.1}% of baseline {}), decode+sample wall {}",
        total_calls,
        100.0 * total_calls as f64 / total_base as f64,
        total_base,
        fmt_duration(wall)
    );

    // Write the decoded gallery.
    let s = engine.img_size().unwrap();
    let tiles: Vec<Image> = all_imgs
        .iter()
        .map(|im| {
            let rgb01: Vec<f32> = im.iter().map(|v| (v + 1.0) / 2.0).collect();
            Image::from_rgb_chw(s, s, &rgb01).upscale(3)
        })
        .collect();
    let out = format!("results/{model}_vae_samples.ppm");
    Image::grid(&tiles, 8).write_ppm(&out)?;
    println!("wrote {out}");
    Ok(())
}
