//! Serving demo: spin up the TCP server, fire concurrent client requests
//! over a mixed (model, method) stream, and report end-to-end latency and
//! throughput — comparing the paper's synchronous batching, this repo's
//! elastic continuous-batching scheduler (the "scheduling system" §4.1
//! leaves to future work; executing groups absorb their own mid-flight
//! arrivals under the configured sizing/admission policies), and the
//! sharded work-stealing engine-worker pool on top of it.
//!
//! With compiled artifacts present the demo serves them; without, it
//! falls back to the pure-rust mock ARM so it runs anywhere:
//!
//!     cargo run --release --example serving_demo [-- --model latent_cifar --clients 8 --requests 4 --engine-threads 4]

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::cli::Args;
use predsamp::substrate::stats::{percentile, Summary};
use predsamp::substrate::timer::{fmt_duration, Timer};
use std::time::Duration;

fn run_load(addr: std::net::SocketAddr, models: &[String], clients: usize, requests: usize) -> anyhow::Result<(Vec<f64>, f64, usize)> {
    let timer = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let model = models[c % models.len()].clone();
        let method = if c % 2 == 0 { "fpi" } else { "zeros" };
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut client = Client::connect(&addr)?;
            let mut lats = Vec::new();
            for r in 0..requests {
                let t = Timer::start();
                let resp = client.call(&format!(
                    r#"{{"op":"sample","model":"{model}","method":"{method}","n":2,"seed":{},"return_samples":false}}"#,
                    c * 1000 + r
                ))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "request failed: {resp}");
                lats.push(t.secs());
            }
            Ok(lats)
        }));
    }
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().expect("client thread")?);
    }
    let wall = timer.secs();
    let n_samples = clients * requests * 2;
    Ok((lats, wall, n_samples))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let clients = args.num::<usize>("clients", 8);
    let requests = args.num::<usize>("requests", 4);
    let max_workers = args.num::<usize>("engine-threads", 4);

    // Artifacts if built, otherwise a mock fixture (same serving stack).
    let artifacts = predsamp::artifacts_dir();
    let (dir, models) = if artifacts.join("manifest.json").exists() {
        (artifacts, vec![args.get("model", "latent_cifar")])
    } else {
        println!("no compiled artifacts found — serving the pure-rust mock ARM instead\n");
        let dir = std::env::temp_dir().join(format!("predsamp-demo-{}", std::process::id()));
        let specs = MockModelSpec::demo_pair();
        let names = specs.iter().map(|s| s.name.clone()).collect();
        write_mock_manifest(&dir, &specs)?;
        (dir, names)
    };

    // (label, continuous batching?, engine workers)
    let scenarios = [("sync / 1 worker", false, 1), ("continuous / 1 worker", true, 1), ("continuous sharded", true, max_workers)];
    for (label, continuous, engine_threads) in scenarios {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            continuous,
            elastic: continuous,
            steal: true,
            // All client connections share the single event-loop edge
            // thread; no per-connection thread sizing is needed.
            engine_threads,
            ..ServeConfig::default()
        };
        let server = spawn(dir.clone(), cfg)?;
        // Warm the engines (lazy per-worker load) outside the measurement.
        {
            let mut c = Client::connect(&server.addr)?;
            for model in &models {
                let warm = c.call(&format!(r#"{{"op":"sample","model":"{model}","n":1,"return_samples":false}}"#))?;
                anyhow::ensure!(warm.get("ok").as_bool() == Some(true), "warmup failed: {warm}");
            }
        }

        let (lats, wall, n) = run_load(server.addr, &models, clients, requests)?;
        let s = Summary::of(&lats);
        println!(
            "{label:<22} ({engine_threads} engine workers): {n} samples / {clients} clients  wall {}  throughput {:.1} samples/s",
            fmt_duration(wall),
            n as f64 / wall
        );
        println!(
            "             request latency mean {} p50 {} p95 {}",
            fmt_duration(s.mean),
            fmt_duration(percentile(&lats, 50.0)),
            fmt_duration(percentile(&lats, 95.0))
        );
        let mut c = Client::connect(&server.addr)?;
        let m = c.call(r#"{"op":"metrics"}"#)?;
        let metrics = m.get("metrics");
        print!("             per-worker (batches, occupancy):");
        if let Some(workers) = metrics.get("workers").as_arr() {
            for w in workers {
                print!(
                    "  w{}: {} @ {:.0}%",
                    w.get("id").as_i64().unwrap_or(-1),
                    w.get("batches").as_i64().unwrap_or(0),
                    100.0 * w.get("occupancy").as_f64().unwrap_or(0.0)
                );
            }
        }
        println!();
        server.stop();
    }
    Ok(())
}
