//! Serving demo: spin up the TCP server, fire concurrent client requests,
//! and report end-to-end latency/throughput — comparing the paper's
//! synchronous batching against this repo's continuous-batching scheduler
//! extension (the "scheduling system" the paper leaves to future work).
//!
//!     cargo run --release --example serving_demo [-- --model latent_cifar --clients 8 --requests 4]

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client};
use predsamp::substrate::cli::Args;
use predsamp::substrate::stats::{percentile, Summary};
use predsamp::substrate::timer::{fmt_duration, Timer};
use std::time::Duration;

fn run_load(addr: std::net::SocketAddr, model: &str, clients: usize, requests: usize) -> anyhow::Result<(Vec<f64>, f64, usize)> {
    let timer = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let model = model.to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut client = Client::connect(&addr)?;
            let mut lats = Vec::new();
            for r in 0..requests {
                let t = Timer::start();
                let resp = client.call(&format!(
                    r#"{{"op":"sample","model":"{model}","method":"fpi","n":2,"seed":{},"return_samples":false}}"#,
                    c * 1000 + r
                ))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "request failed: {resp}");
                lats.push(t.secs());
            }
            Ok(lats)
        }));
    }
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().expect("client thread")?);
    }
    let wall = timer.secs();
    let n_samples = clients * requests * 2;
    Ok((lats, wall, n_samples))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get("model", "latent_cifar");
    let clients = args.num::<usize>("clients", 8);
    let requests = args.num::<usize>("requests", 4);

    for continuous in [true, false] {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 32,
            max_wait: Duration::from_millis(25),
            continuous,
            worker_threads: clients.min(8),
        };
        let server = spawn(predsamp::artifacts_dir(), cfg)?;
        // Warm the engine (first request compiles executables).
        let mut c = Client::connect(&server.addr)?;
        let warm = c.call(&format!(r#"{{"op":"sample","model":"{model}","n":1,"return_samples":false}}"#))?;
        anyhow::ensure!(warm.get("ok").as_bool() == Some(true), "warmup failed: {warm}");

        let (lats, wall, n) = run_load(server.addr, &model, clients, requests)?;
        let s = Summary::of(&lats);
        println!(
            "{:<11} batching: {n} samples / {clients} clients  wall {}  throughput {:.1} samples/s",
            if continuous { "continuous" } else { "sync" },
            fmt_duration(wall),
            n as f64 / wall
        );
        println!(
            "             request latency mean {} p50 {} p95 {}",
            fmt_duration(s.mean),
            fmt_duration(percentile(&lats, 50.0)),
            fmt_duration(percentile(&lats, 95.0))
        );
        let m = c.call(r#"{"op":"metrics"}"#)?;
        println!("             server metrics: {}", m.get("metrics"));
        server.stop();
    }
    Ok(())
}
