//! Quickstart: load a model artifact, sample with every method, and see
//! the paper's headline effect — predictive sampling cuts ARM calls by an
//! order of magnitude while producing *bitwise identical* samples.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have been run once.

use predsamp::coordinator::config::Method;
use predsamp::coordinator::engine::Engine;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(predsamp::artifacts_dir())?;
    let model = "mnist_bin";
    let engine = Engine::load(&manifest, model)?;
    let d = engine.info.dim;
    println!("model {model}: d={d}, K={}, test bpd {:.3}\n", engine.info.categories, engine.info.bpd);

    let seed = 0;
    let baseline = engine.sample_batch(Method::Baseline, 1, seed)?;
    println!(
        "{:<16} {:>5} ARM calls ({:>5.1}%)  {:>9}",
        "baseline",
        baseline.arm_calls,
        100.0,
        fmt_duration(baseline.wall_secs)
    );

    for method in [
        Method::Zeros,
        Method::PredictLast,
        Method::Fpi,
        Method::Forecast { t_use: 20 },
    ] {
        let res = engine.sample_batch(method, 1, seed)?;
        let same = res.jobs[0].x == baseline.jobs[0].x;
        println!(
            "{:<16} {:>5} ARM calls ({:>5.1}%)  {:>9}  speedup {:>4.1}x  sample {}",
            method.label(),
            res.arm_calls,
            res.calls_pct(d),
            fmt_duration(res.wall_secs),
            baseline.wall_secs / res.wall_secs,
            if same { "identical ✓" } else { "DIFFERENT ✗" }
        );
        assert!(same, "predictive sampling must reproduce the ancestral sample exactly");
    }

    println!("\nThe sample (16x16 binary digits, '@' = 1):");
    let job = &baseline.jobs[0];
    let im = predsamp::sampler::trace::render_gray(job, 16, 16, 2);
    print!("{}", im.to_ascii());
    Ok(())
}
