"""Python mirror of the predictive-sampling algorithms (paper Alg. 1 & 2).

The production implementation lives in rust (rust/src/sampler); these tests
validate the *algorithmic* claims directly against the JAX model so the two
implementations can be cross-checked through the same HLO artifacts:

  1. exactness — FPI returns bitwise the ancestral sample for the same ε;
  2. convergence — at most d iterations;
  3. the ARM-call reduction is real (fewer iterations than d).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.gumbel import sample_gumbel


def _logp_fn(params, cfg):
    import jax

    f = jax.jit(lambda x: model.step(params, x, cfg)[0])
    return lambda x: np.asarray(f(jnp.asarray(x.astype(np.int32))))


def ancestral_sample(logp_fn, eps, d):
    """Naive d-call ancestral sampling with reparametrization noise eps [d,K]."""
    x = np.zeros((1, d), dtype=np.int32)
    for i in range(d):
        lp = logp_fn(x)  # [1, d, K]
        x[0, i] = int(np.argmax(lp[0, i] + eps[i]))
    return x[0], d


def fpi_sample(logp_fn, eps, d, max_iters=None):
    """Algorithm 2: x^{n+1} = g(x^n, eps) until fixed point."""
    x = np.zeros((1, d), dtype=np.int32)
    calls = 0
    for _ in range(max_iters or d + 1):
        lp = logp_fn(x)
        calls += 1
        x_new = np.argmax(lp[0] + eps, axis=-1).astype(np.int32)[None, :]
        if np.array_equal(x_new, x):
            break
        x = x_new
    return x[0], calls


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fpi_exactness_and_convergence(tiny_cfg, tiny_params, seed):
    """Same ε ⇒ FPI sample == ancestral sample, in ≤ d calls."""
    rng = np.random.default_rng(seed)
    d, k = tiny_cfg.dim, tiny_cfg.categories
    eps = sample_gumbel(rng, (d, k))
    logp_fn = _logp_fn(tiny_params, tiny_cfg)
    x_anc, _ = ancestral_sample(logp_fn, eps, d)
    x_fpi, calls = fpi_sample(logp_fn, eps, d)
    np.testing.assert_array_equal(x_fpi, x_anc)
    assert calls <= d + 1


def test_fpi_reduces_calls_on_trained_model(tiny_cfg_1ch, rng):
    """On structured data a trained model converges in far fewer than d calls."""
    from compile import train

    data = rng.integers(0, 2, size=(64, 1, 5, 5)).astype(np.int32)
    data[:, :, :3, :] = 0
    params, _ = train.train_arm(tiny_cfg_1ch, data, steps=60, batch_size=16, seed=0)
    logp_fn = _logp_fn(params, tiny_cfg_1ch)
    d, k = tiny_cfg_1ch.dim, tiny_cfg_1ch.categories
    total = 0
    for s in range(4):
        eps = sample_gumbel(np.random.default_rng(100 + s), (d, k))
        _, calls = fpi_sample(logp_fn, eps, d)
        total += calls
    assert total / 4 < 0.8 * d, f"expected <80% of {d} calls, got {total/4}"


def test_fpi_prefix_monotone(tiny_cfg, tiny_params):
    """The agreed prefix between iterates is non-decreasing across FPI steps
    (validity propagates forward, never backward)."""
    rng = np.random.default_rng(7)
    d, k = tiny_cfg.dim, tiny_cfg.categories
    eps = sample_gumbel(rng, (d, k))
    logp_fn = _logp_fn(tiny_params, tiny_cfg)

    x = np.zeros((1, d), dtype=np.int32)
    prev_valid = 0
    for _ in range(d + 1):
        lp = logp_fn(x)
        x_new = np.argmax(lp[0] + eps, axis=-1).astype(np.int32)[None, :]
        agree = np.flatnonzero(x_new[0] != x[0])
        valid = d if agree.size == 0 else int(agree[0])
        assert valid >= prev_valid
        prev_valid = valid
        if np.array_equal(x_new, x):
            break
        x = x_new


def test_forecast_zeros_baseline_structure(tiny_cfg, tiny_params):
    """Algorithm 1 with the 'forecast zeros' baseline is still exact."""
    rng = np.random.default_rng(11)
    d, k = tiny_cfg.dim, tiny_cfg.categories
    eps = sample_gumbel(rng, (d, k))
    logp_fn = _logp_fn(tiny_params, tiny_cfg)
    x_anc, _ = ancestral_sample(logp_fn, eps, d)

    # Algorithm 1 with F(x) = zeros.
    x = np.zeros((1, d), dtype=np.int32)
    i, calls = 0, 0
    while i < d:
        x[0, i:] = 0  # forecast
        lp = logp_fn(x)
        calls += 1
        out = np.argmax(lp[0] + eps, axis=-1)
        while i < d and (x[0, i] == out[i]):
            i += 1
        if i < d:
            x[0, i] = out[i]
            i += 1
    np.testing.assert_array_equal(x[0], x_anc)
    assert calls <= d
