"""Synthetic dataset generators: determinism, ranges, shapes, diversity."""

import numpy as np
import pytest

from compile import datasets


@pytest.mark.parametrize(
    "name,kw,c,k",
    [
        ("binary_digits", {"size": 16}, 1, 2),
        ("svhn", {"size": 10, "bits": 8}, 3, 256),
        ("cifar", {"size": 10, "bits": 5}, 3, 32),
        ("cifar", {"size": 10, "bits": 8}, 3, 256),
        ("imagenet", {"size": 16, "bits": 8}, 3, 256),
    ],
)
def test_shapes_and_ranges(name, kw, c, k):
    x = datasets.dataset_by_name(name, 8, seed=0, **kw)
    s = kw["size"]
    assert x.shape == (8, c, s, s)
    assert x.min() >= 0 and x.max() < k
    # some signal, not constant
    assert x.std() > 0


def test_deterministic():
    a = datasets.cifar_synth(4, size=8, bits=8, seed=5)
    b = datasets.cifar_synth(4, size=8, bits=8, seed=5)
    np.testing.assert_array_equal(a, b)


def test_seed_changes_data():
    a = datasets.cifar_synth(4, size=8, bits=8, seed=5)
    b = datasets.cifar_synth(4, size=8, bits=8, seed=6)
    assert not np.array_equal(a, b)


def test_images_differ_within_batch():
    x = datasets.svhn_synth(6, size=10, bits=8, seed=0)
    flat = x.reshape(6, -1)
    for i in range(5):
        assert not np.array_equal(flat[i], flat[i + 1])


def test_binary_digits_are_binary_and_sparse():
    x = datasets.binary_digits(16, size=16, seed=0)
    assert set(np.unique(x)) <= {0, 1}
    frac_on = x.mean()
    assert 0.02 < frac_on < 0.6  # stroke images: mostly background


def test_smoothness_vs_bits():
    """Lower bit-depth data has fewer distinct values (the K axis the paper
    links to predictive-sampling difficulty)."""
    x5 = datasets.cifar_synth(4, size=10, bits=5, seed=1)
    x8 = datasets.cifar_synth(4, size=10, bits=8, seed=1)
    assert len(np.unique(x5)) <= 32
    assert len(np.unique(x8)) > 32


def test_unknown_name_raises():
    with pytest.raises(KeyError):
        datasets.dataset_by_name("nope", 1)
