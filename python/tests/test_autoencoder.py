"""Discrete autoencoder: shapes, straight-through quantization, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import autoencoder as ae
from compile import datasets, train


@pytest.fixture(scope="module")
def acfg():
    return ae.AeConfig("t", img_size=8, width=16, latent_channels=2, latent_hw=4, categories=8)


@pytest.fixture(scope="module")
def aparams(acfg):
    return ae.init_params(acfg, seed=0)


def test_shapes(acfg, aparams, rng):
    img = rng.normal(size=(2, 3, 8, 8)).astype(np.float32)
    logits = ae.encode_logits(aparams, jnp.asarray(img), acfg)
    assert logits.shape == (2, 2, 4, 4, 8)
    recon, _ = ae.autoencode(aparams, jnp.asarray(img), acfg)
    assert recon.shape == (2, 3, 8, 8)


def test_encode_decode_flat_layout(acfg, aparams, rng):
    img = rng.normal(size=(2, 3, 8, 8)).astype(np.float32)
    z = ae.encode_flat(aparams, jnp.asarray(img), acfg)
    assert z.shape == (2, acfg.latent_dim)
    assert z.dtype == jnp.int32
    assert int(jnp.min(z)) >= 0 and int(jnp.max(z)) < acfg.categories
    out = ae.decode_flat(aparams, z, acfg)
    assert out.shape == (2, 3, 8, 8)


def test_quantize_is_onehot_and_st_gradient(acfg):
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 2, 4, 4, 8)).astype(np.float32))
    q = ae.quantize_st(logits)
    np.testing.assert_allclose(np.asarray(q).sum(-1), 1.0, rtol=1e-5)
    hard = np.asarray(q).round()
    np.testing.assert_allclose(np.asarray(q), hard, atol=1e-5)

    # Straight-through: gradient flows to the logits.
    def f(lo):
        return jnp.sum(ae.quantize_st(lo) ** 2 * jnp.arange(8.0))

    g = jax.grad(f)(logits)
    assert float(jnp.abs(g).max()) > 0


def test_ae_training_reduces_mse(acfg, rng):
    imgs = datasets.cifar_synth(48, size=8, bits=8, seed=3)
    params, losses = train.train_autoencoder(acfg, imgs, steps=40, batch_size=8, seed=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_normalize_img_range():
    x = np.array([[[[0, 255]]]], dtype=np.uint8)
    n = ae.normalize_img(x)
    assert n.min() == -1.0 and n.max() == 1.0


def test_encode_deterministic(acfg, aparams, rng):
    img = jnp.asarray(rng.normal(size=(1, 3, 8, 8)).astype(np.float32))
    z1 = ae.encode_flat(aparams, img, acfg)
    z2 = ae.encode_flat(aparams, img, acfg)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
