"""Shared fixtures: tiny model configs that train/evaluate in milliseconds."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model  # noqa: E402


@pytest.fixture(scope="session")
def tiny_cfg():
    """3-channel micro ARM: 4x4 pixels, K=5, d=48."""
    return model.ArmConfig("tiny", channels=3, height=4, width=4, categories=5,
                           filters=8, n_resnets=2, t_fore=4, fore_filters=8, embed_dim=3)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return model.init_params(tiny_cfg, seed=0)


@pytest.fixture(scope="session")
def tiny_cfg_1ch():
    """1-channel micro ARM: 5x5 binary, d=25."""
    return model.ArmConfig("tiny1", channels=1, height=5, width=5, categories=2,
                           filters=8, n_resnets=1, t_fore=6, fore_filters=8, embed_dim=2)


@pytest.fixture(scope="session")
def tiny_params_1ch(tiny_cfg_1ch):
    return model.init_params(tiny_cfg_1ch, seed=1)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
