"""Gumbel-max reparametrization + posterior noise (paper §2.2, Appendix B)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.gumbel import gumbel_argmax, posterior_gumbel, sample_gumbel

EULER = 0.5772156649015329


def test_gumbel_marginal_moments(rng):
    g = sample_gumbel(rng, (200_000,))
    assert abs(g.mean() - EULER) < 0.02
    assert abs(g.var() - np.pi**2 / 6) < 0.05


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 50), seed=st.integers(0, 2**31 - 1))
def test_posterior_argmax_consistency(k, seed):
    """argmax(mu + eps) == x exactly for posterior eps — the property that
    makes forecast-module training on data samples valid."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(30, k))
    logp = logits - np.log(np.exp(logits).sum(-1, keepdims=True))
    x = rng.integers(0, k, size=(30,))
    eps = posterior_gumbel(rng, logp, x)
    np.testing.assert_array_equal(gumbel_argmax(logp, eps), x)


def test_posterior_marginal_is_standard_gumbel(rng):
    """When x ~ Cat(softmax(mu)), eps ~ p(eps|x) must be marginally G(0,1)."""
    k = 5
    n = 60_000
    logits = rng.normal(size=(k,))
    logp = logits - np.log(np.exp(logits).sum())
    # Sample x from the model, then posterior noise.
    eps_prior = sample_gumbel(rng, (n, k))
    x = np.argmax(logp[None, :] + eps_prior, axis=-1)
    eps_post = posterior_gumbel(rng, np.broadcast_to(logp, (n, k)), x)
    for c in range(k):
        col = eps_post[:, c]
        assert abs(col.mean() - EULER) < 0.03, f"col {c} mean {col.mean()}"
        assert abs(col.var() - np.pi**2 / 6) < 0.1, f"col {c} var {col.var()}"


def test_gumbel_argmax_matches_categorical_frequencies(rng):
    """Gumbel-max sampling reproduces the categorical distribution."""
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    logp = np.log(probs)
    n = 100_000
    eps = sample_gumbel(rng, (n, 4))
    x = gumbel_argmax(np.broadcast_to(logp, (n, 4)), eps)
    freq = np.bincount(x, minlength=4) / n
    np.testing.assert_allclose(freq, probs, atol=0.01)


def test_posterior_deterministic_under_seed():
    rng1 = np.random.default_rng(42)
    rng2 = np.random.default_rng(42)
    logp = np.log(np.full((10, 3), 1 / 3))
    x = np.arange(10) % 3
    np.testing.assert_array_equal(posterior_gumbel(rng1, logp, x), posterior_gumbel(rng2, logp, x))
