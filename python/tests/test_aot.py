"""AOT export: HLO text is produced, parseable-looking, and shape-correct."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_structure(tiny_cfg_1ch, tiny_params_1ch):
    spec = jax.ShapeDtypeStruct((1, tiny_cfg_1ch.dim), jnp.int32)
    lowered = jax.jit(lambda x: model.step(tiny_params_1ch, x, tiny_cfg_1ch)).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tuple-return of two f32 arrays with the right shapes
    assert f"f32[1,{tiny_cfg_1ch.dim},2]" in text
    assert f"f32[1,{tiny_cfg_1ch.pixels},{tiny_cfg_1ch.t_fore},2]" in text
    assert f"s32[1,{tiny_cfg_1ch.dim}]" in text


def test_export_fn_writes_file(tmp_path, tiny_cfg_1ch, tiny_params_1ch):
    spec = jax.ShapeDtypeStruct((1, tiny_cfg_1ch.dim), jnp.int32)
    path = str(tmp_path / "t.hlo.txt")
    n = aot.export_fn(lambda x: model.step(tiny_params_1ch, x, tiny_cfg_1ch), (spec,), path)
    assert n > 100
    assert os.path.getsize(path) == n


def test_save_test_batch_roundtrip(tmp_path):
    x = np.arange(12, dtype=np.int32).reshape(3, 4)
    p = str(tmp_path / "x.bin")
    aot.save_test_batch(x, p)
    back = np.fromfile(p, dtype="<i4").reshape(3, 4)
    np.testing.assert_array_equal(back, x)


def test_configs_consistent():
    """Every ARM config's derived quantities line up; manifest keys stable."""
    for name, cfg in aot.ARM_CONFIGS.items():
        assert cfg.name == name
        assert cfg.dim == cfg.channels * cfg.height * cfg.width
        m = cfg.to_manifest()
        for key in ("dim", "pixels", "categories", "t_fore", "share_repr"):
            assert key in m
    for name in aot.LATENT_OF.values():
        assert name in aot.AE_CONFIGS


@pytest.mark.skipif(not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
                    reason="full artifacts not built")
def test_built_manifest_is_complete():
    with open(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for key, fn in entry["files"].items():
            assert os.path.exists(os.path.join(adir, fn)), f"{name}/{key} missing: {fn}"
        assert entry["dim"] == entry["channels"] * entry["height"] * entry["width"]
