"""L1 correctness: Pallas kernels vs pure-jnp oracles, hypothesis-swept.

These are the core kernel-correctness signal: every kernel must match its
ref.py oracle to float32 tolerance across shapes, kernel sizes, and mask
variants.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gated import gated_pallas
from compile.kernels.head import log_softmax_pallas
from compile.kernels.masked_conv import masked_conv2d_pallas
from compile.kernels.ref import gated_ref, log_softmax_ref, masked_conv2d_ref, spatial_causal_mask

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# masked_conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    cin=st.integers(1, 9),
    cout=st.integers(1, 9),
    h=st.integers(3, 10),
    w=st.integers(3, 10),
    ksz=st.sampled_from([1, 3, 5]),
    center=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_conv_matches_ref(b, cin, cout, h, w, ksz, center, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, cin, h, w)
    wgt = _rand(rng, cout, cin, ksz, ksz)
    bias = _rand(rng, cout)
    mask = jnp.asarray(spatial_causal_mask(ksz, ksz, include_center=center))
    ref = masked_conv2d_ref(x, wgt, bias, mask)
    pal = masked_conv2d_pallas(x, wgt, bias, mask)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_masked_conv_is_causal():
    """Perturbing a pixel never changes outputs at raster-earlier pixels."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 1, 2, 6, 6)
    w = _rand(rng, 3, 2, 3, 3)
    b = _rand(rng, 3)
    mask = jnp.asarray(spatial_causal_mask(3, 3, include_center=False))
    base = np.asarray(masked_conv2d_pallas(x, w, b, mask))
    x2 = x.copy()
    x2[0, :, 3, 2] += 5.0  # perturb pixel (3,2), raster index 20
    out = np.asarray(masked_conv2d_pallas(x2, w, b, mask))
    flat_base = base.reshape(3, -1)
    flat_out = out.reshape(3, -1)
    # All outputs at raster positions <= 20 unchanged (mask A: center excluded).
    np.testing.assert_array_equal(flat_out[:, : 3 * 6 + 2 + 1], flat_base[:, : 3 * 6 + 2 + 1])
    # And something after it did change (sanity that the perturbation matters).
    assert np.abs(flat_out[:, 3 * 6 + 3 :] - flat_base[:, 3 * 6 + 3 :]).max() > 0


@pytest.mark.parametrize("center", [True, False])
def test_spatial_mask_shape_and_counts(center):
    m = spatial_causal_mask(5, 5, include_center=center)
    assert m.shape == (5, 5)
    # strictly above rows fully on, center row half on, below rows off
    assert m[:2].sum() == 10
    assert m[2].sum() == 2 + (1 if center else 0)
    assert m[3:].sum() == 0


# ---------------------------------------------------------------------------
# gated
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    shape=st.lists(st.integers(1, 7), min_size=1, max_size=4),
    seed=st.integers(0, 2**31 - 1),
)
def test_gated_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, *shape)
    g = _rand(rng, *shape)
    np.testing.assert_allclose(
        np.asarray(gated_pallas(a, g)), np.asarray(gated_ref(a, g)), rtol=1e-6, atol=1e-6
    )


def test_gated_range():
    rng = np.random.default_rng(1)
    a = _rand(rng, 100) * 10
    g = _rand(rng, 100) * 10
    out = np.asarray(gated_pallas(a, g))
    assert np.all(out <= 1.0) and np.all(out >= -1.0)


# ---------------------------------------------------------------------------
# log_softmax
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 80),
    k=st.integers(2, 300),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_log_softmax_matches_ref(rows, k, scale, seed):
    rng = np.random.default_rng(seed)
    x = (_rand(rng, rows, k) * scale).astype(np.float32)
    ref = log_softmax_ref(x)
    pal = log_softmax_pallas(x)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_log_softmax_normalized():
    rng = np.random.default_rng(2)
    x = _rand(rng, 7, 33) * 5
    lp = np.asarray(log_softmax_pallas(x))
    np.testing.assert_allclose(np.exp(lp).sum(axis=-1), 1.0, rtol=1e-5)


def test_log_softmax_high_rank():
    rng = np.random.default_rng(3)
    x = _rand(rng, 2, 3, 4, 11)
    np.testing.assert_allclose(
        np.asarray(log_softmax_pallas(x)), np.asarray(log_softmax_ref(x)), rtol=1e-5, atol=1e-6
    )
