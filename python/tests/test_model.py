"""L2 correctness: autoregressive structure, shapes, layout, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, train


def _rand_x(rng, cfg, b=2):
    return rng.integers(0, cfg.categories, size=(b, cfg.channels, cfg.height, cfg.width)).astype(np.int32)


def test_forward_shapes(tiny_cfg, tiny_params, rng):
    x = _rand_x(rng, tiny_cfg)
    logp, fore = model.forward(tiny_params, jnp.asarray(x), tiny_cfg)
    assert logp.shape == (2, tiny_cfg.dim, tiny_cfg.categories)
    assert fore.shape == (2, tiny_cfg.pixels, tiny_cfg.t_fore, tiny_cfg.categories)
    np.testing.assert_allclose(np.exp(np.asarray(logp)).sum(-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.exp(np.asarray(fore)).sum(-1), 1.0, rtol=1e-5)


def test_flat_img_roundtrip(tiny_cfg, rng):
    x = _rand_x(rng, tiny_cfg, b=3)
    flat = model.img_to_flat(jnp.asarray(x))
    back = model.flat_to_img(flat, tiny_cfg)
    np.testing.assert_array_equal(np.asarray(back), x)
    # Layout contract: flat[(y*W + x)*C + c] == img[c, y, x].
    assert int(flat[0, (1 * tiny_cfg.width + 2) * tiny_cfg.channels + 1]) == int(x[0, 1, 1, 2])


@settings(max_examples=10, deadline=None)
@given(pos=st.integers(0, 47), seed=st.integers(0, 2**31 - 1))
def test_strict_autoregressive_property(tiny_cfg, tiny_params, pos, seed):
    """Changing flat variable j must not change logp at any i <= j.

    This is the paper's strict triangular dependence — the property that
    makes predictive sampling exact.
    """
    rng = np.random.default_rng(seed)
    x = rng.integers(0, tiny_cfg.categories, size=(1, tiny_cfg.dim)).astype(np.int32)
    x2 = x.copy()
    x2[0, pos] = (x2[0, pos] + 1 + rng.integers(0, tiny_cfg.categories - 1)) % tiny_cfg.categories
    lp1, _ = model.step(tiny_params, jnp.asarray(x), tiny_cfg)
    lp2, _ = model.step(tiny_params, jnp.asarray(x2), tiny_cfg)
    a, b = np.asarray(lp1)[0], np.asarray(lp2)[0]
    np.testing.assert_array_equal(a[: pos + 1], b[: pos + 1])


@settings(max_examples=6, deadline=None)
@given(pix=st.integers(0, 15), seed=st.integers(0, 2**31 - 1))
def test_forecast_head_causality(tiny_cfg, tiny_params, pix, seed):
    """fore[:, p, :, :] may only depend on pixels strictly before p."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, tiny_cfg.categories, size=(1, tiny_cfg.channels, tiny_cfg.height, tiny_cfg.width)).astype(np.int32)
    x2 = x.copy()
    y, xw = divmod(pix, tiny_cfg.width)
    x2[0, :, y, xw] = (x2[0, :, y, xw] + 1) % tiny_cfg.categories
    _, f1 = model.forward(tiny_params, jnp.asarray(x), tiny_cfg)
    _, f2 = model.forward(tiny_params, jnp.asarray(x2), tiny_cfg)
    a, b = np.asarray(f1)[0], np.asarray(f2)[0]
    np.testing.assert_array_equal(a[: pix + 1], b[: pix + 1])


def test_forecast_head_causality_noshare(rng):
    """Same property for the share_repr=False (Table 3) variant."""
    cfg = model.ArmConfig("tiny_ns", channels=3, height=4, width=4, categories=5,
                          filters=8, n_resnets=1, t_fore=3, fore_filters=8, embed_dim=3,
                          share_repr=False)
    params = model.init_params(cfg, seed=3)
    x = rng.integers(0, cfg.categories, size=(1, 3, 4, 4)).astype(np.int32)
    for pix in [0, 5, 10, 15]:
        x2 = x.copy()
        y, xw = divmod(pix, 4)
        x2[0, :, y, xw] = (x2[0, :, y, xw] + 2) % cfg.categories
        _, f1 = model.forward(params, jnp.asarray(x), cfg)
        _, f2 = model.forward(params, jnp.asarray(x2), cfg)
        np.testing.assert_array_equal(np.asarray(f1)[0, : pix + 1], np.asarray(f2)[0, : pix + 1])


def test_channel_conditioning_active(tiny_cfg, tiny_params, rng):
    """Changing channel 0 of a pixel must change logits of channel 2 at the
    same pixel (the head's within-pixel conditioning is real)."""
    x = _rand_x(rng, tiny_cfg, b=1)
    x2 = x.copy()
    x2[0, 0, 2, 2] = (x2[0, 0, 2, 2] + 1) % tiny_cfg.categories
    lp1, _ = model.forward(tiny_params, jnp.asarray(x), tiny_cfg)
    lp2, _ = model.forward(tiny_params, jnp.asarray(x2), tiny_cfg)
    j = (2 * tiny_cfg.width + 2) * tiny_cfg.channels + 2  # channel 2 of pixel (2,2)
    assert np.abs(np.asarray(lp1)[0, j] - np.asarray(lp2)[0, j]).max() > 0


def test_pallas_and_ref_paths_agree(tiny_cfg_1ch, tiny_params_1ch, rng):
    """The use_pallas=True lowering is numerically the same model."""
    x = rng.integers(0, 2, size=(1, tiny_cfg_1ch.dim)).astype(np.int32)
    lp_r, f_r = model.step(tiny_params_1ch, jnp.asarray(x), tiny_cfg_1ch, use_pallas=False)
    lp_p, f_p = model.step(tiny_params_1ch, jnp.asarray(x), tiny_cfg_1ch, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lp_p), np.asarray(lp_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_r), rtol=1e-4, atol=1e-5)


def test_loss_decreases(tiny_cfg_1ch, rng):
    data = rng.integers(0, 2, size=(64, 1, 5, 5)).astype(np.int32)
    data[:, :, :, :2] = 0  # learnable structure
    params, losses = train.train_arm(tiny_cfg_1ch, data, steps=40, batch_size=16, seed=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_bpd_upper_bound(tiny_cfg, tiny_params, rng):
    """Untrained bpd should be ~log2(K); never wildly above."""
    x = _rand_x(rng, tiny_cfg, b=4)
    bpd = float(model.nll_bpd(tiny_params, jnp.asarray(x), tiny_cfg))
    assert 0 < bpd < 2.5 * np.log2(tiny_cfg.categories)


def test_adam_step_moves_params(tiny_cfg_1ch, tiny_params_1ch):
    state = train.adam_init(tiny_params_1ch)
    grads = jax.tree_util.tree_map(jnp.ones_like, tiny_params_1ch)
    new, state2 = train.adam_update(tiny_params_1ch, grads, state, lr=1e-3)
    assert int(state2["t"]) == 1
    diffs = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()), tiny_params_1ch, new)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
