"""Deterministic synthetic dataset generators.

The paper evaluates on Binary MNIST, SVHN, CIFAR10 and ImageNet32. This box
is offline and CPU-only, so we substitute procedurally generated datasets
that preserve the two axes predictive sampling is sensitive to (paper §4.1):

  * the number of categories K (binary vs 5-bit vs 8-bit), and
  * local spatial predictability with occasional structure transitions
    (the locus of forecasting mistakes in Figs. 3-4).

All generators are deterministic in (seed, n) and return uint-valued
numpy arrays shaped [N, C, H, W] with values in [0, K).
See DESIGN.md §3 for the substitution rationale.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "binary_digits",
    "svhn_synth",
    "cifar_synth",
    "imagenet_synth",
    "dataset_by_name",
]


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(entropy=0xC0FFEE, spawn_key=(seed,)))


def _raster_line(img: np.ndarray, x0: float, y0: float, x1: float, y1: float, width: float) -> None:
    """Rasterize a thick anti-alias-free line segment into a 2D binary image."""
    h, w = img.shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    dx, dy = x1 - x0, y1 - y0
    norm2 = dx * dx + dy * dy + 1e-9
    t = np.clip(((xx - x0) * dx + (yy - y0) * dy) / norm2, 0.0, 1.0)
    px, py = x0 + t * dx, y0 + t * dy
    dist = np.sqrt((xx - px) ** 2 + (yy - py) ** 2)
    img[dist <= width] = 1


def _raster_arc(img: np.ndarray, cx: float, cy: float, r: float, a0: float, a1: float, width: float) -> None:
    h, w = img.shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    dist = np.abs(np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2) - r)
    ang = np.arctan2(yy - cy, xx - cx)
    lo, hi = min(a0, a1), max(a0, a1)
    mask = (dist <= width) & (ang >= lo) & (ang <= hi)
    img[mask] = 1


def binary_digits(n: int, size: int = 16, seed: int = 0) -> np.ndarray:
    """Binary-MNIST stand-in: procedural digit-like stroke images.

    Each image is 1-4 strokes (lines and arcs) on black background,
    binarized. Returns uint8 [n, 1, size, size] with values in {0, 1}.
    """
    rng = _rng(seed)
    out = np.zeros((n, 1, size, size), dtype=np.uint8)
    for i in range(n):
        img = np.zeros((size, size), dtype=np.uint8)
        n_strokes = int(rng.integers(1, 5))
        for _ in range(n_strokes):
            if rng.random() < 0.5 or size < 10:
                x0, y0, x1, y1 = rng.uniform(1, max(size - 2, 2), size=4)
                _raster_line(img, x0, y0, x1, y1, width=rng.uniform(0.7, 1.4))
            else:
                cx, cy = rng.uniform(4, size - 5, size=2)
                r = rng.uniform(2.0, size / 3)
                a0 = rng.uniform(-np.pi, np.pi)
                a1 = a0 + rng.uniform(np.pi / 2, 2 * np.pi)
                _raster_arc(img, cx, cy, r, a0, min(a1, np.pi), width=rng.uniform(0.7, 1.2))
        out[i, 0] = img
    return out


def _smooth_field(rng: np.random.Generator, c: int, h: int, w: int, n_waves: int = 4) -> np.ndarray:
    """Sum of low-frequency cosines -> smooth field in [0, 1], [c, h, w]."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    field = np.zeros((c, h, w))
    for ch in range(c):
        for _ in range(n_waves):
            fx, fy = rng.uniform(-1.5, 1.5, size=2) * np.pi / max(h, w)
            phase = rng.uniform(0, 2 * np.pi)
            amp = rng.uniform(0.2, 1.0)
            field[ch] += amp * np.cos(fx * xx + fy * yy + phase)
    mn, mx = field.min(), field.max()
    return (field - mn) / (mx - mn + 1e-9)


def svhn_synth(n: int, size: int = 12, bits: int = 8, seed: int = 0) -> np.ndarray:
    """SVHN stand-in: digit-like rectangles over smooth color gradients.

    Returns uint8 [n, 3, size, size] with values in [0, 2**bits).
    """
    rng = _rng(seed + 101)
    k = 1 << bits
    out = np.zeros((n, 3, size, size), dtype=np.int64)
    for i in range(n):
        bg = _smooth_field(rng, 3, size, size, n_waves=3)
        # 1-2 "digit" blocks: solid rectangles with contrasting color
        img = bg.copy()
        for _ in range(int(rng.integers(1, 3))):
            x0 = int(rng.integers(0, size - 3))
            y0 = int(rng.integers(0, size - 4))
            bw = int(rng.integers(2, max(3, size // 3)))
            bh = int(rng.integers(3, max(4, size // 2)))
            color = rng.uniform(0, 1, size=3)
            img[:, y0 : y0 + bh, x0 : x0 + bw] = color[:, None, None]
        img = img + rng.normal(0, 0.015, size=img.shape)
        out[i] = np.clip(np.round(img * (k - 1)), 0, k - 1)
    return out.astype(np.uint8 if bits <= 8 else np.int64)


def cifar_synth(n: int, size: int = 12, bits: int = 8, seed: int = 0) -> np.ndarray:
    """CIFAR10 stand-in: smooth textures plus one or two colored shapes.

    Returns uint8 [n, 3, size, size] with values in [0, 2**bits).
    """
    rng = _rng(seed + 202)
    k = 1 << bits
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    out = np.zeros((n, 3, size, size), dtype=np.int64)
    for i in range(n):
        img = _smooth_field(rng, 3, size, size, n_waves=5)
        for _ in range(int(rng.integers(1, 3))):
            cx, cy = rng.uniform(2, size - 2, size=2)
            r = rng.uniform(1.5, size / 3)
            mask = (xx - cx) ** 2 + (yy - cy) ** 2 <= r * r
            color = rng.uniform(0, 1, size=3)
            alpha = rng.uniform(0.6, 1.0)
            for ch in range(3):
                img[ch][mask] = alpha * color[ch] + (1 - alpha) * img[ch][mask]
        img = img + rng.normal(0, 0.01, size=img.shape)
        out[i] = np.clip(np.round(img * (k - 1)), 0, k - 1)
    return out.astype(np.uint8 if bits <= 8 else np.int64)


def imagenet_synth(n: int, size: int = 16, bits: int = 8, seed: int = 0) -> np.ndarray:
    """ImageNet32 stand-in: higher-variance mixture of texture families.

    Returns uint8 [n, 3, size, size].
    """
    rng = _rng(seed + 303)
    k = 1 << bits
    out = np.zeros((n, 3, size, size), dtype=np.int64)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    for i in range(n):
        family = int(rng.integers(0, 3))
        if family == 0:  # smooth
            img = _smooth_field(rng, 3, size, size, n_waves=4)
        elif family == 1:  # stripes
            fx, fy = rng.uniform(-2.5, 2.5, size=2) * np.pi / size
            base = 0.5 + 0.5 * np.sign(np.cos(fx * xx * 4 + fy * yy * 4 + rng.uniform(0, 6)))
            tint = rng.uniform(0.2, 1.0, size=3)
            img = base[None] * tint[:, None, None]
        else:  # blocks
            img = np.zeros((3, size, size))
            cells = int(rng.integers(2, 5))
            step = max(1, size // cells)
            for by in range(0, size, step):
                for bx in range(0, size, step):
                    img[:, by : by + step, bx : bx + step] = rng.uniform(0, 1, size=3)[:, None, None]
        img = np.clip(img + rng.normal(0, 0.02, size=img.shape), 0, 1)
        out[i] = np.clip(np.round(img * (k - 1)), 0, k - 1)
    return out.astype(np.uint8)


_REGISTRY = {
    "binary_digits": binary_digits,
    "svhn": svhn_synth,
    "cifar": cifar_synth,
    "imagenet": imagenet_synth,
}


def dataset_by_name(name: str, n: int, seed: int = 0, **kw) -> np.ndarray:
    """Look up a generator by registry name and produce n examples."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](n, seed=seed, **kw)
