"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels (interpret=True) match these
implementations to float tolerance. They are also the *fast path* used
during training and for the default (non-`_pallas`) HLO artifacts, since
interpret-mode Pallas is slow on the CPU backend; both paths lower to the
same mathematical function (verified by `python/tests/test_kernels.py` and
the rust `pallas_parity` integration test).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "spatial_causal_mask",
    "masked_conv2d_ref",
    "gated_ref",
    "log_softmax_ref",
]


def spatial_causal_mask(kh: int, kw: int, include_center: bool) -> np.ndarray:
    """Raster-order causal mask over a (kh, kw) kernel window.

    Taps strictly above the center row, or in the center row strictly left
    of center, are allowed. The center tap is allowed iff `include_center`
    (PixelCNN mask "B" spatially; mask "A" excludes it). Taps below/right
    are always disallowed.
    """
    m = np.zeros((kh, kw), dtype=np.float32)
    cy, cx = kh // 2, kw // 2
    m[:cy, :] = 1.0
    m[cy, :cx] = 1.0
    if include_center:
        m[cy, cx] = 1.0
    return m


def masked_conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Causally-masked SAME conv. x: [B,Cin,H,W], w: [Cout,Cin,kh,kw],
    b: [Cout], mask: [kh,kw]. Returns [B,Cout,H,W].

    The mask is folded into the weights (dense conv afterwards) — the same
    trick the Pallas kernel uses to keep the MXU inner loop dense.
    """
    wm = w * mask[None, None, :, :]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        wm.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def gated_ref(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Gated activation tanh(a) * sigmoid(g) (PixelCNN gate)."""
    return jnp.tanh(a) * jax.nn.sigmoid(g)


def log_softmax_ref(logits: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable log-softmax over the last axis."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    s = logits - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))
