"""Pallas kernel for the categorical head's log-softmax normalizer.

The ARM emits `d` independent K-way categorical distributions per image;
normalizing them is a bandwidth-bound rowwise reduction. The kernel tiles
rows of the [N, K] logit matrix through VMEM, computes the max-shifted
log-sum-exp in one pass over the VMEM-resident tile, and writes normalized
log-probs. K is zero-padded to the 128-lane boundary by the wrapper with
-inf so padding never wins the max or contributes to the sum.

interpret=True (CPU validation); oracle: `ref.log_softmax_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["log_softmax_pallas"]

_ROWS = 64  # rows per program


def _lse_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    o_ref[...] = s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


@jax.jit
def log_softmax_pallas(logits):
    """Log-softmax over the last axis of [..., K] via the Pallas kernel."""
    shape = logits.shape
    k = shape[-1]
    x = logits.reshape(-1, k).astype(jnp.float32)
    n = x.shape[0]
    kpad = (-k) % 128
    rpad = (-n) % _ROWS
    # -inf pad on K: never the max, exp() contributes exactly 0 to the sum.
    x = jnp.pad(x, ((0, rpad), (0, kpad)), constant_values=-jnp.inf)
    # Rows added by rpad are all -inf; replace with zeros to avoid nan rows
    # (their outputs are sliced away anyway).
    if rpad:
        x = x.at[n:, :].set(0.0)
    m, kk = x.shape
    out = pl.pallas_call(
        _lse_kernel,
        grid=(m // _ROWS,),
        in_specs=[pl.BlockSpec((_ROWS, kk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_ROWS, kk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, kk), jnp.float32),
        interpret=True,
    )(x)
    return out[:n, :k].reshape(shape)
