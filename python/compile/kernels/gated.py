"""Pallas kernel for the fused PixelCNN gate: tanh(a) · sigmoid(g).

On GPU this fusion saves a round-trip through HBM between the two halves
of the 2F-channel conv output; on TPU the same reasoning holds for
HBM↔VMEM traffic — the kernel reads both halves of a VMEM-resident tile
once and writes one output tile. Grid tiles the flattened element space so
arbitrarily-shaped activations reuse the same kernel.

interpret=True (CPU validation); oracle: `ref.gated_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gated_pallas"]

_TILE = 1024  # elements per program; multiple of the 128-lane VPU width


def _gate_kernel(a_ref, g_ref, o_ref):
    a = a_ref[...]
    g = g_ref[...]
    o_ref[...] = jnp.tanh(a) * (1.0 / (1.0 + jnp.exp(-g)))


@jax.jit
def gated_pallas(a, g):
    """Fused gate over same-shaped tensors a, g (any shape). f32 out."""
    shape = a.shape
    flat_a = a.reshape(-1).astype(jnp.float32)
    flat_g = g.reshape(-1).astype(jnp.float32)
    n = flat_a.shape[0]
    pad = (-n) % _TILE
    if pad:
        flat_a = jnp.pad(flat_a, (0, pad))
        flat_g = jnp.pad(flat_g, (0, pad))
    m = flat_a.shape[0]
    out = pl.pallas_call(
        _gate_kernel,
        grid=(m // _TILE,),
        in_specs=[
            pl.BlockSpec((_TILE,), lambda i: (i,)),
            pl.BlockSpec((_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(flat_a, flat_g)
    return out[:n].reshape(shape)
