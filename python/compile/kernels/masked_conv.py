"""Pallas kernel for the causally-masked convolution — the ARM hot spot.

Hardware adaptation (paper targets CUDA; see DESIGN.md §4): instead of the
per-thread weight masking a GPU PixelCNN uses, the causal mask is folded
into the weight tensor once per call, so the kernel's inner loop is a
*dense* im2col × weight matmul that maps onto the MXU systolic array. Each
grid program stages one image's padded slab through VMEM (expressed with
BlockSpec rather than CUDA threadblocks), builds the im2col patch matrix,
and performs a single `[H·W, Cin·kh·kw] @ [Cin·kh·kw, Cout]` contraction.

VMEM footprint per program (f32):
    (H+kh-1)·(W+kw-1)·Cin + Cin·kh·kw·Cout + H·W·Cout  elements.
For the largest config here (Cin=768, Cout=96, 12×12, kh=kw=3) that is
≈ 3.2 MiB — below the 16 MiB VMEM budget. On images too large for one
slab, a real-TPU version would row-tile with overlapping halos via manual
HBM→VMEM DMA (pl.dslice on an ANY-memory operand); at this repo's scales
the single-slab schedule is already VMEM-resident, so we keep the simpler
grid = (batch,) schedule.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so this path is validated for correctness/structure (against
`ref.masked_conv2d_ref`) rather than wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_conv2d_pallas"]


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int):
    """One grid step: output [1, Cout, H, W] for one image.

    x_ref: [1, Cin, H + kh - 1, W + kw - 1] — padded input slab.
    w_ref: [Cout, Cin, kh, kw] — pre-masked weights (dense by the time we
           get here; the causal mask was folded in by the wrapper).
    b_ref: [Cout]
    o_ref: [1, Cout, H, W]
    """
    x = x_ref[...]
    w = w_ref[...]
    cout, cin = w.shape[0], w.shape[1]
    hout, wout = o_ref.shape[2], o_ref.shape[3]
    # im2col: gather the kh*kw shifted views, stack into the patch matrix.
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(x[0, :, dy : dy + hout, dx : dx + wout])  # [Cin, H, W]
    patches = jnp.stack(cols, axis=0)  # [kh*kw, Cin, H, W]
    patches = patches.transpose(2, 3, 1, 0).reshape(hout * wout, cin * kh * kw)
    wmat = w.transpose(1, 2, 3, 0).reshape(cin * kh * kw, cout)
    out = jnp.dot(patches, wmat, preferred_element_type=jnp.float32)  # MXU contraction
    out = out + b_ref[...][None, :]
    o_ref[...] = out.reshape(hout, wout, cout).transpose(2, 0, 1)[None]


@jax.jit
def masked_conv2d_pallas(x, w, b, mask):
    """Causally-masked SAME conv via the Pallas kernel (interpret mode).

    x: [B, Cin, H, W] f32; w: [Cout, Cin, kh, kw]; b: [Cout]; mask: [kh, kw].
    Returns [B, Cout, H, W] f32, numerically equal to
    `ref.masked_conv2d_ref(x, w, b, mask)`.
    """
    bsz, cin, h, wdt = x.shape
    cout, _, kh, kw = w.shape
    wm = (w * mask[None, None, :, :]).astype(jnp.float32)  # fold mask -> dense matmul
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, 0), (ph, ph), (pw, pw)))

    return pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, cin, h + kh - 1, wdt + kw - 1), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cout, cin, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, cout, h, wdt), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, cout, h, wdt), jnp.float32),
        interpret=True,
    )(xp, wm, b.astype(jnp.float32))
