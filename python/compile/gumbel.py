"""Gumbel-max reparametrization utilities (paper §2.2 and Appendix B).

The sampling step `x_i ~ Cat(softmax(μ_i))` is reparametrized as
`x_i = argmax_c(μ_i,c + ε_i,c)` with ε standard Gumbel — isolating all
stochasticity into ε so predictive sampling becomes a deterministic
fixed-point problem. The *posterior* sampler p(ε | x) (Appendix B) draws
noise consistent with a given sample x, enabling forecast-module training
on data samples without running the slow autoregressive inverse.

The rust coordinator re-implements these (substrate/gumbel.rs); the pytest
suite checks both the argmax-consistency and the marginal statistics here.
"""

from __future__ import annotations

import numpy as np

__all__ = ["sample_gumbel", "gumbel_argmax", "posterior_gumbel"]


def sample_gumbel(rng: np.random.Generator, shape) -> np.ndarray:
    """Standard Gumbel(0, 1) noise."""
    u = rng.uniform(low=np.finfo(np.float64).tiny, high=1.0, size=shape)
    return -np.log(-np.log(u))


def gumbel_argmax(logp: np.ndarray, eps: np.ndarray) -> np.ndarray:
    """argmax over the last axis of logp + eps (the reparametrized sample)."""
    return np.argmax(logp + eps, axis=-1)


def _trunc_gumbel(rng: np.random.Generator, mu: np.ndarray, bound: np.ndarray) -> np.ndarray:
    """Sample Gumbel(mu) truncated to (-inf, bound].

    Uses the max-coupling identity TG = -log(exp(-bound) + exp(-G)) with
    G ~ Gumbel(mu) (Maddison et al. 2014; Kool et al. 2019), evaluated with
    logaddexp for stability.
    """
    g = mu + sample_gumbel(rng, mu.shape)
    return -np.logaddexp(-bound, -g)


def posterior_gumbel(rng: np.random.Generator, logp: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Sample ε ~ p(ε | x) for categorical log-probs.

    logp: [..., K] model log-probabilities μ; x: [...] integer samples.
    Returns ε with the guarantees:
      * argmax(μ + ε) == x exactly, and
      * each ε component is marginally standard Gumbel.
    """
    k = logp.shape[-1]
    x_onehot = np.eye(k, dtype=bool)[x]  # [..., K]
    mu_x = np.take_along_axis(logp, x[..., None], axis=-1)  # [..., 1]
    # Max-trick decomposition: M = max_c(mu_c + eps_c) ~ Gumbel(lse(mu)) and
    # is independent of the argmax. Sample M, pin the winner's value to it.
    lse = np.log(np.exp(logp).sum(axis=-1, keepdims=True))  # ~0 if normalized
    max_val = lse + sample_gumbel(rng, mu_x.shape)  # [..., 1]
    eps_win = max_val - mu_x
    # Losing coordinates: truncated below the maximum.
    eps_rest = _trunc_gumbel(rng, logp, np.broadcast_to(max_val, logp.shape)) - logp
    return np.where(x_onehot, eps_win, eps_rest)
