"""AOT pipeline: train every model config, lower to HLO text, emit manifest.

This is the ONLY python entrypoint in the build (make artifacts); rust is
self-contained afterwards. Interchange is HLO *text* — xla_extension 0.5.1
rejects jax>=0.5 serialized protos (64-bit instruction ids), while the text
parser reassigns ids (see /opt/xla-example/README.md).

Per ARM config and batch size B in {1, 32} we export
    <cfg>_step_b<B>.hlo.txt : x i32[B,d] -> (logp f32[B,d,K], fore f32[B,P,T,K])
plus logp-only flavors (steplp_b<B>) and trailing-window span variants
    <cfg>_step_b<B>_s<S>.hlo.txt : x i32[B,d] -> (logp f32[B,S,K], fore ...)
(S in span_ladder(d); logp restricted to the last S positions) that the
rust VariantCatalog selects among per pass,
plus, for the latent configs, the autoencoder
    ae_<name>_enc_b32.hlo.txt : img f32[32,3,16,16] -> z i32[32,256]
    ae_<name>_dec_b32.hlo.txt : z i32[32,256] -> img f32[32,3,16,16]
plus a Pallas-kernel lowering of the smallest model (parity artifact), a
small test batch per config (<cfg>_test_x.bin, row-major i32 LE) for
rust-side likelihood eval, and artifacts/manifest.json describing it all.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import autoencoder as ae
from . import datasets, model, train

# ---------------------------------------------------------------------------
# Configurations (scaled per DESIGN.md §3)
# ---------------------------------------------------------------------------

ARM_CONFIGS = {
    # Explicit likelihood modeling (Table 1). Binary digits keep the paper's
    # smaller-model choice; color sets share one architecture.
    "mnist_bin": model.ArmConfig("mnist_bin", channels=1, height=16, width=16, categories=2,
                                 filters=32, n_resnets=2, t_fore=20, fore_filters=32, embed_dim=4),
    "svhn8": model.ArmConfig("svhn8", channels=3, height=10, width=10, categories=256,
                             filters=48, n_resnets=2, t_fore=5, fore_filters=48),
    "cifar5": model.ArmConfig("cifar5", channels=3, height=10, width=10, categories=32,
                              filters=48, n_resnets=2, t_fore=5, fore_filters=48),
    "cifar8": model.ArmConfig("cifar8", channels=3, height=10, width=10, categories=256,
                              filters=48, n_resnets=2, t_fore=5, fore_filters=48),
    # Table-3 ablation: learned forecasting without representation sharing.
    "cifar8_noshare": model.ArmConfig("cifar8_noshare", channels=3, height=10, width=10, categories=256,
                                      filters=48, n_resnets=2, t_fore=5, fore_filters=48, share_repr=False),
    # Latent-space ARMs (Table 2): 4x8x8, K=64.
    "latent_svhn": model.ArmConfig("latent_svhn", channels=4, height=8, width=8, categories=64,
                                   filters=48, n_resnets=2, t_fore=5, fore_filters=48),
    "latent_cifar": model.ArmConfig("latent_cifar", channels=4, height=8, width=8, categories=64,
                                    filters=48, n_resnets=2, t_fore=5, fore_filters=48),
    "latent_in32": model.ArmConfig("latent_in32", channels=4, height=8, width=8, categories=64,
                                   filters=48, n_resnets=2, t_fore=5, fore_filters=48),
}

AE_CONFIGS = {
    "svhn": ae.AeConfig("svhn"),
    "cifar": ae.AeConfig("cifar"),
    "in32": ae.AeConfig("in32"),
}

# dataset name, generator kwargs per explicit config
DATA_FOR = {
    "mnist_bin": ("binary_digits", {"size": 16}),
    "svhn8": ("svhn", {"size": 10, "bits": 8}),
    "cifar5": ("cifar", {"size": 10, "bits": 5}),
    "cifar8": ("cifar", {"size": 10, "bits": 8}),
    "cifar8_noshare": ("cifar", {"size": 10, "bits": 8}),
}
AE_DATA_FOR = {"svhn": ("svhn", {"size": 16, "bits": 8}),
               "cifar": ("cifar", {"size": 16, "bits": 8}),
               "in32": ("imagenet", {"size": 16, "bits": 8})}
LATENT_OF = {"latent_svhn": "svhn", "latent_cifar": "cifar", "latent_in32": "in32"}

BATCH_SIZES = (1, 32)
N_TRAIN = 512
N_TEST = 64


def span_ladder(dim: int):
    """Trailing-window span lengths exported next to the full-shape pass.

    A geometric d/8, d/4, d/2 ladder: continuous-batching schedules spend
    most passes near the frontier, so short windows dominate selection
    while the full-shape export stays the anchor/fallback. Values are
    deduped and clamped to 1 <= s < d (tiny models may export fewer)."""
    spans = sorted({max(1, dim // 8), max(1, dim // 4), max(1, dim // 2)})
    return tuple(s for s in spans if s < dim)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    `as_hlo_text(True)` == print_large_constants: the trained weights are
    baked into the graph as constants, and the default printer elides
    anything big as `constant({...})` — which the consumer-side parser
    silently turns into garbage. Full printing is essential.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def export_fn(fn, example_args, path: str) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def export_arm(params, cfg: model.ArmConfig, out_dir: str, batch_sizes=BATCH_SIZES, use_pallas=False, suffix=""):
    files = {}
    for b in batch_sizes:
        spec = jax.ShapeDtypeStruct((b, cfg.dim), jnp.int32)
        name = f"{cfg.name}_step{suffix}_b{b}.hlo.txt"
        n = export_fn(lambda x: model.step(params, x, cfg, use_pallas=use_pallas), (spec,),
                      os.path.join(out_dir, name))
        print(f"  wrote {name} ({n} chars)", flush=True)
        files[f"step{suffix}_b{b}"] = name
        if not use_pallas:
            # logp-only variant: methods that never read the forecast heads
            # (baseline / zeros / last / FPI / no-reparam) skip both the
            # fore-head compute and its device->host transfer — the
            # dominant per-pass cost at B=32 (see EXPERIMENTS.md §Perf).
            name_lp = f"{cfg.name}_steplp{suffix}_b{b}.hlo.txt"
            n = export_fn(lambda x: (model.step(params, x, cfg)[0],), (spec,),
                          os.path.join(out_dir, name_lp))
            print(f"  wrote {name_lp} ({n} chars)", flush=True)
            files[f"steplp{suffix}_b{b}"] = name_lp
            # Trailing-window span variants, both flavors: full [B, d]
            # input, logp sliced to the last S positions (XLA dead-code
            # eliminates the untouched head computation). The rust
            # VariantCatalog picks the cheapest exported shape covering
            # each pass's frontier hull; the full-shape export above is
            # its required anchor.
            for s in span_ladder(cfg.dim):
                def step_span(x, s=s):
                    lp, fore = model.step(params, x, cfg)
                    return lp[:, -s:, :], fore

                name_s = f"{cfg.name}_step{suffix}_b{b}_s{s}.hlo.txt"
                n = export_fn(step_span, (spec,), os.path.join(out_dir, name_s))
                print(f"  wrote {name_s} ({n} chars)", flush=True)
                files[f"step{suffix}_b{b}_s{s}"] = name_s
                name_slp = f"{cfg.name}_steplp{suffix}_b{b}_s{s}.hlo.txt"
                n = export_fn(lambda x, s=s: (model.step(params, x, cfg)[0][:, -s:, :],), (spec,),
                              os.path.join(out_dir, name_slp))
                print(f"  wrote {name_slp} ({n} chars)", flush=True)
                files[f"steplp{suffix}_b{b}_s{s}"] = name_slp
    return files


def export_ae(params, cfg: ae.AeConfig, out_dir: str, b: int = 32):
    s = cfg.img_size
    img_spec = jax.ShapeDtypeStruct((b, 3, s, s), jnp.float32)
    z_spec = jax.ShapeDtypeStruct((b, cfg.latent_dim), jnp.int32)
    files = {}
    n = export_fn(lambda x: (ae.encode_flat(params, x, cfg),), (img_spec,),
                  os.path.join(out_dir, f"ae_{cfg.name}_enc_b{b}.hlo.txt"))
    print(f"  wrote ae_{cfg.name}_enc_b{b}.hlo.txt ({n} chars)", flush=True)
    files[f"enc_b{b}"] = f"ae_{cfg.name}_enc_b{b}.hlo.txt"
    n = export_fn(lambda z: (ae.decode_flat(params, z, cfg),), (z_spec,),
                  os.path.join(out_dir, f"ae_{cfg.name}_dec_b{b}.hlo.txt"))
    print(f"  wrote ae_{cfg.name}_dec_b{b}.hlo.txt ({n} chars)", flush=True)
    files[f"dec_b{b}"] = f"ae_{cfg.name}_dec_b{b}.hlo.txt"
    return files


def save_test_batch(x_flat: np.ndarray, path: str):
    """Row-major little-endian i32 dump of a [N, d] test batch."""
    x_flat.astype("<i4").tofile(path)


# ---------------------------------------------------------------------------
# Main pipeline
# ---------------------------------------------------------------------------


def run(out_dir: str, quick: bool = False, only=None):
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()
    manifest = {"version": 1, "quick": quick, "models": {}, "autoencoders": {}}
    # --only reruns a subset: merge into the existing manifest.
    man_path = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(man_path):
        with open(man_path) as f:
            manifest = json.load(f)
        manifest["quick"] = quick

    arm_steps = 60 if quick else 700
    # 8-bit models need much longer to get the K=256 conditionals away
    # from uniform (otherwise FPI converges trivially and the paper's
    # categories-vs-difficulty ordering inverts).
    arm_steps_8bit = 60 if quick else 2200
    mnist_steps = 60 if quick else 300
    latent_steps = 60 if quick else 400
    ae_steps = 50 if quick else 250
    n_train = 128 if quick else N_TRAIN

    # ---- explicit-likelihood ARMs -------------------------------------
    for name, (dset, kw) in DATA_FOR.items():
        if only and name not in only:
            continue
        cfg = ARM_CONFIGS[name]
        print(f"[{name}] generating data + training...", flush=True)
        data = datasets.dataset_by_name(dset, n_train + N_TEST, seed=0, **kw).astype(np.int32)
        tr, te = data[:n_train], data[n_train:]
        steps = mnist_steps if name == "mnist_bin" else (arm_steps_8bit if cfg.categories >= 256 else arm_steps)
        params, losses = train.train_arm(cfg, tr, steps=steps, batch_size=16, seed=0)
        bpd = train.eval_bpd(params, cfg, te)
        print(f"[{name}] test bpd {bpd:.4f}", flush=True)
        files = export_arm(params, cfg, out_dir,
                           batch_sizes=(32,) if name == "cifar8_noshare" else BATCH_SIZES)
        if name == "mnist_bin":
            files.update(export_arm(params, cfg, out_dir, batch_sizes=(1,), use_pallas=True, suffix="_pallas"))
        np.savez(os.path.join(out_dir, f"{name}_params.npz"), **{k: np.asarray(v) for k, v in params.items()})
        test_flat = np.asarray(model.img_to_flat(jnp.asarray(te[:32])))
        save_test_batch(test_flat, os.path.join(out_dir, f"{name}_test_x.bin"))
        files["test_x"] = f"{name}_test_x.bin"
        manifest["models"][name] = {
            **cfg.to_manifest(), "files": files, "bpd": bpd,
            "final_loss": float(np.mean(losses[-20:])), "train_steps": steps,
            "kind": "explicit", "dataset": dset, "dataset_kw": kw,
            "test_n": int(test_flat.shape[0]),
        }

    # ---- autoencoders + latent ARMs ------------------------------------
    for ae_name, (dset, kw) in AE_DATA_FOR.items():
        latent_name = {v: k for k, v in LATENT_OF.items()}[ae_name]
        if only and latent_name not in only:
            continue
        acfg = AE_CONFIGS[ae_name]
        cfg = ARM_CONFIGS[latent_name]
        print(f"[ae:{ae_name}] generating data + training AE...", flush=True)
        imgs = datasets.dataset_by_name(dset, n_train + N_TEST, seed=1, **kw)
        ae_params, _ = train.train_autoencoder(acfg, imgs[:n_train], steps=ae_steps, batch_size=16, seed=0)
        mse = float(np.mean((np.asarray(ae.autoencode(ae_params, jnp.asarray(ae.normalize_img(imgs[n_train:n_train+32])), acfg)[0])
                             - ae.normalize_img(imgs[n_train:n_train+32])) ** 2))
        print(f"[ae:{ae_name}] test mse {mse:.5f}; encoding latents...", flush=True)
        latents = train.encode_dataset(ae_params, acfg, imgs)  # [N, 256]
        lat_imgs = np.asarray(model.flat_to_img(jnp.asarray(latents), cfg))
        print(f"[{latent_name}] training latent ARM...", flush=True)
        params, losses = train.train_arm(cfg, lat_imgs[:n_train], steps=latent_steps, batch_size=16, seed=0)
        bpd = train.eval_bpd(params, cfg, lat_imgs[n_train:])
        print(f"[{latent_name}] test bpd(latent) {bpd:.4f}", flush=True)
        files = export_arm(params, cfg, out_dir)
        files.update(export_ae(ae_params, acfg, out_dir))
        save_test_batch(latents[n_train : n_train + 32], os.path.join(out_dir, f"{latent_name}_test_x.bin"))
        files["test_x"] = f"{latent_name}_test_x.bin"
        manifest["models"][latent_name] = {
            **cfg.to_manifest(), "files": files, "bpd": bpd,
            "final_loss": float(np.mean(losses[-20:])), "train_steps": arm_steps,
            "kind": "latent", "dataset": dset, "dataset_kw": kw, "autoencoder": ae_name,
            "test_n": 32,
        }
        manifest["autoencoders"][ae_name] = {**acfg.to_manifest(), "mse": mse}

    manifest["build_seconds"] = round(time.time() - t_start, 1)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest.json written; total {manifest['build_seconds']}s", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="tiny training run (CI smoke)")
    ap.add_argument("--only", nargs="*", default=None, help="subset of model names")
    args = ap.parse_args()
    run(os.path.abspath(args.out), quick=args.quick, only=args.only)


if __name__ == "__main__":
    main()
