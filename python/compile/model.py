"""L2: the autoregressive model (PixelCNN-family) in JAX.

Architecture (paper §4.1 / Appendix A, adapted to this substrate — see
DESIGN.md §3):

* **Spatially-causal trunk** — an embedding lookup of the discrete input
  (mathematically a one-hot × linear layer, implemented as a gather), a
  5×5 mask-"A" convolution (center tap excluded), then `n_resnets` gated
  residual blocks with 3×3 center-inclusive causal convs. By induction the
  trunk output `u(p)` depends only on pixels strictly before `p` in raster
  order — exactly the `h` the paper shares with the forecasting modules.
* **Channel-autoregressive head** — per-pixel logits are
  `base(u(p)) + Σ_{c'<c} W[c'→c][x_{p,c'}]`, i.e. the categorical output
  of channel `c` conditions on all preceding channels of the same pixel
  via K×K lookup tables (a gather; equivalent to the paper's masked 1×1
  convolutions over one-hot inputs, but O(C²) gathers instead of a
  (CK)² matmul).
* **Forecasting modules** (paper §2.4) — a causal 3×3 conv + gate over the
  shared representation `u`, then a 1×1 conv to T·K logits. Module output
  `fore[b, p, t, :]` is log P_F^{(t)} of flat variable `p·C + t`
  conditioned on pixels `< p` only. The `share_repr=False` ablation
  (Table 3) replaces `u` with features computed directly from the input
  embedding through a mask-"A" conv, i.e. conditioned on x_{<i} without
  the shared representation.

Flattening order everywhere (the L2↔L3 contract): channel innermost,
`flat(y, x, c) = (y·W + x)·C + c`.

All convolutions route through the Pallas kernels (`use_pallas=True`) or
their jnp oracles (`use_pallas=False`, the default fast path); both lower
into the same step HLO signature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.gated import gated_pallas
from .kernels.head import log_softmax_pallas
from .kernels.masked_conv import masked_conv2d_pallas

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArmConfig:
    """Static configuration of one ARM (image-space or latent-space)."""

    name: str
    channels: int  # C: data channels per pixel
    height: int
    width: int
    categories: int  # K
    filters: int  # F: trunk width
    n_resnets: int
    t_fore: int  # T: forecast window, counted in flat variables
    fore_filters: int
    embed_dim: int = 16
    share_repr: bool = True  # False => Table-3 "no representation sharing"

    @property
    def pixels(self) -> int:
        return self.height * self.width

    @property
    def dim(self) -> int:
        return self.channels * self.pixels

    def to_manifest(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "channels": self.channels,
            "height": self.height,
            "width": self.width,
            "categories": self.categories,
            "filters": self.filters,
            "n_resnets": self.n_resnets,
            "t_fore": self.t_fore,
            "fore_filters": self.fore_filters,
            "share_repr": self.share_repr,
            "dim": self.dim,
            "pixels": self.pixels,
        }


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _winit(rng: np.random.Generator, shape, fan_in: int) -> jnp.ndarray:
    return jnp.asarray(rng.normal(0.0, 1.0 / np.sqrt(fan_in), size=shape), jnp.float32)


def init_params(cfg: ArmConfig, seed: int = 0) -> Params:
    """Initialize all ARM parameters (numpy-seeded, deterministic)."""
    rng = np.random.default_rng(np.random.SeedSequence(entropy=0xA12, spawn_key=(seed,)))
    c, k, f, e = cfg.channels, cfg.categories, cfg.filters, cfg.embed_dim
    ff, t = cfg.fore_filters, cfg.t_fore
    p: Params = {}
    p["embed"] = _winit(rng, (c, k, e), e)
    p["conv_in_w"] = _winit(rng, (f, c * e, 5, 5), c * e * 24)
    p["conv_in_b"] = jnp.zeros((f,), jnp.float32)
    for i in range(cfg.n_resnets):
        p[f"res{i}_w"] = _winit(rng, (2 * f, f, 3, 3), f * 9)
        p[f"res{i}_b"] = jnp.zeros((2 * f,), jnp.float32)
    p["head_h_w"] = _winit(rng, (f, f, 1, 1), f)
    p["head_h_b"] = jnp.zeros((f,), jnp.float32)
    p["head_o_w"] = _winit(rng, (c * k, f, 1, 1), f)
    p["head_o_b"] = jnp.zeros((c * k,), jnp.float32)
    # Channel-AR lookup tables: chan[c_src][c_dst] used when c_src < c_dst.
    # Stored dense [C, C, K, K]; the strictly-lower mask is applied in fwd.
    if c > 1:
        p["chan"] = _winit(rng, (c, c, k, k), k) * 0.1
    # Forecasting modules.
    fore_in = f if cfg.share_repr else c * e
    p["fore_c_w"] = _winit(rng, (2 * ff, fore_in, 3, 3), fore_in * 9)
    p["fore_c_b"] = jnp.zeros((2 * ff,), jnp.float32)
    p["fore_o_w"] = _winit(rng, (t * k, ff, 1, 1), ff)
    p["fore_o_b"] = jnp.zeros((t * k,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _conv(x, w, b, mask, use_pallas: bool):
    if use_pallas:
        return masked_conv2d_pallas(x, w, b, jnp.asarray(mask))
    return ref.masked_conv2d_ref(x, w, b, jnp.asarray(mask))


def _gate(a, g, use_pallas: bool):
    return gated_pallas(a, g) if use_pallas else ref.gated_ref(a, g)


def _logsoftmax(x, use_pallas: bool):
    return log_softmax_pallas(x) if use_pallas else ref.log_softmax_ref(x)


def _embed(params: Params, x_img: jnp.ndarray, cfg: ArmConfig) -> jnp.ndarray:
    """x_img i32 [B,C,H,W] -> embedded [B, C*E, H, W] via gather."""
    # emb[c] is [K, E]; take along K with x values.
    parts = []
    for c in range(cfg.channels):
        e = jnp.take(params["embed"][c], x_img[:, c], axis=0)  # [B,H,W,E]
        parts.append(e)
    emb = jnp.concatenate(parts, axis=-1)  # [B,H,W,C*E]
    return emb.transpose(0, 3, 1, 2)


def trunk(params: Params, x_img: jnp.ndarray, cfg: ArmConfig, use_pallas: bool = False) -> jnp.ndarray:
    """Spatially-causal trunk: u[b,:,y,x] depends on pixels strictly < (y,x)."""
    mask_a = ref.spatial_causal_mask(5, 5, include_center=False)
    mask_b = ref.spatial_causal_mask(3, 3, include_center=True)
    h = _embed(params, x_img, cfg)
    u = _conv(h, params["conv_in_w"], params["conv_in_b"], mask_a, use_pallas)
    for i in range(cfg.n_resnets):
        y = _conv(u, params[f"res{i}_w"], params[f"res{i}_b"], mask_b, use_pallas)
        a, g = jnp.split(y, 2, axis=1)
        u = u + _gate(a, g, use_pallas)
    return u


def _head_logits(params: Params, u: jnp.ndarray, x_img: jnp.ndarray, cfg: ArmConfig) -> jnp.ndarray:
    """Per-variable logits [B, d, K] (flat order: (y*W+x)*C + c)."""
    b = x_img.shape[0]
    c, k = cfg.channels, cfg.categories
    hh = jax.nn.relu(ref.masked_conv2d_ref(u, params["head_h_w"], params["head_h_b"], jnp.ones((1, 1))))
    base = ref.masked_conv2d_ref(hh, params["head_o_w"], params["head_o_b"], jnp.ones((1, 1)))
    # [B, C*K, H, W] -> [B, H, W, C, K]
    base = base.reshape(b, c, k, cfg.height, cfg.width).transpose(0, 3, 4, 1, 2)
    if c > 1:
        # Channel conditioning: for c_dst, add chan[c_src, c_dst][x_{p,c_src}]
        # for every c_src < c_dst (gathers, not matmuls).
        add = jnp.zeros_like(base)
        for cd in range(1, c):
            acc = 0.0
            for cs in range(cd):
                tbl = params["chan"][cs, cd]  # [K, K]
                acc = acc + jnp.take(tbl, x_img[:, cs], axis=0)  # [B,H,W,K]
            add = add.at[:, :, :, cd, :].set(acc)
        base = base + add
    return base.reshape(b, cfg.dim, k)


def _fore_logits(params: Params, u: jnp.ndarray, x_img: jnp.ndarray, cfg: ArmConfig, use_pallas: bool = False) -> jnp.ndarray:
    """Forecast-head logits [B, P, T, K]; entry (p, t) is the forecast of
    flat variable p*C + t, conditioned on pixels < p only."""
    b = x_img.shape[0]
    if cfg.share_repr:
        src = u
        mask = ref.spatial_causal_mask(3, 3, include_center=True)  # u already strictly past
    else:
        src = _embed(params, x_img, cfg)
        mask = ref.spatial_causal_mask(3, 3, include_center=False)  # x needs mask A
    y = _conv(src, params["fore_c_w"], params["fore_c_b"], mask, use_pallas)
    a, g = jnp.split(y, 2, axis=1)
    fh = _gate(a, g, use_pallas)
    fo = ref.masked_conv2d_ref(fh, params["fore_o_w"], params["fore_o_b"], jnp.ones((1, 1)))
    fo = fo.reshape(b, cfg.t_fore, cfg.categories, cfg.height, cfg.width)
    return fo.transpose(0, 3, 4, 1, 2).reshape(b, cfg.pixels, cfg.t_fore, cfg.categories)


def forward(params: Params, x_img: jnp.ndarray, cfg: ArmConfig, use_pallas: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full parallel inference pass.

    x_img: i32 [B, C, H, W]. Returns (logp [B,d,K], fore_logp [B,P,T,K]),
    both log-softmax normalized over K.
    """
    u = trunk(params, x_img, cfg, use_pallas)
    logits = _head_logits(params, u, x_img, cfg)
    fore = _fore_logits(params, u, x_img, cfg, use_pallas)
    return _logsoftmax(logits, use_pallas), _logsoftmax(fore, use_pallas)


# ---------------------------------------------------------------------------
# Flat <-> image layout
# ---------------------------------------------------------------------------


def flat_to_img(x_flat: jnp.ndarray, cfg: ArmConfig) -> jnp.ndarray:
    """[B, d] -> [B, C, H, W] with flat order (y*W + x)*C + c."""
    b = x_flat.shape[0]
    return x_flat.reshape(b, cfg.height, cfg.width, cfg.channels).transpose(0, 3, 1, 2)


def img_to_flat(x_img: jnp.ndarray) -> jnp.ndarray:
    """[B, C, H, W] -> [B, d] with flat order (y*W + x)*C + c."""
    b, c, h, w = x_img.shape
    return x_img.transpose(0, 2, 3, 1).reshape(b, c * h * w)


def step(params: Params, x_flat: jnp.ndarray, cfg: ArmConfig, use_pallas: bool = False):
    """The AOT-exported signature: x i32 [B,d] -> (logp [B,d,K], fore [B,P,T,K])."""
    return forward(params, flat_to_img(x_flat.astype(jnp.int32), cfg), cfg, use_pallas)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def nll_bpd(params: Params, x_img: jnp.ndarray, cfg: ArmConfig) -> jnp.ndarray:
    """Mean negative log-likelihood in bits per dimension."""
    logp, _ = forward(params, x_img, cfg)
    x_flat = img_to_flat(x_img)
    ll = jnp.take_along_axis(logp, x_flat[:, :, None].astype(jnp.int32), axis=2)[:, :, 0]
    return -jnp.mean(ll) / jnp.log(2.0)


def loss_fn(params: Params, x_img: jnp.ndarray, cfg: ArmConfig, fore_weight: float = 0.01) -> jnp.ndarray:
    """NLL + fore_weight · KL(ARM ‖ forecast) (paper Eq. 9, ARM detached)."""
    logp, fore = forward(params, x_img, cfg)
    x_flat = img_to_flat(x_img)
    ll = jnp.take_along_axis(logp, x_flat[:, :, None].astype(jnp.int32), axis=2)[:, :, 0]
    nll = -jnp.mean(ll)

    arm = jax.lax.stop_gradient(logp)  # [B, d, K]
    arm_p = jnp.exp(arm)
    kls = []
    c = cfg.channels
    for t in range(cfg.t_fore):
        # Forecast (p, t) targets flat variable j = p*C + t, valid while the
        # target pixel p + t//C stays inside the image.
        n_valid = cfg.pixels - (t // c)
        if n_valid <= 0:
            continue
        p_idx = jnp.arange(n_valid)
        j_idx = p_idx * c + t
        kl = jnp.sum(arm_p[:, j_idx, :] * (arm[:, j_idx, :] - fore[:, p_idx, t, :]), axis=-1)
        kls.append(jnp.mean(kl))
    fore_kl = jnp.mean(jnp.stack(kls)) if kls else 0.0
    return nll + fore_weight * fore_kl


def param_count(params: Params) -> int:
    return int(sum(np.prod(v.shape) for v in jax.tree_util.tree_leaves(params)))
