"""Build-time training: from-scratch Adam + training loops.

optax is unavailable in this offline image, so Adam is implemented directly
over jax pytrees. Training recipes follow the paper's Appendix A (Adam,
lr 2e-4 with exponential decay, weight decay 1e-6, forecast-KL weight
0.01; separate AE-then-ARM schedule for the latent experiments), scaled
down per DESIGN.md §3 for a single CPU core.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import autoencoder as ae
from . import model

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Adam (from scratch)
# ---------------------------------------------------------------------------


def adam_init(params: Params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(
    params: Params,
    grads: Params,
    state: Dict[str, Any],
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 1e-6,
) -> Tuple[Params, Dict[str, Any]]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    corr1 = 1.0 - b1**tf
    corr2 = 1.0 - b2**tf

    def upd(p, m_, v_):
        mhat = m_ / corr1
        vhat = v_ / corr2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Generic training loop
# ---------------------------------------------------------------------------


def train_loop(
    params: Params,
    loss: Callable[[Params, jnp.ndarray], jnp.ndarray],
    data: np.ndarray,
    steps: int,
    batch_size: int,
    lr: float = 2e-4,
    lr_decay: float = 0.999995,
    seed: int = 0,
    log_every: int = 50,
    tag: str = "",
) -> Tuple[Params, List[float]]:
    """Minimizes `loss(params, batch)` with Adam over random minibatches."""
    state = adam_init(params)
    rng = np.random.default_rng(seed)
    losses: List[float] = []

    @jax.jit
    def update(p, s, batch, lr_now):
        l, g = jax.value_and_grad(loss)(p, batch)
        p2, s2 = adam_update(p, g, s, lr_now)
        return p2, s2, l

    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, data.shape[0], size=batch_size)
        batch = jnp.asarray(data[idx])
        lr_now = lr * (lr_decay**it)
        params, state, l = update(params, state, batch, lr_now)
        losses.append(float(l))
        if log_every and (it % log_every == 0 or it == steps - 1):
            print(f"  [{tag}] step {it:5d} loss {float(l):.4f} ({time.time()-t0:.1f}s)", flush=True)
    return params, losses


# ---------------------------------------------------------------------------
# Recipes
# ---------------------------------------------------------------------------


def train_arm(cfg: model.ArmConfig, data_imgs: np.ndarray, steps: int, batch_size: int, seed: int = 0):
    """Train an ARM (with forecast heads) on int images [N, C, H, W]."""
    params = model.init_params(cfg, seed=seed)

    def loss(p, batch):
        return model.loss_fn(p, batch, cfg)

    params, losses = train_loop(
        params, loss, data_imgs.astype(np.int32), steps, batch_size, seed=seed, tag=f"arm:{cfg.name}"
    )
    return params, losses


def train_autoencoder(cfg: ae.AeConfig, imgs_u8: np.ndarray, steps: int, batch_size: int, seed: int = 0):
    """Train the discrete AE on uint8 images [N, 3, S, S]."""
    params = ae.init_params(cfg, seed=seed)
    data = ae.normalize_img(imgs_u8)

    def loss(p, batch):
        return ae.mse_loss(p, batch, cfg)

    params, losses = train_loop(params, loss, data, steps, batch_size, seed=seed, tag=f"ae:{cfg.name}")
    return params, losses


def encode_dataset(ae_params: Params, cfg: ae.AeConfig, imgs_u8: np.ndarray, batch: int = 64) -> np.ndarray:
    """Frozen-encoder latents for the whole dataset, flat [N, latent_dim]."""
    data = ae.normalize_img(imgs_u8)
    enc = jax.jit(lambda b: ae.encode_flat(ae_params, b, cfg))
    outs = [np.asarray(enc(jnp.asarray(data[i : i + batch]))) for i in range(0, data.shape[0], batch)]
    return np.concatenate(outs, axis=0)


def eval_bpd(params: Params, cfg: model.ArmConfig, data_imgs: np.ndarray, batch: int = 32) -> float:
    """Test-set bits/dim of the ARM."""
    f = jax.jit(lambda b: model.nll_bpd(params, b, cfg))
    vals = [float(f(jnp.asarray(data_imgs[i : i + batch].astype(np.int32)))) for i in range(0, min(len(data_imgs), 256), batch)]
    return float(np.mean(vals))
