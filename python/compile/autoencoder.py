"""Discrete-latent autoencoder (paper §4.2 / Appendix A.3).

Encoder: two 3×3 convs (half width), one strided 4×4 conv (full width),
two residual blocks, 1×1 to Cz·K logits; quantization by argmax-of-softmax
with a straight-through gradient. Decoder mirrors it. Substituted scale
(DESIGN.md §3): 16×16 RGB images → 4×8×8 latents with K=64 categories.

The latent ARM (model.py with C=4, H=W=8) is trained on frozen-encoder
latents, following the paper's separate-training schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AeConfig:
    name: str
    img_size: int = 16
    width: int = 64
    latent_channels: int = 4
    latent_hw: int = 8
    categories: int = 64

    @property
    def latent_dim(self) -> int:
        return self.latent_channels * self.latent_hw * self.latent_hw

    def to_manifest(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "img_size": self.img_size,
            "width": self.width,
            "latent_channels": self.latent_channels,
            "latent_hw": self.latent_hw,
            "categories": self.categories,
            "latent_dim": self.latent_dim,
        }


def _winit(rng: np.random.Generator, shape, fan_in: int) -> jnp.ndarray:
    return jnp.asarray(rng.normal(0.0, 1.0 / np.sqrt(fan_in), size=shape), jnp.float32)


def init_params(cfg: AeConfig, seed: int = 0) -> Params:
    rng = np.random.default_rng(np.random.SeedSequence(entropy=0xAE, spawn_key=(seed,)))
    w, hw = cfg.width, cfg.width // 2
    cz, k = cfg.latent_channels, cfg.categories
    p: Params = {}
    # Encoder.
    p["e0_w"], p["e0_b"] = _winit(rng, (hw, 3, 3, 3), 27), jnp.zeros((hw,), jnp.float32)
    p["e1_w"], p["e1_b"] = _winit(rng, (hw, hw, 3, 3), hw * 9), jnp.zeros((hw,), jnp.float32)
    p["e2_w"], p["e2_b"] = _winit(rng, (w, hw, 4, 4), hw * 16), jnp.zeros((w,), jnp.float32)
    for i in range(2):
        p[f"er{i}a_w"], p[f"er{i}a_b"] = _winit(rng, (w, w, 3, 3), w * 9), jnp.zeros((w,), jnp.float32)
        p[f"er{i}b_w"], p[f"er{i}b_b"] = _winit(rng, (w, w, 3, 3), w * 9), jnp.zeros((w,), jnp.float32)
    p["eo_w"], p["eo_b"] = _winit(rng, (cz * k, w, 1, 1), w), jnp.zeros((cz * k,), jnp.float32)
    # Decoder.
    p["di_w"], p["di_b"] = _winit(rng, (w, cz * k, 1, 1), cz * k), jnp.zeros((w,), jnp.float32)
    for i in range(2):
        p[f"dr{i}a_w"], p[f"dr{i}a_b"] = _winit(rng, (w, w, 3, 3), w * 9), jnp.zeros((w,), jnp.float32)
        p[f"dr{i}b_w"], p[f"dr{i}b_b"] = _winit(rng, (w, w, 3, 3), w * 9), jnp.zeros((w,), jnp.float32)
    p["dt_w"], p["dt_b"] = _winit(rng, (w, hw, 4, 4), w * 16), jnp.zeros((hw,), jnp.float32)
    p["d1_w"], p["d1_b"] = _winit(rng, (hw, hw, 3, 3), hw * 9), jnp.zeros((hw,), jnp.float32)
    p["d2_w"], p["d2_b"] = _winit(rng, (3, hw, 3, 3), hw * 9), jnp.zeros((3,), jnp.float32)
    return p


def _conv(x, w, b, stride=1, padding="SAME"):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def _deconv(x, w, b, stride=2):
    # Transposed conv: [In, Out, kh, kw] with IOHW numbers.
    out = jax.lax.conv_transpose(
        x, w, strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "IOHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def _resblock(x, wa, ba, wb, bb):
    y = jax.nn.relu(_conv(x, wa, ba))
    y = _conv(y, wb, bb)
    return jax.nn.relu(x + y)


def encode_logits(params: Params, img: jnp.ndarray, cfg: AeConfig) -> jnp.ndarray:
    """img f32 [B,3,S,S] in [-1,1] -> latent logits [B, Cz, Hz, Wz, K]."""
    h = jax.nn.relu(_conv(img, params["e0_w"], params["e0_b"]))
    h = jax.nn.relu(_conv(h, params["e1_w"], params["e1_b"]))
    h = jax.nn.relu(_conv(h, params["e2_w"], params["e2_b"], stride=2))
    for i in range(2):
        h = _resblock(h, params[f"er{i}a_w"], params[f"er{i}a_b"], params[f"er{i}b_w"], params[f"er{i}b_b"])
    lo = _conv(h, params["eo_w"], params["eo_b"])  # [B, Cz*K, Hz, Wz]
    b = img.shape[0]
    lo = lo.reshape(b, cfg.latent_channels, cfg.categories, cfg.latent_hw, cfg.latent_hw)
    return lo.transpose(0, 1, 3, 4, 2)  # [B, Cz, Hz, Wz, K]


def quantize_st(logits: jnp.ndarray) -> jnp.ndarray:
    """argmax-of-softmax one-hot with straight-through softmax gradient."""
    sm = jax.nn.softmax(logits, axis=-1)
    hard = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=sm.dtype)
    return hard + sm - jax.lax.stop_gradient(sm)


def decode(params: Params, z_onehot: jnp.ndarray, cfg: AeConfig) -> jnp.ndarray:
    """z_onehot f32 [B, Cz, Hz, Wz, K] -> reconstruction [B, 3, S, S]."""
    b = z_onehot.shape[0]
    z = z_onehot.transpose(0, 1, 4, 2, 3).reshape(b, cfg.latent_channels * cfg.categories, cfg.latent_hw, cfg.latent_hw)
    h = _conv(z, params["di_w"], params["di_b"])
    for i in range(2):
        h = _resblock(h, params[f"dr{i}a_w"], params[f"dr{i}a_b"], params[f"dr{i}b_w"], params[f"dr{i}b_b"])
    h = jax.nn.relu(_deconv(h, params["dt_w"], params["dt_b"], stride=2))
    h = jax.nn.relu(_conv(h, params["d1_w"], params["d1_b"]))
    return _conv(h, params["d2_w"], params["d2_b"])


def autoencode(params: Params, img: jnp.ndarray, cfg: AeConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    logits = encode_logits(params, img, cfg)
    zq = quantize_st(logits)
    return decode(params, zq, cfg), logits


def mse_loss(params: Params, img: jnp.ndarray, cfg: AeConfig) -> jnp.ndarray:
    recon, _ = autoencode(params, img, cfg)
    return jnp.mean((recon - img) ** 2)


def encode_flat(params: Params, img: jnp.ndarray, cfg: AeConfig) -> jnp.ndarray:
    """Deterministic encoder to flat int latents [B, latent_dim].

    Flat order matches the latent ARM: (y·Wz + x)·Cz + c.
    """
    z = jnp.argmax(encode_logits(params, img, cfg), axis=-1)  # [B, Cz, Hz, Wz]
    return z.transpose(0, 2, 3, 1).reshape(img.shape[0], cfg.latent_dim).astype(jnp.int32)


def decode_flat(params: Params, z_flat: jnp.ndarray, cfg: AeConfig) -> jnp.ndarray:
    """Flat int latents [B, latent_dim] -> images f32 [B, 3, S, S]."""
    b = z_flat.shape[0]
    z = z_flat.reshape(b, cfg.latent_hw, cfg.latent_hw, cfg.latent_channels).transpose(0, 3, 1, 2)
    onehot = jax.nn.one_hot(z, cfg.categories, dtype=jnp.float32)
    return decode(params, onehot, cfg)


def normalize_img(img_u8: np.ndarray) -> np.ndarray:
    """uint8 [N,3,S,S] in [0,255] -> f32 in [-1, 1]."""
    return (img_u8.astype(np.float32) / 255.0) * 2.0 - 1.0
