//! Offline minimal reimplementation of the `anyhow` API surface this
//! project uses (the real crate is unavailable without crates.io access):
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! An [`Error`] is a chain of messages, root cause first. `{e}` prints the
//! outermost context, `{e:#}` the whole chain joined with `": "` —
//! matching the real crate's `Display` behavior closely enough for logs
//! and tests. No backtraces, no downcasting.

use std::fmt;

/// A context-carrying error: `stack[0]` is the root cause, later entries
/// are contexts added by [`Context::context`] (outermost last).
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { stack: vec![message.to_string()] }
    }

    fn wrap(mut self, context: String) -> Error {
        self.stack.push(context);
        self
    }

    /// The outermost message (what `{}` prints).
    pub fn to_string_outer(&self) -> &str {
        self.stack.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.to_string_outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_string_outer())?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion that makes `?` work on std error types. `Error`
// itself deliberately does not implement `std::error::Error`, which keeps
// this impl coherent (the real crate uses the same trick).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut stack = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            stack.push(c.to_string());
            cur = c.source();
        }
        stack.reverse(); // root cause first
        Error { stack }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().wrap(context.to_string())),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().wrap(f().to_string())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = Err::<(), _>(e).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
        let s: String = "owned".into();
        assert_eq!(anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
