//! Offline minimal reimplementation of the `log` macro facade: levelled
//! stderr logging controlled by `RUST_LOG` (off/error/warn/info/debug/
//! trace; default `warn`). No per-module filtering, no pluggable loggers —
//! just enough for the serving stack's diagnostics.

use std::sync::atomic::{AtomicU8, Ordering};

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn max_level() -> u8 {
    let cached = LEVEL.load(Ordering::Relaxed);
    if cached != UNSET {
        return cached;
    }
    let level = match std::env::var("RUST_LOG").ok().map(|s| s.to_ascii_lowercase()).as_deref() {
        Some("off") => 0,
        Some("error") => 1,
        Some("info") => 3,
        Some("debug") => 4,
        Some("trace") => 5,
        _ => 2, // warn (also the default with RUST_LOG unset or unknown)
    };
    LEVEL.store(level, Ordering::Relaxed);
    level
}

#[doc(hidden)]
pub fn __log(level: u8, tag: &str, args: std::fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__log(1, "ERROR", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__log(2, "WARN", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__log(3, "INFO", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__log(4, "DEBUG", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__log(5, "TRACE", format_args!($($arg)*)) };
}
