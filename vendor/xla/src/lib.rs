//! Offline **stub** of the `xla` (PJRT) bindings API surface predsamp
//! uses. It exists so the crate builds and the mock-ARM / substrate paths
//! run on machines without the XLA closure: every operation that would
//! touch PJRT returns an error at runtime instead of failing the build.
//!
//! To run compiled artifacts, point the `xla` path dependency in the root
//! `Cargo.toml` at the real bindings — the type and method names here
//! mirror that API, so no source change is needed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (offline `xla` stub); point the \
         `xla` path dependency at the real bindings to run compiled artifacts"
    )))
}

/// PJRT CPU client handle (stub: creation succeeds, compilation errors).
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling HLO")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("tuple unpack")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("tuple unpack")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("tuple unpack")
    }

    pub fn copy_raw_to(&self, _out: &mut [f32]) -> Result<()> {
        unavailable("literal read")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("literal read")
    }
}

/// Parsed HLO-text module (stub: parsing only checks the file is readable).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        std::fs::read_to_string(p).map_err(|e| Error(format!("reading {}: {e}", p.display())))?;
        Ok(HloModuleProto)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
