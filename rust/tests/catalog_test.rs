//! Shape-variant catalog properties over the mock ARM — no artifacts
//! required, so these run everywhere.
//!
//! Two layers: randomized properties straight against `VariantCatalog`
//! (selection covers the plan, compaction→scatter round-trips bitwise,
//! telemetry counts every pass exactly once), and the engine-level A/B
//! matrix (every `{span-mix} x {policy}` cell bitwise equal between a
//! catalog-serving engine and the legacy full-shape engine).

use predsamp::coordinator::config::Method;
use predsamp::coordinator::engine::Engine;
use predsamp::runtime::artifact::{write_mock_manifest, Manifest, MockModelSpec};
use predsamp::runtime::step::{StepOutput, VariantCatalog};
use predsamp::sampler::mock::MockArm;
use predsamp::sampler::{PassPlan, SlotSpan};
use predsamp::substrate::proptest_lite::check;
use predsamp::{prop_assert, prop_assert_eq};

#[test]
fn catalog_selection_covers_and_roundtrips_bitwise() {
    // Random variant grids x random plans: the selected variant must
    // cover the plan's live rows and frontier hull, be minimal-cost among
    // covering variants, and the scattered window must be bitwise equal
    // to a full-shape pass over the same input.
    check("catalog-roundtrip", 24, |g| {
        let (c, px, k) = (g.usize_in(1, 3), g.usize_in(3, 8), g.usize_in(2, 6));
        let t_fore = g.usize_in(0, 3);
        let strength = g.f64_in(0.0, 4.0) as f32;
        let mseed = g.rng.next_u64();
        let d = c * px;
        let arm = |b: usize| MockArm::new(b, c, px, k, t_fore, strength, mseed);
        let mut batches = vec![1usize, 1 + g.usize_in(1, 3), 4 + g.usize_in(0, 4)];
        batches.sort_unstable();
        batches.dedup();
        let mut spans: Vec<usize> = (0..g.usize_in(0, 3)).map(|_| g.usize_in(1, d - 1)).collect();
        spans.sort_unstable();
        spans.dedup();
        let mut cat = VariantCatalog::new("prop", d, k, px, t_fore);
        for &b in &batches {
            cat.push_backend(b, d, true, Box::new(arm(b))).map_err(|e| e.to_string())?;
            if g.usize_in(0, 1) == 1 {
                cat.push_backend(b, d, false, Box::new(arm(b))).map_err(|e| e.to_string())?;
            }
            for &s in &spans {
                cat.push_backend(b, s, true, Box::new(arm(b))).map_err(|e| e.to_string())?;
                if g.usize_in(0, 1) == 1 {
                    cat.push_backend(b, s, false, Box::new(arm(b))).map_err(|e| e.to_string())?;
                }
            }
        }
        cat.validate().map_err(|e| e.to_string())?;
        let view = *batches.last().unwrap();
        let x: Vec<i32> = (0..view * d).map(|_| (g.rng.next_u64() % k as u64) as i32).collect();
        let slots: Vec<SlotSpan> = (0..view)
            .map(|_| SlotSpan { active: g.usize_in(0, 3) > 0, lo: g.usize_in(0, d), hi: d })
            .collect();
        let plan = PassPlan { slots, need_fore: g.usize_in(0, 1) == 1, ..Default::default() };

        // Full-shape reference over the same input, before the telemetry
        // snapshot so only the planned pass is attributed below.
        let mut full_out = StepOutput::default();
        cat.run_full(view, true, &x, &mut full_out).map_err(|e| e.to_string())?;
        let before = cat.stats();
        let mut out = StepOutput::default();
        let cost = cat.run_plan(view, true, &x, &mut out, &plan).map_err(|e| e.to_string())?;
        let after = cat.stats();

        let live: Vec<usize> = (0..view).filter(|&i| plan.slots[i].active).collect();
        let passes = |s: &predsamp::runtime::step::CatalogStats| s.variant_hits + s.full_shape_fallbacks;
        if live.is_empty() {
            prop_assert_eq!(cost, 0, "all-dead plan must be free");
            prop_assert_eq!(passes(&after), passes(&before), "all-dead plan must not count a pass");
            return Ok(());
        }
        prop_assert_eq!(passes(&after), passes(&before) + 1, "exactly one pass counted");
        prop_assert_eq!(after.positions_evaluated, before.positions_evaluated + cost as u64, "positions must equal the returned device cost");

        // Which variant served the pass (shapes histogram is ordered like
        // `variants()`), and does it cover + is it minimal?
        let sel = (0..after.shapes.len())
            .find(|&i| after.shapes[i].1 == before.shapes[i].1 + 1)
            .ok_or("no variant hit counted")?;
        let v = &cat.variants()[sel];
        let need_lo = live.iter().map(|&i| plan.slots[i].lo.min(d)).min().unwrap_or(0);
        let need = plan.need_fore && t_fore > 0;
        prop_assert!(v.batch >= live.len(), "variant b{} cannot host {} live rows", v.batch, live.len());
        prop_assert!(d - v.span <= need_lo, "span {} does not reach frontier {}", v.span, need_lo);
        if need {
            prop_assert!(v.has_fore, "fore-needing plan served by a logp-only variant");
        }
        for o in cat.variants() {
            if o.batch >= live.len() && d - o.span <= need_lo && (!need || o.has_fore) {
                let ocost = o.batch * o.span + if o.has_fore { o.batch * px * t_fore } else { 0 };
                prop_assert!(ocost >= cost, "covering variant b{}_s{} cost {} beats selected {}", o.batch, o.span, ocost, cost);
            }
        }

        // Compaction -> selected shape -> scatter must be bitwise equal to
        // the full pass on every position the plan promised.
        for &i in &live {
            let lo = plan.slots[i].lo.min(d);
            for j in lo..d {
                for cc in 0..k {
                    let at = (i * d + j) * k + cc;
                    prop_assert!(
                        out.logp[at].to_bits() == full_out.logp[at].to_bits(),
                        "slot {} pos {} cat {}: plan {} != full {}",
                        i,
                        j,
                        cc,
                        out.logp[at],
                        full_out.logp[at]
                    );
                }
            }
            if need {
                let row = px * t_fore * k;
                prop_assert_eq!(&out.fore[i * row..(i + 1) * row], &full_out.fore[i * row..(i + 1) * row], "slot {} fore row", i);
            }
        }
        Ok(())
    });
}

#[test]
fn degenerate_plans_hit_expected_shapes() {
    // A trailing-position logp-only plan picks the shortest span in its
    // cheapest flavor; a full-frontier plan falls back to the anchor.
    let d = 24;
    let arm = |b: usize| MockArm::new(b, 2, 12, 5, 1, 2.5, 9);
    let mut cat = VariantCatalog::new("degen", d, 5, 12, 1);
    for b in [1usize, 4] {
        for s in [6usize, 12, 24] {
            cat.push_backend(b, s, true, Box::new(arm(b))).unwrap();
            cat.push_backend(b, s, false, Box::new(arm(b))).unwrap();
        }
    }
    cat.validate().unwrap();
    let x = vec![0i32; 4 * d];
    let mut out = StepOutput::default();

    // Single live slot at the last position, heads unread: b1_s6_lp.
    let mut plan = PassPlan::full(4, d);
    plan.need_fore = false;
    for s in plan.slots.iter_mut().skip(1) {
        s.active = false;
    }
    plan.slots[0].lo = d - 1;
    let cost = cat.run_plan(4, true, &x, &mut out, &plan).unwrap();
    assert_eq!(cost, 6, "b1_s6_lp costs span alone");
    let st = cat.stats();
    assert_eq!(st.shapes.iter().find(|(l, _)| l == "b1_s6_lp").map(|&(_, h)| h), Some(1));
    assert_eq!((st.variant_hits, st.full_shape_fallbacks), (1, 0));

    // All slots dead: free, uncounted.
    let mut dead = PassPlan::full(4, d);
    for s in dead.slots.iter_mut() {
        s.active = false;
    }
    assert_eq!(cat.run_plan(4, true, &x, &mut out, &dead).unwrap(), 0);
    assert_eq!(cat.stats().variant_hits + cat.stats().full_shape_fallbacks, 1);

    // Full frontier with heads: the full-shape fore anchor, counted as a
    // fallback, costing B*(d + P*T).
    let full = PassPlan::full(4, d);
    let cost = cat.run_plan(4, true, &x, &mut out, &full).unwrap();
    assert_eq!(cost, 4 * (24 + 12), "full-shape anchor pays B*(d + P*T)");
    let st = cat.stats();
    assert_eq!(st.shapes.iter().find(|(l, _)| l == "b4_s24").map(|&(_, h)| h), Some(1));
    assert_eq!(st.full_shape_fallbacks, 1);
}

#[test]
fn catalog_vs_legacy_bitwise_matrix() {
    // THE catalog acceptance gate: for every exported span mix — none,
    // one short, a proper ladder, extremes, odd off-grid lengths — and
    // every sampling policy, an engine serving through the variant
    // catalog must produce bitwise-identical samples and pass counts to
    // the legacy full-shape engine over the same manifest.
    let mixes: &[&[usize]] = &[&[], &[3], &[6, 12], &[1, 23], &[5, 7, 11]];
    for (mi, spans) in mixes.iter().enumerate() {
        let dir = std::env::temp_dir().join(format!("predsamp-cat-matrix-{mi}-{}", std::process::id()));
        let mut spec = MockModelSpec::new("m", 11 + mi as u64);
        spec.spans = spans.to_vec();
        write_mock_manifest(&dir, &[spec]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        let legacy = Engine::load_with(&man, "m", false).unwrap();
        let cat = Engine::load_with(&man, "m", true).unwrap();
        assert_eq!(
            cat.catalog_stats().is_some(),
            !spans.is_empty(),
            "mix {mi}: catalog present iff span variants are exported"
        );
        let methods = [
            Method::Baseline,
            Method::Zeros,
            Method::PredictLast,
            Method::Fpi,
            Method::Forecast { t_use: 1 },
            Method::NoReparam,
        ];
        for method in methods {
            for n in [1usize, 4] {
                let a = legacy.sample_batch(method, n, 77).unwrap();
                let b = cat.sample_batch(method, n, 77).unwrap();
                assert_eq!(a.arm_calls, b.arm_calls, "mix {mi} {method:?} n={n}: pass count diverged");
                for s in 0..n {
                    assert_eq!(a.jobs[s].x, b.jobs[s].x, "mix {mi} {method:?} n={n} slot {s}: sample diverged");
                }
            }
        }
        if !spans.is_empty() {
            let st = cat.catalog_stats().unwrap();
            assert!(st.variant_hits > 0, "mix {mi}: span variants exported but never selected");
            assert!(st.positions_evaluated > 0, "mix {mi}: device cost never recorded");
        }
    }
}
