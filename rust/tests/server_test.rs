//! End-to-end serving tests. The mock-ARM tests exercise the full TCP
//! serving stack (protocol, dispatcher, sharded engine workers, batching,
//! exactness) with no compiled artifacts; the remaining tests add the
//! real-artifact path and skip when `make artifacts` hasn't run.

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client, ServerHandle};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::json::Value;
use std::time::Duration;

fn server() -> Option<ServerHandle> {
    let dir = predsamp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping server test: run `make artifacts`");
        return None;
    }
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(10),
        continuous: true,
        worker_threads: 4,
        engine_threads: 2,
    };
    Some(spawn(dir, cfg).expect("server spawns"))
}

/// Spawn a server over a two-model mock fixture (no artifacts needed).
fn spawn_mock(tag: &str, engine_threads: usize, continuous: bool) -> ServerHandle {
    let dir = std::env::temp_dir().join(format!("predsamp-server-{tag}-{}", std::process::id()));
    let mut a = MockModelSpec::new("mock_a", 11);
    a.batches = vec![1, 4];
    let mut b = MockModelSpec::new("mock_b", 7);
    b.channels = 1;
    b.pixels = 16;
    b.categories = 4;
    b.strength = 1.5;
    b.batches = vec![1, 4];
    write_mock_manifest(&dir, &[a, b]).unwrap();
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        continuous,
        worker_threads: 4,
        engine_threads,
    };
    spawn(dir, cfg).expect("mock server spawns")
}

fn samples_of(v: &Value) -> Vec<Vec<i32>> {
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v}");
    predsamp::coordinator::protocol::parse_samples(v.get("samples")).expect("samples field")
}

#[test]
fn mock_sharding_preserves_bitwise_exactness() {
    // THE acceptance gate for the worker pool: engine_threads = 1 vs 4
    // must produce bitwise-identical samples for a mixed concurrent
    // (model, method) stream — job noise is keyed (seed, job index),
    // never worker or slot.
    let collect = |tag: &str, threads: usize| -> Vec<Vec<Vec<i32>>> {
        let server = spawn_mock(tag, threads, true);
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..6u64 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
                let method = if i % 3 == 0 { "fpi" } else { "zeros" };
                let r = c
                    .call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i}}}"#))
                    .unwrap();
                samples_of(&r)
            }));
        }
        let out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        server.stop();
        out
    };
    let one = collect("exact1", 1);
    let four = collect("exact4", 4);
    assert_eq!(one, four, "samples must not depend on engine_threads");
    assert_eq!(one.len(), 6);
    assert!(one.iter().all(|s| s.len() == 3));
}

#[test]
fn sync_path_chunks_are_distinct_jobs() {
    // Regression for the duplicate-sample bug: n = 2 * batch_size on the
    // sync path used to reuse job ids 0..bs per chunk, repeating the
    // first chunk's samples verbatim.
    let server = spawn_mock("chunks", 1, false);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":8,"seed":3}"#).unwrap();
    let xs = samples_of(&r);
    assert_eq!(xs.len(), 8);
    for i in 0..xs.len() {
        for j in i + 1..xs.len() {
            assert_ne!(xs[i], xs[j], "jobs {i} and {j} identical — a chunk reused the first chunk's noise");
        }
    }
    // calls_pct is per-job normalized now: 8 jobs at bs=4 with <= d passes
    // per chunk can never exceed 100% of the baseline's d.
    let pct = r.get("calls_pct").as_f64().unwrap();
    assert!(pct > 0.0 && pct <= 100.0 + 1e-9, "calls_pct {pct} out of (0, 100]");
    server.stop();

    // Cross-path exactness: the continuous scheduler assigns the same job
    // ids 0..n, so the same request must give bitwise-equal samples.
    let server = spawn_mock("chunks2", 1, true);
    let mut c = Client::connect(&server.addr).unwrap();
    let r2 = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":8,"seed":3}"#).unwrap();
    assert_eq!(samples_of(&r2), xs, "sync chunking and continuous batching must agree bitwise");
    // Baseline (always sync, chunked) agrees too: exactness across the stack.
    let r3 = c.call(r#"{"op":"sample","model":"mock_a","method":"baseline","n":8,"seed":3}"#).unwrap();
    assert_eq!(samples_of(&r3), xs, "baseline must match predictive sampling bitwise");
    server.stop();
}

#[test]
fn mock_metrics_and_info_report_worker_pool() {
    let server = spawn_mock("metrics", 3, true);
    let mut c = Client::connect(&server.addr).unwrap();
    for seed in 0..3 {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":4,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let info = c.call(r#"{"op":"info"}"#).unwrap();
    assert_eq!(info.get("engine_workers").as_i64(), Some(3));
    assert_eq!(info.get("workers").as_arr().unwrap().len(), 3);
    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    let metrics = m.get("metrics");
    assert_eq!(metrics.get("engine_workers").as_i64(), Some(3));
    assert!(metrics.get("requests").as_i64().unwrap() >= 4);
    assert_eq!(metrics.get("samples").as_i64(), Some(12));
    let workers = metrics.get("workers").as_arr().unwrap();
    assert_eq!(workers.len(), 3);
    for w in workers {
        assert!(w.get("queue_depth").as_i64().unwrap() >= 0);
        let occ = w.get("occupancy").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    }
    // All batches landed somewhere, and the sum matches the aggregate.
    let batch_sum: i64 = workers.iter().map(|w| w.get("batches").as_i64().unwrap()).sum();
    assert_eq!(batch_sum, metrics.get("batches").as_i64().unwrap());
    server.stop();
}

#[test]
fn mock_eval_errors_cleanly_and_server_survives() {
    // Mock models have no test set: eval must error without wedging the
    // worker, and unknown models must error per-request.
    let server = spawn_mock("evalerr", 2, true);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"eval","model":"mock_a"}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    let r = c.call(r#"{"op":"sample","model":"no_such_model"}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    // The pool still serves after the errors.
    let r = c.call(r#"{"op":"sample","model":"mock_b","method":"fpi","n":2,"seed":0}"#).unwrap();
    assert_eq!(samples_of(&r).len(), 2);
    server.stop();
}

#[test]
fn ping_info_metrics_eval() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    let info = c.call(r#"{"op":"info"}"#).unwrap();
    let models = info.get("models").as_arr().unwrap();
    assert!(models.iter().any(|m| m.get("name").as_str() == Some("mnist_bin")));

    let ev = c.call(r#"{"op":"eval","model":"mnist_bin"}"#).unwrap();
    assert_eq!(ev.get("ok").as_bool(), Some(true));
    assert!(ev.get("bpd").as_f64().unwrap() > 0.0);

    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("requests").as_i64().unwrap() >= 3);
    server.stop();
}

#[test]
fn sample_request_roundtrip_and_exactness() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r1 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":4}"#)
        .unwrap();
    assert_eq!(r1.get("ok").as_bool(), Some(true), "{r1}");
    let s1 = predsamp::coordinator::protocol::parse_samples(r1.get("samples")).unwrap();
    assert_eq!(s1.len(), 2);
    assert_eq!(s1[0].len(), 256);

    // Baseline through the server must give the same samples (exactness
    // survives the whole serving stack).
    let r2 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"baseline","n":2,"seed":4}"#)
        .unwrap();
    let s2 = predsamp::coordinator::protocol::parse_samples(r2.get("samples")).unwrap();
    assert_eq!(s1, s2, "serving stack must preserve exactness");
    // And predictive sampling must have used fewer calls.
    assert!(r1.get("arm_calls").as_f64().unwrap() < r2.get("arm_calls").as_f64().unwrap());
    server.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(server) = server() else { return };
    let addr = server.addr;
    let mut handles = Vec::new();
    for i in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let r = c
                .call(&format!(
                    r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i},"return_samples":true}}"#
                ))
                .unwrap();
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            let s = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
            assert_eq!(s.len(), 2);
            (i, s)
        }));
    }
    let mut results: Vec<(i32, Vec<Vec<i32>>)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|(i, _)| *i);
    // Same seed ⇒ same samples regardless of how requests were merged:
    let mut c = Client::connect(&addr).unwrap();
    for (i, s) in &results {
        let r = c
            .call(&format!(
                r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i}}}"#
            ))
            .unwrap();
        let again = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
        assert_eq!(&again, s, "client {i} samples must be reproducible");
    }
    server.stop();
}

#[test]
fn decode_through_server() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c
        .call(r#"{"op":"sample","model":"latent_cifar","method":"fpi","n":1,"seed":0,"return_samples":false,"decode":true}"#)
        .unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    let imgs = r.get("images").as_arr().unwrap();
    assert_eq!(imgs.len(), 1);
    assert_eq!(imgs[0].as_arr().unwrap().len(), 3 * 16 * 16);
    server.stop();
}

#[test]
fn malformed_requests_get_errors() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    for bad in [
        "this is not json",
        r#"{"op":"sample"}"#,
        r#"{"op":"sample","model":"no_such_model"}"#,
        r#"{"op":"bogus"}"#,
    ] {
        let r = c.call(bad).unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(false), "{bad} -> {r}");
        assert!(matches!(r.get("error"), Value::Str(_)));
    }
    // connection still usable afterwards
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    server.stop();
}
