//! End-to-end serving tests. The mock-ARM tests exercise the full TCP
//! serving stack (protocol, dispatcher, sharded engine workers, batching,
//! exactness) with no compiled artifacts; the remaining tests add the
//! real-artifact path and skip when `make artifacts` hasn't run.

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::federation::{spawn_router, RouterConfig, RouterHandle};
use predsamp::coordinator::placement::PlacementKind;
use predsamp::coordinator::policy::{AdmissionKind, PolicyKind};
use predsamp::coordinator::server::{spawn, Client, ServerHandle};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::json::Value;
use predsamp::substrate::readiness::ReadinessKind;
use std::time::Duration;

fn server() -> Option<ServerHandle> {
    let dir = predsamp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping server test: run `make artifacts`");
        return None;
    }
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(10),
        continuous: true,
        elastic: true,
        steal: true,
        engine_threads: 2,
        ..ServeConfig::default()
    };
    Some(spawn(dir, cfg).expect("server spawns"))
}

/// Spawn a server over the shared two-model mock fixture (no artifacts
/// needed) with an arbitrary config; every mock server in this file
/// serves the same model family so the tests stay comparable.
fn spawn_mock_with(tag: &str, cfg: ServeConfig) -> ServerHandle {
    let dir = std::env::temp_dir().join(format!("predsamp-server-{tag}-{}", std::process::id()));
    let mut a = MockModelSpec::new("mock_a", 11);
    a.batches = vec![1, 4];
    let mut b = MockModelSpec::new("mock_b", 7);
    b.channels = 1;
    b.pixels = 16;
    b.categories = 4;
    b.strength = 1.5;
    b.batches = vec![1, 4];
    write_mock_manifest(&dir, &[a, b]).unwrap();
    spawn(dir, cfg).expect("mock server spawns")
}

fn spawn_mock_cfg(tag: &str, engine_threads: usize, continuous: bool, elastic: bool, steal: bool, max_wait: Duration) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait,
        continuous,
        elastic,
        steal,
        engine_threads,
        ..ServeConfig::default()
    };
    spawn_mock_with(tag, cfg)
}

fn spawn_mock(tag: &str, engine_threads: usize, continuous: bool) -> ServerHandle {
    spawn_mock_cfg(tag, engine_threads, continuous, true, true, Duration::from_millis(5))
}

/// As [`spawn_mock`], overriding the scheduling-policy knobs.
fn spawn_mock_policy(tag: &str, policy: PolicyKind, admission: AdmissionKind) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(20),
        engine_threads: 2,
        policy,
        admission,
        slo: Duration::from_millis(10),
        ..ServeConfig::default()
    };
    spawn_mock_with(tag, cfg)
}

fn samples_of(v: &Value) -> Vec<Vec<i32>> {
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v}");
    predsamp::coordinator::protocol::parse_samples(v.get("samples")).expect("samples field")
}

/// Front `server` with a single-backend federation router. The routed
/// tier re-stripes upstream ids and proxies streams and frames, and the
/// edge-behavior tests below must not be able to tell the difference.
fn via_router(server: &ServerHandle) -> RouterHandle {
    via_router_cfg(server, |_| {})
}

/// As [`via_router`], letting the test adjust the router's edge knobs.
fn via_router_cfg(server: &ServerHandle, tweak: impl FnOnce(&mut RouterConfig)) -> RouterHandle {
    let mut cfg = RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![server.addr.to_string()],
        probe_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    };
    tweak(&mut cfg);
    spawn_router(cfg).expect("router spawns")
}

#[test]
fn mock_sharding_preserves_bitwise_exactness() {
    // THE acceptance gate for the worker pool: engine_threads = 1 vs 4
    // must produce bitwise-identical samples for a mixed concurrent
    // (model, method) stream — job noise is keyed (seed, job index),
    // never worker or slot.
    let collect = |tag: &str, threads: usize| -> Vec<Vec<Vec<i32>>> {
        let server = spawn_mock(tag, threads, true);
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..6u64 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
                let method = if i % 3 == 0 { "fpi" } else { "zeros" };
                let r = c
                    .call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i}}}"#))
                    .unwrap();
                samples_of(&r)
            }));
        }
        let out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        server.stop();
        out
    };
    let one = collect("exact1", 1);
    let four = collect("exact4", 4);
    assert_eq!(one, four, "samples must not depend on engine_threads");
    assert_eq!(one.len(), 6);
    assert!(one.iter().all(|s| s.len() == 3));
}

#[test]
fn sync_path_chunks_are_distinct_jobs() {
    // Regression for the duplicate-sample bug: n = 2 * batch_size on the
    // sync path used to reuse job ids 0..bs per chunk, repeating the
    // first chunk's samples verbatim.
    let server = spawn_mock("chunks", 1, false);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":8,"seed":3}"#).unwrap();
    let xs = samples_of(&r);
    assert_eq!(xs.len(), 8);
    for i in 0..xs.len() {
        for j in i + 1..xs.len() {
            assert_ne!(xs[i], xs[j], "jobs {i} and {j} identical — a chunk reused the first chunk's noise");
        }
    }
    // calls_pct is per-job normalized now: 8 jobs at bs=4 with <= d passes
    // per chunk can never exceed 100% of the baseline's d.
    let pct = r.get("calls_pct").as_f64().unwrap();
    assert!(pct > 0.0 && pct <= 100.0 + 1e-9, "calls_pct {pct} out of (0, 100]");
    server.stop();

    // Cross-path exactness: the continuous scheduler assigns the same job
    // ids 0..n, so the same request must give bitwise-equal samples.
    let server = spawn_mock("chunks2", 1, true);
    let mut c = Client::connect(&server.addr).unwrap();
    let r2 = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":8,"seed":3}"#).unwrap();
    assert_eq!(samples_of(&r2), xs, "sync chunking and continuous batching must agree bitwise");
    // Baseline (always sync, chunked) agrees too: exactness across the stack.
    let r3 = c.call(r#"{"op":"sample","model":"mock_a","method":"baseline","n":8,"seed":3}"#).unwrap();
    assert_eq!(samples_of(&r3), xs, "baseline must match predictive sampling bitwise");
    server.stop();
}

#[test]
fn mock_metrics_and_info_report_worker_pool() {
    let server = spawn_mock("metrics", 3, true);
    let mut c = Client::connect(&server.addr).unwrap();
    for seed in 0..3 {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":4,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let info = c.call(r#"{"op":"info"}"#).unwrap();
    assert_eq!(info.get("engine_workers").as_i64(), Some(3));
    assert_eq!(info.get("workers").as_arr().unwrap().len(), 3);
    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    let metrics = m.get("metrics");
    assert_eq!(metrics.get("engine_workers").as_i64(), Some(3));
    assert!(metrics.get("requests").as_i64().unwrap() >= 4);
    assert_eq!(metrics.get("samples").as_i64(), Some(12));
    let workers = metrics.get("workers").as_arr().unwrap();
    assert_eq!(workers.len(), 3);
    for w in workers {
        assert!(w.get("queue_depth").as_i64().unwrap() >= 0);
        let occ = w.get("occupancy").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    }
    // All batches landed somewhere, and the sum matches the aggregate.
    let batch_sum: i64 = workers.iter().map(|w| w.get("batches").as_i64().unwrap()).sum();
    assert_eq!(batch_sum, metrics.get("batches").as_i64().unwrap());
    server.stop();
}

#[test]
fn metrics_aggregate_sums_age_buckets_and_policy_counters() {
    // The aggregation invariant for the new policy gauges: the top-level
    // `metrics` response must equal the element-wise sum of the
    // per-worker age histograms (every request sampled exactly once, at
    // window close or mid-flight absorption — wherever its group ended
    // up after routing and stealing), and the per-policy schedule
    // counters must cover every executed batch.
    let server = spawn_mock("agebuckets", 2, true);
    let mut c = Client::connect(&server.addr).unwrap();
    let n_requests = 6;
    for i in 0..n_requests {
        let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"{model}","method":"fpi","n":2,"seed":{i},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    let metrics = m.get("metrics");
    let agg: Vec<i64> = metrics.get("admission_age_buckets").as_arr().unwrap().iter().map(|v| v.as_i64().unwrap()).collect();
    assert_eq!(
        agg.len(),
        metrics.get("admission_age_bounds_ms").as_arr().unwrap().len() + 1,
        "one bucket per bound plus the overflow"
    );
    let workers = metrics.get("workers").as_arr().unwrap();
    let mut summed = vec![0i64; agg.len()];
    for w in workers {
        let wb = w.get("admission_age_buckets").as_arr().unwrap();
        assert_eq!(wb.len(), agg.len());
        for (s, v) in summed.iter_mut().zip(wb) {
            *s += v.as_i64().unwrap();
        }
    }
    assert_eq!(summed, agg, "aggregate age histogram must equal the per-worker sums");
    assert_eq!(agg.iter().sum::<i64>(), n_requests, "every sample request is aged exactly once");
    // Elastic continuous serving sizes with the default occupancy-first
    // policy; the per-policy counters must cover every executed batch.
    let by_policy = metrics.get("schedules_by_policy");
    let occ = by_policy.get("occupancy").as_i64().unwrap_or(0);
    assert!(occ >= 1, "elastic schedules must be counted under their sizing policy: {m}");
    let batches = metrics.get("batches").as_i64().unwrap();
    assert_eq!(occ, batches, "every batch ran under the occupancy policy on this server");
    server.stop();
}

#[test]
fn sizing_policy_and_admission_choices_preserve_bitwise_exactness() {
    // Policy-subsystem acceptance at the serving layer: the same
    // staggered mixed stream served under occupancy-first, latency-lean,
    // SLO-hybrid sizing, and the legacy absorb-budget admission must
    // produce bitwise-identical samples — policies move work, never
    // samples.
    let collect = |tag: &str, policy: PolicyKind, admission: AdmissionKind| -> Vec<Vec<Vec<i32>>> {
        let server = spawn_mock_policy(tag, policy, admission);
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..8u64 {
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i * 5));
                let mut c = Client::connect(&addr).unwrap();
                let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
                let method = if i % 3 == 0 { "fpi" } else { "zeros" };
                let r = c
                    .call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i}}}"#))
                    .unwrap();
                samples_of(&r)
            }));
        }
        let out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        server.stop();
        out
    };
    let occ = collect("pol-occ", PolicyKind::Occupancy, AdmissionKind::OldestFirst);
    let fit = collect("pol-fit", PolicyKind::Latency, AdmissionKind::OldestFirst);
    let slo = collect("pol-slo", PolicyKind::Slo, AdmissionKind::OldestFirst);
    let budget = collect("pol-budget", PolicyKind::Occupancy, AdmissionKind::Budget(64));
    assert_eq!(occ, fit, "sizing policy must not change any sample");
    assert_eq!(occ, slo, "SLO sizing must not change any sample");
    assert_eq!(occ, budget, "admission policy must not change any sample");
    assert!(occ.iter().all(|s| s.len() == 3));
}

/// As [`spawn_mock`], overriding the placement policy.
fn spawn_mock_placement(tag: &str, engine_threads: usize, placement: PlacementKind) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        engine_threads,
        placement,
        ..ServeConfig::default()
    };
    spawn_mock_with(tag, cfg)
}

/// Poll the `metrics` op until `pred` holds (worker gauges are published
/// after a worker's turn ends, so they can lag the reply by a beat).
/// Returns the last metrics object either way; the caller asserts on it.
fn metrics_eventually(c: &mut Client, pred: impl Fn(&Value) -> bool) -> Value {
    let mut m = c.call(r#"{"op":"metrics"}"#).unwrap();
    for _ in 0..100 {
        if pred(m.get("metrics")) {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
        m = c.call(r#"{"op":"metrics"}"#).unwrap();
    }
    m
}

fn worker_resident(metrics: &Value, w: usize) -> Vec<String> {
    metrics.get("workers").as_arr().unwrap()[w]
        .get("resident_models")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect()
}

fn pin_ab() -> PlacementKind {
    PlacementKind::Pinned(vec![("mock_a".to_string(), vec![0]), ("mock_b".to_string(), vec![1])])
}

#[test]
fn placement_policies_preserve_bitwise_exactness() {
    // THE placement acceptance gate: the same staggered mixed stream
    // served under replicate-all, per-model pinning, and a capacity cap
    // of one engine per worker must produce bitwise-identical samples —
    // placement moves groups between workers (and evicts engines), never
    // samples.
    let collect = |tag: &str, placement: PlacementKind| -> Vec<Vec<Vec<i32>>> {
        let server = spawn_mock_placement(tag, 2, placement);
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..8u64 {
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i * 5));
                let mut c = Client::connect(&addr).unwrap();
                let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
                let method = if i % 3 == 0 { "fpi" } else { "zeros" };
                let r = c
                    .call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i}}}"#))
                    .unwrap();
                samples_of(&r)
            }));
        }
        let out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        server.stop();
        out
    };
    let replicated = collect("place-rep", PlacementKind::ReplicateAll);
    let pinned = collect("place-pin", pin_ab());
    let capped = collect("place-cap", PlacementKind::CapacityCapped(1));
    assert_eq!(replicated, pinned, "pinning must not change any sample");
    assert_eq!(replicated, capped, "capacity capping must not change any sample");
    assert!(replicated.iter().all(|s| s.len() == 3));
}

#[test]
fn pinned_models_stay_on_their_workers() {
    // Pin mock_a to worker 0 and mock_b to worker 1: after serving both,
    // each engine must be resident only on its pinned worker, exactly
    // one lazy load each — the placement plane's whole point.
    let server = spawn_mock_placement("pin-resident", 2, pin_ab());
    let mut c = Client::connect(&server.addr).unwrap();
    for (model, seed) in [("mock_a", 0), ("mock_b", 1), ("mock_a", 2), ("mock_b", 3)] {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"{model}","method":"fpi","n":2,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let m = metrics_eventually(&mut c, |m| {
        worker_resident(m, 0) == vec!["mock_a".to_string()] && worker_resident(m, 1) == vec!["mock_b".to_string()]
    });
    let metrics = m.get("metrics");
    assert_eq!(metrics.get("placement").as_str(), Some("pinned"));
    assert_eq!(worker_resident(metrics, 0), vec!["mock_a".to_string()], "mock_a must live only on its pinned worker: {m}");
    assert_eq!(worker_resident(metrics, 1), vec!["mock_b".to_string()], "mock_b must live only on its pinned worker: {m}");
    assert_eq!(metrics.get("engine_loads").as_i64(), Some(2), "one lazy load per pinned model, ever");
    assert_eq!(metrics.get("evictions").as_i64(), Some(0));
    server.stop();
}

#[test]
fn eval_routes_to_eligible_worker_under_pinning() {
    // Regression: evals used to assume any worker owns a full Router.
    // With mock_a pinned to worker 1, an eval of mock_a must execute on
    // worker 1 (loading its engine there) — worker 0 must never touch
    // it. The eval itself errors (mock models have no test set), which
    // is exactly why residency is the observable: the engine loads
    // before the bpd pass fails.
    let placement = PlacementKind::Pinned(vec![("mock_a".to_string(), vec![1])]);
    let server = spawn_mock_placement("pin-eval", 2, placement);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"eval","model":"mock_a"}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "mock eval still errors: {r}");
    let m = metrics_eventually(&mut c, |m| worker_resident(m, 1).contains(&"mock_a".to_string()));
    let metrics = m.get("metrics");
    assert!(worker_resident(metrics, 1).contains(&"mock_a".to_string()), "the eval must have run on the pinned worker: {m}");
    assert!(worker_resident(metrics, 0).is_empty(), "the ineligible worker must never load the pinned engine: {m}");
    server.stop();
}

#[test]
fn capacity_cap_evicts_lru_and_reports() {
    // One worker, one-engine budget, alternating models: every model
    // switch must evict the previous engine (LRU) and reload on return,
    // with the `evictions`/`engine_loads` gauges telling the story.
    let server = spawn_mock_placement("cap-evict", 1, PlacementKind::CapacityCapped(1));
    let mut c = Client::connect(&server.addr).unwrap();
    for (model, seed) in [("mock_a", 0), ("mock_b", 1), ("mock_a", 2)] {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"{model}","method":"fpi","n":2,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let m = metrics_eventually(&mut c, |m| m.get("evictions").as_i64().unwrap_or(0) >= 2);
    let metrics = m.get("metrics");
    assert_eq!(metrics.get("placement").as_str(), Some("capped"));
    assert_eq!(metrics.get("evictions").as_i64(), Some(2), "a→b and b→a each evict once: {m}");
    assert_eq!(metrics.get("engine_loads").as_i64(), Some(3), "two loads plus one post-eviction reload: {m}");
    assert_eq!(worker_resident(metrics, 0), vec!["mock_a".to_string()], "only the engine budget stays resident: {m}");
    server.stop();
}

#[test]
fn convergence_history_reported_and_warms() {
    // The server-level estimator must accumulate per-(model, method)
    // history across schedules and expose it through `metrics` — the
    // observable end of the cold-start seeding path.
    let server = spawn_mock("convergence", 1, true);
    let mut c = Client::connect(&server.addr).unwrap();
    for seed in 0..3 {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":2,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let m = metrics_eventually(&mut c, |m| m.get("convergence").get("mock_a/fpi").as_obj().is_some());
    let entry = m.get("metrics").get("convergence").get("mock_a/fpi");
    assert!(entry.as_obj().is_some(), "fpi schedules must be observed into the book: {m}");
    let ppj = entry.get("passes_per_job").as_f64().unwrap();
    assert!(ppj > 0.0, "passes/job estimate must be positive: {ppj}");
    assert!(entry.get("pass_secs").as_f64().unwrap() > 0.0);
    assert!(entry.get("schedules").as_i64().unwrap() >= 1);
    server.stop();
}

#[test]
fn mock_eval_errors_cleanly_and_server_survives() {
    // Mock models have no test set: eval must error without wedging the
    // worker, and unknown models must error per-request.
    let server = spawn_mock("evalerr", 2, true);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"eval","model":"mock_a"}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    let r = c.call(r#"{"op":"sample","model":"no_such_model"}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    // The pool still serves after the errors.
    let r = c.call(r#"{"op":"sample","model":"mock_b","method":"fpi","n":2,"seed":0}"#).unwrap();
    assert_eq!(samples_of(&r).len(), 2);
    server.stop();
}

#[test]
fn elasticity_and_stealing_preserve_bitwise_exactness() {
    // THE elastic acceptance gate at the serving layer: the same staggered
    // mixed stream with live-queue elasticity + group stealing on vs off
    // must produce bitwise-identical samples — arrival time, absorption
    // into a running schedule, and group migration must all be invisible.
    let collect = |tag: &str, elastic: bool, steal: bool| -> Vec<Vec<Vec<i32>>> {
        let server = spawn_mock_cfg(tag, 3, true, elastic, steal, Duration::from_millis(30));
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..8u64 {
            handles.push(std::thread::spawn(move || {
                // Stagger arrivals so some requests land while their group
                // is already queued or executing.
                std::thread::sleep(Duration::from_millis(i * 7));
                let mut c = Client::connect(&addr).unwrap();
                let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
                let method = if i % 3 == 0 { "fpi" } else { "zeros" };
                let r = c
                    .call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i}}}"#))
                    .unwrap();
                samples_of(&r)
            }));
        }
        let out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        server.stop();
        out
    };
    let on = collect("elastic-on", true, true);
    let off = collect("elastic-off", false, false);
    assert_eq!(on, off, "elasticity/stealing must not change any sample");
    assert!(on.iter().all(|s| s.len() == 3));
}

#[test]
fn stashed_group_executes_within_its_own_window() {
    // Regression for the k×max_wait latency bug: a request queued behind
    // another group's batching window used to re-pay a full max_wait from
    // the moment the worker got to it. Windows are now sized off each
    // request's admission time, so group B executes as soon as the worker
    // frees up (its window already elapsed while queued).
    let wait = Duration::from_millis(200);
    let server = spawn_mock_cfg("stash-latency", 1, true, true, true, wait);
    let addr = server.addr;
    let a = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":2,"seed":1,"return_samples":false}"#).unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    });
    // Let A's window open first, then queue B behind it.
    std::thread::sleep(Duration::from_millis(40));
    let mut c = Client::connect(&server.addr).unwrap();
    let t = std::time::Instant::now();
    let r = c.call(r#"{"op":"sample","model":"mock_b","method":"fpi","n":1,"seed":2,"return_samples":false}"#).unwrap();
    let b_latency = t.elapsed();
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    a.join().unwrap();
    server.stop();
    // New behavior: ~max_wait (B's own window, mostly elapsed while queued
    // behind A). Old behavior: A's window remainder + a *fresh* max_wait
    // ≈ 360ms+. The bound sits between the two with slack for CI jitter.
    assert!(
        b_latency < wait + Duration::from_millis(100),
        "request stashed behind another group took {b_latency:?} — re-paying the batching window (max_wait = {wait:?})"
    );
}

#[test]
fn idle_tiebreak_spreads_lazy_engine_loads() {
    // Regression for least-loaded ties resolving to worker 0: on an idle
    // 2-worker server, two sequential single-model bursts must land on
    // *different* workers (ties break to the fewest loaded engines, then
    // round-robin), so lazy engine loads stop serializing on worker 0.
    let server = spawn_mock("tiebreak", 2, true);
    let mut c = Client::connect(&server.addr).unwrap();
    for (model, seed) in [("mock_a", 0), ("mock_b", 1)] {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"{model}","method":"fpi","n":2,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    // Gauges are stored after the worker's turn ends; give them a beat.
    std::thread::sleep(Duration::from_millis(100));
    let info = c.call(r#"{"op":"info"}"#).unwrap();
    let workers = info.get("workers").as_arr().unwrap();
    let loaded: Vec<i64> = workers.iter().map(|w| w.get("engines_loaded").as_i64().unwrap()).collect();
    assert_eq!(loaded.iter().sum::<i64>(), 2, "two engines loaded in total: {loaded:?}");
    assert!(loaded.iter().all(|&l| l == 1), "idle-server groups must spread across workers, got {loaded:?}");
    server.stop();
}

#[test]
fn client_call_reports_server_eof() {
    // A server that hangs up must surface as a clear error, not JSON
    // parse noise over an empty string.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let peer = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        // Consume the request line, then close without replying.
        let mut line = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(stream), &mut line).unwrap();
    });
    let mut c = Client::connect(&addr).unwrap();
    let err = c.call(r#"{"op":"ping"}"#).expect_err("EOF must be an error").to_string();
    peer.join().unwrap();
    assert!(err.contains("connection closed by server"), "unhelpful EOF error: {err}");
}

#[test]
fn ping_info_metrics_eval() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    let info = c.call(r#"{"op":"info"}"#).unwrap();
    let models = info.get("models").as_arr().unwrap();
    assert!(models.iter().any(|m| m.get("name").as_str() == Some("mnist_bin")));

    let ev = c.call(r#"{"op":"eval","model":"mnist_bin"}"#).unwrap();
    assert_eq!(ev.get("ok").as_bool(), Some(true));
    assert!(ev.get("bpd").as_f64().unwrap() > 0.0);

    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("requests").as_i64().unwrap() >= 3);
    server.stop();
}

#[test]
fn sample_request_roundtrip_and_exactness() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r1 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":4}"#)
        .unwrap();
    assert_eq!(r1.get("ok").as_bool(), Some(true), "{r1}");
    let s1 = predsamp::coordinator::protocol::parse_samples(r1.get("samples")).unwrap();
    assert_eq!(s1.len(), 2);
    assert_eq!(s1[0].len(), 256);

    // Baseline through the server must give the same samples (exactness
    // survives the whole serving stack).
    let r2 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"baseline","n":2,"seed":4}"#)
        .unwrap();
    let s2 = predsamp::coordinator::protocol::parse_samples(r2.get("samples")).unwrap();
    assert_eq!(s1, s2, "serving stack must preserve exactness");
    // And predictive sampling must have used fewer calls.
    assert!(r1.get("arm_calls").as_f64().unwrap() < r2.get("arm_calls").as_f64().unwrap());
    server.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(server) = server() else { return };
    let addr = server.addr;
    let mut handles = Vec::new();
    for i in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let r = c
                .call(&format!(
                    r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i},"return_samples":true}}"#
                ))
                .unwrap();
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            let s = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
            assert_eq!(s.len(), 2);
            (i, s)
        }));
    }
    let mut results: Vec<(i32, Vec<Vec<i32>>)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|(i, _)| *i);
    // Same seed ⇒ same samples regardless of how requests were merged:
    let mut c = Client::connect(&addr).unwrap();
    for (i, s) in &results {
        let r = c
            .call(&format!(
                r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i}}}"#
            ))
            .unwrap();
        let again = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
        assert_eq!(&again, s, "client {i} samples must be reproducible");
    }
    server.stop();
}

#[test]
fn decode_through_server() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c
        .call(r#"{"op":"sample","model":"latent_cifar","method":"fpi","n":1,"seed":0,"return_samples":false,"decode":true}"#)
        .unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    let imgs = r.get("images").as_arr().unwrap();
    assert_eq!(imgs.len(), 1);
    assert_eq!(imgs[0].as_arr().unwrap().len(), 3 * 16 * 16);
    server.stop();
}

/// Decode the sample row carried by one streamed per-job event. Framed
/// events look identical here: the client already spliced the binary row
/// back in as `"sample"`.
fn event_row(ev: &Value) -> Vec<i32> {
    let row = ev.get("sample").as_arr().expect("stream event carries its sample row");
    row.iter().map(|v| v.as_i64().unwrap() as i32).collect()
}

#[test]
fn slow_loris_trickle_does_not_stall_other_connections() {
    // One peer dribbles a request a byte at a time. On the old blocking
    // edge this pinned a connection thread; on the event loop it must not
    // delay anyone else, and the request still completes once the line
    // finally terminates.
    let server = spawn_mock("loris", 2, true);
    let addr = server.addr;
    let loris = std::thread::spawn(move || {
        use std::io::Write;
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        for &b in br#"{"op":"ping","id":7}"#.iter() {
            s.write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(4));
        }
        s.write_all(b"\n").unwrap();
        let mut resp = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(s), &mut resp).unwrap();
        resp
    });
    // While the trickle is in flight (~80 ms), a healthy connection keeps
    // getting served end to end.
    let mut c = Client::connect(&server.addr).unwrap();
    for seed in 0..5 {
        let r = c
            .call(&format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":1,"seed":{seed},"return_samples":false}}"#))
            .unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    }
    let resp = loris.join().unwrap();
    let v = predsamp::substrate::json::parse(resp.trim()).unwrap();
    assert_eq!(v.get("pong").as_bool(), Some(true), "the dribbled request must still complete: {v}");
    assert_eq!(v.get("id").as_i64(), Some(7));
    // A partial line followed by EOF is *not* a request: the server drops
    // it and closes without replying.
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    std::io::Write::write_all(&mut s, br#"{"op":"ping","#).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut rest = Vec::new();
    std::io::Read::read_to_end(&mut s, &mut rest).unwrap();
    assert!(rest.is_empty(), "a truncated trailing line must be dropped, got {:?}", String::from_utf8_lossy(&rest));
    server.stop();
}

#[test]
fn pipelined_requests_are_matched_by_id() {
    // Several requests on one connection before reading any reply:
    // replies may complete in any order (different models and engine
    // queues), and the `id` echo is what lets the client pair them up.
    // The same contract holds one tier up, through a federation router —
    // the router re-stripes its upstream ids and splices the client's
    // back on, and pipelined out-of-order completion must survive that.
    let server = spawn_mock("pipeline", 2, true);
    let router = via_router(&server);
    let req = |i: u64| {
        let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
        let method = if i % 3 == 0 { "fpi" } else { "zeros" };
        format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":2,"seed":{i},"id":{i}}}"#)
    };
    for (tier, addr) in [("direct", server.addr), ("routed", router.addr)] {
        let mut c = Client::connect(&addr).unwrap();
        for i in 0..6 {
            c.send_line(&req(i)).unwrap();
        }
        let mut by_id = std::collections::HashMap::new();
        for _ in 0..6 {
            let r = c.read_message().unwrap();
            let id = r.get("id").as_i64().expect("every pipelined reply must echo its request id");
            assert!(by_id.insert(id, samples_of(&r)).is_none(), "{tier}: duplicate reply for id {id}");
        }
        // The same requests issued one at a time must agree bitwise: the
        // pipelined path moves replies, never samples.
        let mut seq = Client::connect(&server.addr).unwrap();
        for i in 0..6u64 {
            let reference = samples_of(&seq.call(&req(i)).unwrap());
            assert_eq!(by_id[&(i as i64)], reference, "{tier}: pipelined reply {i} diverged from the sequential path");
        }
    }
    router.stop();
    server.stop();
}

#[test]
fn backpressured_connection_does_not_stall_others() {
    // A reader that drains nothing while piling up large replies trips
    // the outbound cap: the event loop stops *reading* that connection
    // instead of buffering without bound — and every other connection
    // keeps being served in the meantime.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        continuous: true,
        elastic: true,
        steal: true,
        engine_threads: 2,
        outbound_cap: 4096,
        ..ServeConfig::default()
    };
    let server = spawn_mock_with("backpressure", cfg);
    let req = |i: usize| format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":8,"seed":{i},"id":{i}}}"#);
    let mut slow = Client::connect(&server.addr).unwrap();
    for i in 0..10 {
        slow.send_line(&req(i)).unwrap();
    }
    // The same calls from a second connection complete while the slow
    // reader sits on its replies — the liveness proof and the bitwise
    // reference in one.
    let mut fast = Client::connect(&server.addr).unwrap();
    let reference: Vec<_> = (0..10).map(|i| samples_of(&fast.call(&req(i)).unwrap())).collect();
    let mut by_id = std::collections::HashMap::new();
    for _ in 0..10 {
        let r = slow.read_message().unwrap();
        by_id.insert(r.get("id").as_i64().unwrap(), samples_of(&r));
    }
    for (i, want) in reference.iter().enumerate() {
        assert_eq!(&by_id[&(i as i64)], want, "backpressured reply {i} diverged");
    }
    server.stop();
}

#[test]
fn many_concurrent_connections_match_sequential_bitwise() {
    // The many-connections acceptance gate, run over the full readiness ×
    // sharding matrix: 256 concurrent clients, mixing plain, streamed,
    // and framed delivery, must be bitwise-identical to the same requests
    // issued one at a time over one connection — under every supported
    // readiness backend and under both 1 and 4 connection shards.
    const N: usize = 256;
    let req = |i: usize| {
        let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
        let method = if i % 3 == 0 { "fpi" } else { "zeros" };
        let opt = match i % 3 {
            1 => r#","stream":true"#,
            2 => r#","frame":true"#,
            _ => "",
        };
        format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":2,"seed":{i},"id":{i}{opt}}}"#)
    };
    let mut reference: Option<Vec<Vec<Vec<i32>>>> = None;
    for kind in [ReadinessKind::Scan, ReadinessKind::Epoll] {
        if !kind.supported() {
            continue;
        }
        for conn_threads in [1usize, 4] {
            let combo = format!("{}x{conn_threads}", kind.label());
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                max_batch: 16,
                max_wait: Duration::from_millis(2),
                continuous: true,
                elastic: true,
                steal: true,
                engine_threads: 2,
                conn_threads,
                readiness: kind,
                ..ServeConfig::default()
            };
            let server = spawn_mock_with(&format!("many-{combo}"), cfg);
            let mut clients: Vec<Client> = (0..N).map(|_| Client::connect(&server.addr).unwrap()).collect();
            for (i, c) in clients.iter_mut().enumerate() {
                c.send_line(&req(i)).unwrap();
            }
            let mut finals = Vec::with_capacity(N);
            for (i, c) in clients.iter_mut().enumerate() {
                loop {
                    let m = c.read_message().unwrap();
                    if m.get("stream").as_bool() == Some(true) {
                        continue;
                    }
                    assert_eq!(m.get("id").as_i64(), Some(i as i64), "[{combo}] reply routed to the wrong connection: {m}");
                    finals.push(samples_of(&m));
                    break;
                }
            }
            drop(clients);
            let mut c = Client::connect(&server.addr).unwrap();
            // The sequential reference is computed once (first combo) and
            // shared: every backend/shard topology must agree with it.
            let reference = reference.get_or_insert_with(|| (0..N).map(|i| samples_of(&c.call(&req(i)).unwrap())).collect());
            for (i, got) in finals.iter().enumerate() {
                assert_eq!(got, &reference[i], "[{combo}] connection {i} samples diverged from the sequential path");
            }
            let m = c.call(r#"{"op":"metrics"}"#).unwrap();
            let edge = m.get("metrics").get("edge");
            assert_eq!(edge.get("readiness").as_str(), Some(kind.label()), "{m}");
            assert_eq!(edge.get("conn_threads").as_i64(), Some(conn_threads as i64), "{m}");
            assert_eq!(edge.get("shards").as_arr().unwrap().len(), conn_threads, "{m}");
            assert!(edge.get("total_conns").as_i64().unwrap() >= (N as i64) + 1, "{m}");
            assert!(edge.get("bytes_in").as_i64().unwrap() > 0 && edge.get("bytes_out").as_i64().unwrap() > 0, "{m}");
            server.stop();
        }
    }
    assert!(reference.is_some(), "at least the scan backend must have run");
}

#[test]
fn crlf_terminated_requests_are_served() {
    // Windows-style line endings: a `\r\n`-terminated request must parse
    // exactly like its `\n` twin — the edge strips the `\r` before the
    // JSON parser ever sees it. The router's edge is the same connection
    // plane, so the routed tier gets the identical treatment.
    let server = spawn_mock("crlf", 1, true);
    let router = via_router(&server);
    for (tier, addr) in [("direct", server.addr), ("routed", router.addr)] {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        std::io::Write::write_all(&mut s, b"{\"op\":\"ping\",\"id\":3}\r\n").unwrap();
        let mut reader = std::io::BufReader::new(s);
        let mut resp = String::new();
        std::io::BufRead::read_line(&mut reader, &mut resp).unwrap();
        let v = predsamp::substrate::json::parse(resp.trim()).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{tier}: CRLF request must be served: {v}");
        assert_eq!(v.get("pong").as_bool(), Some(true), "{tier}: {v}");
        assert_eq!(v.get("id").as_i64(), Some(3), "{tier}: {v}");
    }
    router.stop();
    server.stop();
}

#[test]
fn streaming_and_framing_are_bitwise_invisible_across_configs() {
    // Exactness stays load-bearing across every delivery mode: plain,
    // streamed, framed, and streamed+framed replies must carry the same
    // bytes on the same seed — under elastic, rigid, sync, SLO-policy,
    // and capacity-capped placement configs alike.
    fn run_at(tag: &str, addr: &std::net::SocketAddr) -> Vec<Vec<i32>> {
        let mut c = Client::connect(addr).unwrap();
        let base = r#""op":"sample","model":"mock_a","method":"fpi","n":3,"seed":5"#;
        let plain = samples_of(&c.call(&format!("{{{base}}}")).unwrap());
        let mut events: Vec<(usize, Vec<i32>)> = Vec::new();
        let fin = c
            .call_streamed(&format!(r#"{{{base},"stream":true}}"#), &mut |ev| {
                events.push((ev.get("job").as_i64().unwrap() as usize, event_row(ev)));
            })
            .unwrap();
        assert_eq!(samples_of(&fin), plain, "{tag}: streamed final reply diverged");
        events.sort_by_key(|(j, _)| *j);
        assert_eq!(events.iter().map(|(j, _)| *j).collect::<Vec<_>>(), vec![0, 1, 2], "{tag}: exactly one event per job");
        assert_eq!(events.into_iter().map(|(_, row)| row).collect::<Vec<_>>(), plain, "{tag}: streamed rows diverged");
        let framed = samples_of(&c.call(&format!(r#"{{{base},"frame":true}}"#)).unwrap());
        assert_eq!(framed, plain, "{tag}: binary-framed payload diverged");
        let mut rows: Vec<(usize, Vec<i32>)> = Vec::new();
        let fin = c
            .call_streamed(&format!(r#"{{{base},"stream":true,"frame":true}}"#), &mut |ev| {
                rows.push((ev.get("job").as_i64().unwrap() as usize, event_row(ev)));
            })
            .unwrap();
        assert_eq!(samples_of(&fin), plain, "{tag}: streamed+framed final diverged");
        rows.sort_by_key(|(j, _)| *j);
        assert_eq!(rows.into_iter().map(|(_, row)| row).collect::<Vec<_>>(), plain, "{tag}: framed event rows diverged");
        plain
    }
    fn run(tag: &str, server: ServerHandle) -> Vec<Vec<i32>> {
        let out = run_at(tag, &server.addr);
        server.stop();
        out
    }
    let wait = Duration::from_millis(5);
    let reference = run("elastic", spawn_mock_cfg("edge-elastic", 2, true, true, true, wait));
    for (tag, server) in [
        ("rigid", spawn_mock_cfg("edge-rigid", 2, true, false, false, wait)),
        ("sync", spawn_mock_cfg("edge-sync", 2, false, false, false, wait)),
        ("slo", spawn_mock_policy("edge-slo", PolicyKind::Slo, AdmissionKind::OldestFirst)),
        ("capped", spawn_mock_placement("edge-capped", 2, PlacementKind::CapacityCapped(1))),
    ] {
        assert_eq!(run(tag, server), reference, "{tag}: serving config changed the payload");
    }
    // All four delivery modes through a federation router tier: streamed
    // events and binary frames are proxied verbatim, so the routed
    // payload is the same payload.
    let server = spawn_mock_cfg("edge-routed", 2, true, true, true, wait);
    let router = via_router(&server);
    assert_eq!(run_at("routed", &router.addr), reference, "routed: the router tier changed the payload");
    router.stop();
    server.stop();
}

#[test]
fn oversized_request_rejected_before_buffering() {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        engine_threads: 1,
        max_line_len: 512,
        ..ServeConfig::default()
    };
    let server = spawn_mock_with("overlimit", cfg);
    // An unterminated flood crosses the cap mid-buffer: rejected the
    // moment the buffer passes the limit, no newline ever required.
    let mut s = std::net::TcpStream::connect(server.addr).unwrap();
    std::io::Write::write_all(&mut s, &[b'x'; 600]).unwrap();
    let mut reader = std::io::BufReader::new(s);
    let mut resp = String::new();
    std::io::BufRead::read_line(&mut reader, &mut resp).unwrap();
    let v = predsamp::substrate::json::parse(resp.trim()).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(false), "{v}");
    assert!(v.get("error").as_str().unwrap().contains("max_line_len"), "{v}");
    let mut rest = String::new();
    assert_eq!(std::io::BufRead::read_line(&mut reader, &mut rest).unwrap(), 0, "over-limit connections must be closed");
    // A complete-but-oversized line is rejected the same way.
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(&format!(r#"{{"op":"ping","pad":"{}"}}"#, "y".repeat(600))).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    assert!(r.get("error").as_str().unwrap().contains("max_line_len"), "{r}");
    // Both rejections happened before parse/dispatch and are counted in
    // the edge section.
    let mut c2 = Client::connect(&server.addr).unwrap();
    let m = c2.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("edge").get("overlimit_rejections").as_i64().unwrap() >= 2, "{m}");
    // A router tier enforces the same cap at its own edge — the flood
    // never reaches the backend, and the router's metrics count it.
    let router = via_router_cfg(&server, |cfg| cfg.max_line_len = 512);
    let mut s = std::net::TcpStream::connect(router.addr).unwrap();
    std::io::Write::write_all(&mut s, &[b'x'; 600]).unwrap();
    let mut reader = std::io::BufReader::new(s);
    let mut resp = String::new();
    std::io::BufRead::read_line(&mut reader, &mut resp).unwrap();
    let v = predsamp::substrate::json::parse(resp.trim()).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(false), "routed: {v}");
    assert!(v.get("error").as_str().unwrap().contains("max_line_len"), "routed: {v}");
    let mut c = Client::connect(&router.addr).unwrap();
    let r = c.call(&format!(r#"{{"op":"ping","pad":"{}"}}"#, "y".repeat(600))).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "routed: {r}");
    assert!(r.get("error").as_str().unwrap().contains("max_line_len"), "routed: {r}");
    let mut c2 = Client::connect(&router.addr).unwrap();
    let m = c2.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("edge").get("overlimit_rejections").as_i64().unwrap() >= 2, "routed: {m}");
    router.stop();
    server.stop();
}

#[test]
fn per_connection_rate_limit_rejects_excess() {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        engine_threads: 1,
        rate_limit: 1,
        ..ServeConfig::default()
    };
    let server = spawn_mock_with("ratelimit", cfg);
    let mut c = Client::connect(&server.addr).unwrap();
    for i in 0..6u64 {
        c.send_line(&format!(r#"{{"op":"ping","id":{i}}}"#)).unwrap();
    }
    let (mut ok, mut limited) = (0, 0);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..6 {
        let r = c.read_message().unwrap();
        assert!(seen.insert(r.get("id").as_i64().unwrap()), "duplicate reply: {r}");
        if r.get("ok").as_bool() == Some(true) {
            ok += 1;
        } else {
            assert!(r.get("error").as_str().unwrap().contains("rate limit"), "{r}");
            limited += 1;
        }
    }
    assert!(ok >= 1, "the burst token must admit at least one request");
    assert!(limited >= 1, "six pipelined pings at 1 req/s must trip the limit");
    // The limited connection stays open, and a token refills within a second.
    std::thread::sleep(Duration::from_millis(1100));
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true), "{pong}");
    // Counted in the edge section (read from a fresh bucket's connection).
    let mut c2 = Client::connect(&server.addr).unwrap();
    let m = c2.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("edge").get("ratelimit_rejections").as_i64().unwrap() >= 1, "{m}");
    server.stop();
}

#[test]
fn reply_timeout_fails_the_request_and_counts_the_orphan() {
    // A lone request sits in its 400 ms batching window, so a 50 ms
    // reply_timeout fires first: the client gets a prompt id-tagged
    // error, and the engine's eventual answer is counted as orphaned —
    // never delivered to a caller that already moved on.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 64,
        max_wait: Duration::from_millis(400),
        continuous: true,
        elastic: true,
        steal: true,
        engine_threads: 1,
        reply_timeout: Duration::from_millis(50),
        ..ServeConfig::default()
    };
    let server = spawn_mock_with("replytimeout", cfg);
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":1,"seed":0,"id":9}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    assert!(r.get("error").as_str().unwrap().contains("reply timeout"), "{r}");
    assert_eq!(r.get("id").as_i64(), Some(9), "the timeout error must still carry the request id");
    // The connection survives, and once the batching window closes the
    // late reply shows up as orphaned in the edge counters.
    let m = metrics_eventually(&mut c, |m| m.get("edge").get("orphaned_replies").as_i64().unwrap_or(0) >= 1);
    let edge = m.get("metrics").get("edge");
    assert!(edge.get("reply_timeouts").as_i64().unwrap() >= 1, "{m}");
    assert!(edge.get("orphaned_replies").as_i64().unwrap() >= 1, "{m}");
    server.stop();
}

#[test]
fn connection_cap_rejects_excess_connections() {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        engine_threads: 1,
        max_conns: 2,
        ..ServeConfig::default()
    };
    let server = spawn_mock_with("conncap", cfg);
    let mut c1 = Client::connect(&server.addr).unwrap();
    let c2 = {
        let mut c2 = Client::connect(&server.addr).unwrap();
        assert_eq!(c1.call(r#"{"op":"ping"}"#).unwrap().get("ok").as_bool(), Some(true));
        assert_eq!(c2.call(r#"{"op":"ping"}"#).unwrap().get("ok").as_bool(), Some(true));
        c2
    };
    // Both slots taken: the third connection gets an error line and EOF.
    let s = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = std::io::BufReader::new(s);
    let mut resp = String::new();
    std::io::BufRead::read_line(&mut reader, &mut resp).unwrap();
    let v = predsamp::substrate::json::parse(resp.trim()).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(false), "{v}");
    assert!(v.get("error").as_str().unwrap().contains("connection limit"), "{v}");
    let mut rest = String::new();
    assert_eq!(std::io::BufRead::read_line(&mut reader, &mut rest).unwrap(), 0, "a rejected connection must be closed");
    let m = c1.call(r#"{"op":"metrics"}"#).unwrap();
    let edge = m.get("metrics").get("edge");
    assert!(edge.get("conn_cap_rejections").as_i64().unwrap() >= 1, "{m}");
    assert!(edge.get("open_conns").as_i64().unwrap() <= 2, "the gauge must never exceed max_conns: {m}");
    // Closing a connection frees its slot (once the loop notices the EOF).
    drop(c2);
    let mut admitted = false;
    for _ in 0..100 {
        let mut c3 = Client::connect(&server.addr).unwrap();
        if c3.call(r#"{"op":"ping"}"#).map(|r| r.get("ok").as_bool() == Some(true)).unwrap_or(false) {
            admitted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(admitted, "closing a connection must free a slot under max_conns");
    server.stop();
}

#[test]
fn malformed_requests_get_errors() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    for bad in [
        "this is not json",
        r#"{"op":"sample"}"#,
        r#"{"op":"sample","model":"no_such_model"}"#,
        r#"{"op":"bogus"}"#,
    ] {
        let r = c.call(bad).unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(false), "{bad} -> {r}");
        assert!(matches!(r.get("error"), Value::Str(_)));
    }
    // connection still usable afterwards
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    server.stop();
}

// ---------------------------------------------------------------------------
// Serialization byte-stability (regression tests for the BTreeMap audit:
// no map with nondeterministic iteration order may reach serialized
// metrics output)
// ---------------------------------------------------------------------------

#[test]
fn metrics_snapshot_is_byte_stable_across_insertion_order() {
    use predsamp::coordinator::metrics::Metrics;
    // Two metrics fed the same multiset of events, with policy labels
    // recorded in different interleavings — as two identical runs would
    // under different thread schedules. The rendered snapshots must be
    // byte-identical.
    let mut a = Metrics::new();
    let mut b = Metrics::new();
    for name in ["slo", "occupancy", "slo", "latency"] {
        a.record_policy(name);
    }
    for name in ["latency", "slo", "occupancy", "slo"] {
        b.record_policy(name);
    }
    for m in [&mut a, &mut b] {
        m.record_request();
        m.record_batch(4, 16, 12.5, 0.25);
        m.record_absorbed(3);
        m.record_absorb_denial();
        m.record_admission_age(Duration::from_millis(7));
    }
    assert_eq!(a.snapshot().to_string(), b.snapshot().to_string());
}

#[test]
fn convergence_book_is_byte_stable_across_observation_order() {
    use predsamp::coordinator::policy::{ConvergenceBook, ConvergencePrior};
    let obs = |p: f64, s: f64| ConvergencePrior { passes_per_job: p, pass_secs: s };
    // Same observations per key; only the cross-key interleaving differs
    // (per-key order must match — the estimate is an EWMA).
    let a = ConvergenceBook::new();
    a.observe("mnist/forecast", obs(3.0, 0.01));
    a.observe("cifar/aux", obs(7.0, 0.05));
    a.observe("mnist/forecast", obs(5.0, 0.02));
    let b = ConvergenceBook::new();
    b.observe("cifar/aux", obs(7.0, 0.05));
    b.observe("mnist/forecast", obs(3.0, 0.01));
    b.observe("mnist/forecast", obs(5.0, 0.02));
    let render = |book: &ConvergenceBook| {
        book.entries()
            .into_iter()
            .map(|(k, est, n)| format!("{k}={}/{}/{n}", est.passes_per_job, est.pass_secs))
            .collect::<Vec<_>>()
            .join(",")
    };
    assert_eq!(render(&a), render(&b));
    assert!(render(&a).starts_with("cifar/aux="), "entries must iterate in key order: {}", render(&a));
}
