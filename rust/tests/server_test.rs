//! End-to-end serving tests: spawn the TCP server against the real
//! artifacts and exercise the protocol, batching and exactness.

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client};
use predsamp::substrate::json::Value;
use std::time::Duration;

fn server() -> Option<predsamp::coordinator::server::ServerHandle> {
    let dir = predsamp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping server test: run `make artifacts`");
        return None;
    }
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(10),
        continuous: true,
        worker_threads: 4,
    };
    Some(spawn(dir, cfg).expect("server spawns"))
}

#[test]
fn ping_info_metrics_eval() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    let info = c.call(r#"{"op":"info"}"#).unwrap();
    let models = info.get("models").as_arr().unwrap();
    assert!(models.iter().any(|m| m.get("name").as_str() == Some("mnist_bin")));

    let ev = c.call(r#"{"op":"eval","model":"mnist_bin"}"#).unwrap();
    assert_eq!(ev.get("ok").as_bool(), Some(true));
    assert!(ev.get("bpd").as_f64().unwrap() > 0.0);

    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    assert!(m.get("metrics").get("requests").as_i64().unwrap() >= 3);
    server.stop();
}

#[test]
fn sample_request_roundtrip_and_exactness() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r1 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":4}"#)
        .unwrap();
    assert_eq!(r1.get("ok").as_bool(), Some(true), "{r1}");
    let s1 = predsamp::coordinator::protocol::parse_samples(r1.get("samples")).unwrap();
    assert_eq!(s1.len(), 2);
    assert_eq!(s1[0].len(), 256);

    // Baseline through the server must give the same samples (exactness
    // survives the whole serving stack).
    let r2 = c
        .call(r#"{"op":"sample","model":"mnist_bin","method":"baseline","n":2,"seed":4}"#)
        .unwrap();
    let s2 = predsamp::coordinator::protocol::parse_samples(r2.get("samples")).unwrap();
    assert_eq!(s1, s2, "serving stack must preserve exactness");
    // And predictive sampling must have used fewer calls.
    assert!(r1.get("arm_calls").as_f64().unwrap() < r2.get("arm_calls").as_f64().unwrap());
    server.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(server) = server() else { return };
    let addr = server.addr;
    let mut handles = Vec::new();
    for i in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let r = c
                .call(&format!(
                    r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i},"return_samples":true}}"#
                ))
                .unwrap();
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            let s = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
            assert_eq!(s.len(), 2);
            (i, s)
        }));
    }
    let mut results: Vec<(i32, Vec<Vec<i32>>)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|(i, _)| *i);
    // Same seed ⇒ same samples regardless of how requests were merged:
    let mut c = Client::connect(&addr).unwrap();
    for (i, s) in &results {
        let r = c
            .call(&format!(
                r#"{{"op":"sample","model":"mnist_bin","method":"fpi","n":2,"seed":{i}}}"#
            ))
            .unwrap();
        let again = predsamp::coordinator::protocol::parse_samples(r.get("samples")).unwrap();
        assert_eq!(&again, s, "client {i} samples must be reproducible");
    }
    server.stop();
}

#[test]
fn decode_through_server() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    let r = c
        .call(r#"{"op":"sample","model":"latent_cifar","method":"fpi","n":1,"seed":0,"return_samples":false,"decode":true}"#)
        .unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    let imgs = r.get("images").as_arr().unwrap();
    assert_eq!(imgs.len(), 1);
    assert_eq!(imgs[0].as_arr().unwrap().len(), 3 * 16 * 16);
    server.stop();
}

#[test]
fn malformed_requests_get_errors() {
    let Some(server) = server() else { return };
    let mut c = Client::connect(&server.addr).unwrap();
    for bad in [
        "this is not json",
        r#"{"op":"sample"}"#,
        r#"{"op":"sample","model":"no_such_model"}"#,
        r#"{"op":"bogus"}"#,
    ] {
        let r = c.call(bad).unwrap();
        assert_eq!(r.get("ok").as_bool(), Some(false), "{bad} -> {r}");
        assert!(matches!(r.get("error"), Value::Str(_)));
    }
    // connection still usable afterwards
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    server.stop();
}
