//! Integration tests over the compiled artifacts: the full L3→runtime→HLO
//! path, cross-checking the paper's correctness guarantees end to end.
//! All tests no-op (with a note) if `make artifacts` hasn't run.

use predsamp::coordinator::config::Method;
use predsamp::coordinator::engine::Engine;
use predsamp::coordinator::scheduler;
use predsamp::runtime::artifact::Manifest;
use predsamp::sampler::forecast;
use predsamp::sampler::noise::JobNoise;
use predsamp::sampler::predictive::PredictiveSampler;

fn manifest() -> Option<Manifest> {
    let dir = predsamp::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest parses"))
    } else {
        eprintln!("skipping integration test: run `make artifacts` first");
        None
    }
}

#[test]
fn exactness_across_methods_and_models() {
    // The central guarantee (paper §2.2): identical ε ⇒ identical sample,
    // for every forecasting policy, through the real compiled artifacts.
    let Some(man) = manifest() else { return };
    for model in ["mnist_bin", "cifar5", "latent_cifar"] {
        let eng = Engine::load(&man, model).unwrap();
        let base = eng.sample_batch(Method::Baseline, 1, 3).unwrap();
        for method in [
            Method::Zeros,
            Method::PredictLast,
            Method::Fpi,
            Method::Forecast { t_use: 1 },
            Method::Forecast { t_use: 5 },
        ] {
            let res = eng.sample_batch(method, 1, 3).unwrap();
            assert_eq!(res.jobs[0].x, base.jobs[0].x, "{model}/{}", method.label());
            assert!(res.arm_calls <= eng.info.dim + 1, "{model}/{}", method.label());
        }
    }
}

#[test]
fn batch32_matches_batch1_samples() {
    // Job noise is keyed by (seed, job id): the b32 artifact must produce
    // the same samples as 32 independent b1 runs.
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "mnist_bin").unwrap();
    let b32 = eng.sample_batch(Method::Fpi, 32, 7).unwrap();
    for id in [0usize, 13, 31] {
        let exe1 = eng.exe(1).unwrap();
        let mut ps = PredictiveSampler::new(exe1, Box::new(forecast::FpiReuse));
        ps.reset_slot(0, JobNoise::new(7, id as u64, eng.info.dim, eng.info.categories));
        while !ps.slot_done(0) {
            ps.step().unwrap();
        }
        let single = ps.take_result(0).unwrap();
        assert_eq!(b32.jobs[id].x, single.x, "job {id}");
    }
}

#[test]
fn fpi_saves_calls_on_every_model() {
    let Some(man) = manifest() else { return };
    for (model, info) in &man.models {
        if !info.step_batch_sizes().contains(&1) {
            continue;
        }
        let eng = Engine::load(&man, model).unwrap();
        let res = eng.sample_batch(Method::Fpi, 1, 0).unwrap();
        assert!(
            (res.arm_calls as f64) < 0.8 * info.dim as f64,
            "{model}: FPI used {}/{} calls",
            res.arm_calls,
            info.dim
        );
    }
}

#[test]
fn noreparam_ablation_collapses_savings() {
    // Table 3's dominant effect, verified through the artifact: without
    // reparametrization the forecast agreement is near-chance for K=256.
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "cifar8").unwrap();
    let fpi = eng.sample_batch(Method::Fpi, 1, 1).unwrap();
    let norep = eng.sample_batch(Method::NoReparam, 1, 1).unwrap();
    assert!(
        norep.arm_calls > 2 * fpi.arm_calls,
        "no-reparam {} should be far worse than fpi {}",
        norep.arm_calls,
        fpi.arm_calls
    );
}

#[test]
fn continuous_scheduler_on_artifact() {
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "latent_cifar").unwrap();
    let exe = eng.exe(32).unwrap();
    let n = 48;
    let cont = scheduler::run_continuous(exe, Box::new(forecast::FpiReuse), n, 5).unwrap();
    let sync = scheduler::run_sync_chunks(exe, Box::new(forecast::FpiReuse), n, 5).unwrap();
    assert_eq!(cont.results.len(), n);
    for i in 0..n {
        assert_eq!(cont.results[i].x, sync.results[i].x, "job {i}");
    }
    assert!(cont.total_passes <= sync.total_passes);
    assert!(cont.occupancy >= sync.occupancy - 1e-9);
}

#[test]
fn decoded_latents_are_plausible_images() {
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "latent_svhn").unwrap();
    let res = eng.sample_batch(Method::Fpi, 1, 9).unwrap();
    let imgs = eng.decode(&[res.jobs[0].x.clone()]).unwrap();
    let img = &imgs[0];
    assert!(img.iter().all(|v| v.is_finite()));
    // trained on [-1,1] images; decodes should stay in a sane envelope
    assert!(img.iter().all(|&v| (-3.0..=3.0).contains(&v)));
    let mean = img.iter().sum::<f32>() / img.len() as f32;
    assert!((-1.0..=1.0).contains(&mean));
}

#[test]
fn mistake_and_convergence_traces_consistent() {
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "mnist_bin").unwrap();
    let res = eng.sample_batch(Method::Fpi, 1, 11).unwrap();
    let job = &res.jobs[0];
    let d = eng.info.dim;
    assert_eq!(job.mistakes.len(), d);
    assert_eq!(job.converge_iter.len(), d);
    assert!(job.converge_iter.iter().all(|&c| c >= 1 && c as usize <= job.iterations));
    assert!(job.converge_iter.windows(2).all(|w| w[0] <= w[1]));
    let n_mistakes: usize = job.mistakes.iter().map(|&m| m as usize).sum();
    assert!(n_mistakes <= job.iterations);
    // first variable's value is decided on pass 1
    assert_eq!(job.converge_iter[0], 1);
}

#[test]
fn pallas_artifact_parity() {
    // Artifact-parity gate: the Pallas-kernel lowering and the reference lowering
    // of the same trained model must agree through the rust runtime.
    let Some(man) = manifest() else { return };
    let info = man.model("mnist_bin").unwrap();
    let Ok(pfile) = info.file("step_pallas_b1") else { return };
    let pexe = predsamp::runtime::step::StepExecutable::load(man.path(pfile), info, 1).unwrap();
    let rexe = predsamp::runtime::step::StepExecutable::load(man.path(info.file("step_b1").unwrap()), info, 1).unwrap();
    for seed in 0..3u64 {
        let x: Vec<i32> = (0..info.dim).map(|i| ((i as u64 * 2654435761 + seed * 97) % 2) as i32).collect();
        let po = pexe.run(&x).unwrap();
        let ro = rexe.run(&x).unwrap();
        let max_err = po.logp.iter().zip(&ro.logp).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "seed {seed}: pallas vs ref max err {max_err}");
    }
}

#[test]
fn bpd_through_runtime_matches_manifest() {
    let Some(man) = manifest() else { return };
    for model in ["mnist_bin", "cifar5", "latent_cifar"] {
        let eng = Engine::load(&man, model).unwrap();
        let bpd = eng.eval_bpd().unwrap();
        assert!(
            (bpd - eng.info.bpd).abs() < 0.2,
            "{model}: rust bpd {bpd:.3} vs python {:.3}",
            eng.info.bpd
        );
    }
}

#[test]
fn exe_call_counting() {
    let Some(man) = manifest() else { return };
    let eng = Engine::load(&man, "mnist_bin").unwrap();
    // FPI never reads the forecast heads, so it runs on the logp-only exe.
    let exe = eng.exe_for(1, false).unwrap();
    let before = exe.calls();
    let _ = eng.sample_batch(Method::Fpi, 1, 2).unwrap();
    assert!(exe.calls() > before, "telemetry must count passes");
}
