//! Extended property tests on the sampler/scheduler over the mock ARM —
//! no artifacts required, so these run everywhere.

use predsamp::coordinator::scheduler;
use predsamp::sampler::ancestral::ancestral_sample;
use predsamp::sampler::forecast::{FpiReuse, Learned, NoReparam, PredictLast, Zeros};
use predsamp::sampler::mock::MockArm;
use predsamp::sampler::noise::JobNoise;
use predsamp::sampler::predictive::PredictiveSampler;
use predsamp::sampler::StepModel;
use predsamp::substrate::proptest_lite::check;
use predsamp::{prop_assert, prop_assert_eq};

#[test]
fn learned_policy_exact_for_any_t_use() {
    // t_use beyond the trained window must clamp, t_use=0 must behave;
    // exactness holds regardless of the window size.
    check("learned-t-use", 8, |g| {
        let model = MockArm::new(1, g.usize_in(1, 4), g.usize_in(2, 6), g.usize_in(2, 6), 3, 2.0, g.rng.next_u64());
        let d = model.dim();
        let k = model.categories();
        let seed = g.rng.next_u64();
        let reference = ancestral_sample(&model, &JobNoise::new(seed, 0, d, k)).map_err(|e| e.to_string())?;
        for t_use in [1usize, 2, 3, 7, 100] {
            let mut ps = PredictiveSampler::new(&model, Box::new(Learned { t_use }));
            ps.reset_slot(0, JobNoise::new(seed, 0, d, k));
            for _ in 0..=d {
                ps.step().map_err(|e| e.to_string())?;
                if ps.slot_done(0) {
                    break;
                }
            }
            let r = ps.take_result(0).ok_or("did not converge")?;
            prop_assert_eq!(&r.x, &reference.x, "t_use={} diverged", t_use);
        }
        Ok(())
    });
}

#[test]
fn noreparam_samples_remain_model_samples() {
    // Even though no-reparam redraws noise, each finalized variable is a
    // valid conditional sample; over many runs the per-variable marginals
    // must match the ancestral sampler's marginals.
    let model = MockArm::new(1, 1, 4, 3, 1, 1.5, 77);
    let d = model.dim();
    let runs = 400;
    let mut anc_counts = vec![[0u32; 3]; d];
    let mut nor_counts = vec![[0u32; 3]; d];
    for s in 0..runs {
        let anc = ancestral_sample(&model, &JobNoise::new(1000 + s, 0, d, 3)).unwrap();
        for (j, &v) in anc.x.iter().enumerate() {
            anc_counts[j][v as usize] += 1;
        }
        let mut ps = PredictiveSampler::new(&model, Box::new(NoReparam));
        ps.reset_slot(0, JobNoise::new(2000 + s, 0, d, 3));
        for _ in 0..=d {
            ps.step().unwrap();
            if ps.slot_done(0) {
                break;
            }
        }
        let r = ps.take_result(0).unwrap();
        for (j, &v) in r.x.iter().enumerate() {
            nor_counts[j][v as usize] += 1;
        }
    }
    for j in 0..d {
        for c in 0..3 {
            let pa = anc_counts[j][c] as f64 / runs as f64;
            let pn = nor_counts[j][c] as f64 / runs as f64;
            assert!(
                (pa - pn).abs() < 0.13,
                "marginal mismatch at var {j} cat {c}: ancestral {pa:.2} vs noreparam {pn:.2}"
            );
        }
    }
}

#[test]
fn mistakes_bound_iterations_tightly() {
    // iterations <= mistakes + 2: every pass except possibly the first
    // (cold zeros forecast can also be wholly correct) and the last must
    // finalize exactly one mistaken position.
    check("mistake-iteration-bound", 12, |g| {
        let model = MockArm::new(1, g.usize_in(1, 3), g.usize_in(2, 7), g.usize_in(2, 6), 1, g.f64_in(0.0, 5.0) as f32, g.rng.next_u64());
        let d = model.dim();
        let mut ps = PredictiveSampler::new(&model, Box::new(FpiReuse));
        ps.reset_slot(0, JobNoise::new(g.rng.next_u64(), 0, d, model.categories()));
        for _ in 0..=d {
            ps.step().map_err(|e| e.to_string())?;
            if ps.slot_done(0) {
                break;
            }
        }
        let r = ps.take_result(0).unwrap();
        let n_mist: usize = r.mistakes.iter().map(|&m| m as usize).sum();
        prop_assert!(
            r.iterations <= n_mist + 2 && n_mist <= r.iterations,
            "iters {} vs mistakes {}",
            r.iterations,
            n_mist
        );
        Ok(())
    });
}

#[test]
fn all_policies_beat_or_match_baseline_calls() {
    check("policy-call-bound", 8, |g| {
        let model = MockArm::new(1, 2, g.usize_in(2, 6), g.usize_in(2, 5), 2, g.f64_in(0.0, 3.0) as f32, g.rng.next_u64());
        let d = model.dim();
        let seed = g.rng.next_u64();
        let policies: Vec<Box<dyn predsamp::sampler::forecast::Forecaster>> = vec![
            Box::new(Zeros),
            Box::new(PredictLast),
            Box::new(FpiReuse),
            Box::new(Learned { t_use: 2 }),
        ];
        for fc in policies {
            let name = fc.name();
            let mut ps = PredictiveSampler::new(&model, fc);
            ps.reset_slot(0, JobNoise::new(seed, 0, d, model.categories()));
            for _ in 0..=d {
                ps.step().map_err(|e| e.to_string())?;
                if ps.slot_done(0) {
                    break;
                }
            }
            let r = ps.take_result(0).unwrap();
            prop_assert!(r.iterations <= d, "{}: {} > d={}", name, r.iterations, d);
        }
        Ok(())
    });
}

#[test]
fn plan_passes_bitwise_equal_full_passes_every_policy() {
    // The pass-plan contract: frontier-aware partial passes (dead slots
    // skipped, prefixes skipped, heads skipped, early scan stop) must be
    // bitwise invisible for every policy, in weak and strong coupling
    // regimes alike — samples, mistake maps, convergence maps, and pass
    // counts all identical, with strictly less work whenever a schedule
    // runs more than one pass.
    check("plan-vs-full", 12, |g| {
        let b = g.usize_in(1, 5);
        let model = MockArm::new(b, g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6), 3, g.f64_in(0.0, 4.0) as f32, g.rng.next_u64());
        let d = model.dim();
        let seed = g.rng.next_u64();
        for name in ["zeros", "predict_last", "fpi", "learned", "noreparam"] {
            let run = |use_plan: bool| -> Result<(predsamp::sampler::BatchResult, usize), String> {
                let fc = predsamp::sampler::forecast::by_name(name, 2).unwrap();
                let mut ps = PredictiveSampler::new(&model, fc);
                ps.set_plan_mode(use_plan);
                let res = ps.run_sync(seed).map_err(|e| e.to_string())?;
                Ok((res, ps.positions_evaluated))
            };
            let (full, full_pos) = run(false)?;
            let (plan, plan_pos) = run(true)?;
            for s in 0..b {
                prop_assert_eq!(&plan.jobs[s].x, &full.jobs[s].x, "{} slot {} sample", name, s);
                prop_assert_eq!(&plan.jobs[s].mistakes, &full.jobs[s].mistakes, "{} slot {} mistakes", name, s);
                prop_assert_eq!(&plan.jobs[s].converge_iter, &full.jobs[s].converge_iter, "{} slot {} trace", name, s);
                prop_assert_eq!(plan.jobs[s].iterations, full.jobs[s].iterations, "{} slot {} iterations", name, s);
            }
            prop_assert_eq!(plan.arm_calls, full.arm_calls, "{} pass count", name);
            let full_row = d + model.pixels() * model.t_fore();
            prop_assert_eq!(full_pos, full.arm_calls * b * full_row, "{} full-pass work must be B*(d + P*T) per pass", name);
            prop_assert!(plan_pos <= full_pos, "{}: planned work {} > full {}", name, plan_pos, full_pos);
            if full.arm_calls > 1 {
                prop_assert!(plan_pos < full_pos, "{}: plan skipped nothing over {} passes", name, full.arm_calls);
            }
        }
        Ok(())
    });
}

#[test]
fn downshift_preserves_samples_mid_schedule() {
    // Batch down-shifting over a [1, 2, 4] family must keep every job's
    // sample bitwise identical to its batch-1 reference, whatever point
    // of the schedule the migrations happen at.
    check("downshift-exactness", 10, |g| {
        let (c, px, k) = (g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6));
        let strength = g.f64_in(0.0, 4.0) as f32;
        let mseed = g.rng.next_u64();
        let m4 = MockArm::new(4, c, px, k, 2, strength, mseed);
        let m2 = MockArm::new(2, c, px, k, 2, strength, mseed);
        let m1 = MockArm::new(1, c, px, k, 2, strength, mseed);
        let d = m4.dim();
        let seed = g.rng.next_u64();
        let n = g.usize_in(5, 13);
        let noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let rep = scheduler::run_continuous_family(&family, Box::new(FpiReuse), noises).map_err(|e| e.to_string())?;
        prop_assert_eq!(rep.results.len(), n, "all jobs must complete");
        for (id, job) in rep.results.iter().enumerate() {
            let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
            ps.reset_slot(0, JobNoise::new(seed, id as u64, d, k));
            while !ps.slot_done(0) {
                ps.step().map_err(|e| e.to_string())?;
            }
            let single = ps.take_result(0).unwrap();
            prop_assert_eq!(&job.x, &single.x, "job {} changed under down-shifting (downshifts={})", id, rep.downshifts);
        }
        prop_assert!(rep.min_batch <= 4 && rep.min_batch >= 1, "min_batch {} out of family", rep.min_batch);
        Ok(())
    });
}

#[test]
fn elastic_live_arrivals_preserve_samples() {
    // The elastic scheduler's contract: whatever trickle pattern jobs
    // arrive in mid-schedule — triggering any interleaving of up-shifts
    // and down-shifts across the [1, 2, 4] family — every job's sample
    // stays bitwise identical to its batch-1 reference.
    use predsamp::coordinator::scheduler::{LiveJob, TickBurstFeed};
    check("elastic-exactness", 10, |g| {
        let (c, px, k) = (g.usize_in(1, 3), g.usize_in(2, 6), g.usize_in(2, 5));
        let strength = g.f64_in(0.0, 4.0) as f32;
        let mseed = g.rng.next_u64();
        let m4 = MockArm::new(4, c, px, k, 2, strength, mseed);
        let m2 = MockArm::new(2, c, px, k, 2, strength, mseed);
        let m1 = MockArm::new(1, c, px, k, 2, strength, mseed);
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let d = m4.dim();
        let seed = g.rng.next_u64();
        let n = g.usize_in(4, 12);
        let first = g.usize_in(1, 3).min(n);
        let job = |id: usize| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) };
        let initial: Vec<LiveJob> = (0..first).map(job).collect();
        let mut arrivals: Vec<(usize, Vec<LiveJob>)> = (first..n).map(|id| (g.usize_in(1, 8), vec![job(id)])).collect();
        arrivals.sort_by_key(|(at, _)| *at);
        let mut feed = TickBurstFeed::new(n, arrivals);
        let rep = scheduler::run_elastic_family(&family, Box::new(FpiReuse), initial, &mut feed).map_err(|e| e.to_string())?;
        for id in 0..n {
            let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
            ps.reset_slot(0, JobNoise::new(seed, id as u64, d, k));
            while !ps.slot_done(0) {
                ps.step().map_err(|e| e.to_string())?;
            }
            let single = ps.take_result(0).unwrap();
            let live = feed.results[id].as_ref().ok_or("job not completed")?;
            prop_assert_eq!(&live.x, &single.x, "job {} changed under elastic scheduling (up={}, down={})", id, rep.upshifts, rep.downshifts);
        }
        prop_assert!(rep.min_batch >= 1 && rep.min_batch <= 4, "min_batch {} out of family", rep.min_batch);
        Ok(())
    });
}

#[test]
fn sizing_policies_preserve_samples() {
    // THE policy-subsystem acceptance gate: whatever sizing policy drives
    // the elastic scheduler — occupancy-first, latency-lean, or the
    // SLO-driven hybrid at any target (pass-denominated or wall-clock) —
    // every job's sample stays bitwise identical to its batch-1
    // reference, under random trickle patterns over a sparse [1, 4]
    // export family (the shape that maximally separates the policies'
    // sizing decisions).
    use predsamp::coordinator::policy::{LatencyLean, OccupancyFirst, SizingPolicy, SloHybrid, SloTarget};
    use predsamp::coordinator::scheduler::{LiveJob, TickBurstFeed};
    use std::time::Duration;
    check("policy-exactness", 10, |g| {
        let (c, px, k) = (g.usize_in(1, 3), g.usize_in(2, 6), g.usize_in(2, 5));
        let strength = g.f64_in(0.0, 4.0) as f32;
        let mseed = g.rng.next_u64();
        let m4 = MockArm::new(4, c, px, k, 2, strength, mseed);
        let m1 = MockArm::new(1, c, px, k, 2, strength, mseed);
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let d = m4.dim();
        let seed = g.rng.next_u64();
        let n = g.usize_in(4, 11);
        let first = g.usize_in(1, 3).min(n);
        let mut ticks: Vec<(usize, usize)> = (first..n).map(|id| (g.usize_in(1, 8), id)).collect();
        ticks.sort();
        let policies: Vec<Box<dyn SizingPolicy>> = vec![
            Box::new(OccupancyFirst),
            Box::new(LatencyLean),
            Box::new(SloHybrid { target: SloTarget::Passes(g.f64_in(0.0, 30.0)) }),
            Box::new(SloHybrid { target: SloTarget::Wall(Duration::from_millis(g.usize_in(0, 40) as u64)) }),
        ];
        for sizing in &policies {
            let job = |id: usize| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) };
            let initial: Vec<LiveJob> = (0..first).map(job).collect();
            let arrivals: Vec<(usize, Vec<LiveJob>)> = ticks.iter().map(|&(at, id)| (at, vec![job(id)])).collect();
            let mut feed = TickBurstFeed::new(n, arrivals);
            let rep =
                scheduler::run_elastic_family_policy(&family, Box::new(FpiReuse), initial, &mut feed, sizing.as_ref()).map_err(|e| e.to_string())?;
            for id in 0..n {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, d, k));
                while !ps.slot_done(0) {
                    ps.step().map_err(|e| e.to_string())?;
                }
                let single = ps.take_result(0).unwrap();
                let live = feed.results[id].as_ref().ok_or("job not completed")?;
                prop_assert_eq!(
                    &live.x,
                    &single.x,
                    "policy {} job {} diverged from the batch-1 reference (up={}, down={})",
                    rep.policy,
                    id,
                    rep.upshifts,
                    rep.downshifts
                );
                prop_assert_eq!(live.iterations, single.iterations, "policy {} job {}: sizing changed the pass count", rep.policy, id);
            }
        }
        Ok(())
    });
}

#[test]
fn scheduler_empty_and_tiny_queues() {
    let model = MockArm::new(3, 2, 4, 3, 1, 2.0, 9);
    let rep = scheduler::run_continuous(&model, Box::new(FpiReuse), 0, 0).unwrap();
    assert!(rep.results.is_empty());
    assert_eq!(rep.total_passes, 0);
    let rep = scheduler::run_continuous(&model, Box::new(FpiReuse), 1, 0).unwrap();
    assert_eq!(rep.results.len(), 1);
    assert_eq!(rep.results[0].x.len(), model.dim());
}

#[test]
fn convergence_map_covers_all_iterations() {
    // The max convergence iteration must equal the job's iteration count
    // (the last pass always finalizes at least one variable).
    check("converge-map-max", 10, |g| {
        let model = MockArm::new(1, 2, g.usize_in(3, 7), 4, 1, 3.0, g.rng.next_u64());
        let d = model.dim();
        let mut ps = PredictiveSampler::new(&model, Box::new(FpiReuse));
        ps.reset_slot(0, JobNoise::new(g.rng.next_u64(), 0, d, 4));
        for _ in 0..=d {
            ps.step().map_err(|e| e.to_string())?;
            if ps.slot_done(0) {
                break;
            }
        }
        let r = ps.take_result(0).unwrap();
        let max_it = *r.converge_iter.iter().max().unwrap() as usize;
        prop_assert_eq!(max_it, r.iterations, "max converge iter vs iterations");
        Ok(())
    });
}
