//! Self-tests for the `predsamp-lint` static-analysis pass
//! (`rust/src/analysis/`): lexer soundness, annotation parsing, and —
//! for every pass — a violating fixture, a clean fixture, and a
//! `lint:allow` escape fixture. The final test lints the repo itself
//! and requires zero findings, which is the acceptance gate CI runs.
//!
//! Fixtures are plain source strings handed to [`SourceFile::from_source`]
//! under a synthetic repo-relative path label — the label, not the
//! filesystem, is what scopes a pass, so one test can present the same
//! text as living inside or outside a pass's jurisdiction.

use predsamp::analysis::lexer::{lex, TokKind};
use predsamp::analysis::passes::{self, doc_parity, lock_order, nondet, panic_guard, unsafe_audit, Ctx};
use predsamp::analysis::report::{Finding, Report};
use predsamp::analysis::source::SourceFile;
use predsamp::analysis::{lint_repo, walker};
use std::path::Path;

/// Run one pass over a single fixture file presented under `path`.
fn findings_for(run: fn(&Ctx, &mut Vec<Finding>), path: &str, src: &str) -> Vec<Finding> {
    let files = vec![SourceFile::from_source(path, src)];
    let mut out = Vec::new();
    run(&Ctx { files: &files, root: Path::new(".") }, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

#[test]
fn lexer_strings_hide_keywords() {
    let toks = lex(r#"let s = "unsafe { HashMap::new() }"; call(s);"#);
    assert!(!toks.iter().any(|t| t.is_ident("unsafe")));
    assert!(!toks.iter().any(|t| t.is_ident("HashMap")));
    assert!(toks.iter().any(|t| t.kind == TokKind::Str && t.text.contains("unsafe")));
    assert!(toks.iter().any(|t| t.is_ident("call")));
}

#[test]
fn lexer_comments_hide_keywords_and_nest() {
    let toks = lex("/* outer /* unsafe */ still comment */ fn x() {}");
    assert!(!toks.iter().any(|t| t.is_ident("unsafe")));
    let comments: Vec<_> = toks.iter().filter(|t| t.is_comment()).collect();
    assert_eq!(comments.len(), 1, "nested block comment must lex as one token");
    assert_eq!(comments[0].text, "outer /* unsafe */ still comment");
    assert!(toks.iter().any(|t| t.is_ident("fn")));
    assert!(toks.iter().any(|t| t.is_ident("x")));

    let toks = lex("// line comment with unwrap() and panic!\nreal();");
    assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
    assert!(toks.iter().any(|t| t.is_ident("real")));
}

#[test]
fn lexer_raw_strings() {
    // Hashed raw string: embedded quote and backslash stay inside the literal.
    let toks = lex(r###"let s = r#"quote " and \ unsafe"#; done(s);"###);
    let strs: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
    assert_eq!(strs.len(), 1);
    assert_eq!(strs[0].text, r#"quote " and \ unsafe"#);
    assert!(!toks.iter().any(|t| t.is_ident("unsafe")));
    assert!(toks.iter().any(|t| t.is_ident("done")));

    // Hash-less raw string: no escape processing, ends at the first quote.
    let toks = lex(r#"let s = r"no \escape here"; done(s);"#);
    let strs: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
    assert_eq!(strs.len(), 1);
    assert_eq!(strs[0].text, r"no \escape here");

    // Byte string lexes as a string; `break`-style identifiers starting
    // with prefix letters stay identifiers.
    let toks = lex(r#"let b = b"bytes"; break range;"#);
    assert!(toks.iter().any(|t| t.kind == TokKind::Str && t.text == "bytes"));
    assert!(toks.iter().any(|t| t.is_ident("break")));
    assert!(toks.iter().any(|t| t.is_ident("range")));
}

#[test]
fn lexer_char_vs_lifetime() {
    let toks = lex("fn f<'a>(x: &'a str) { let c = 'q'; let n = '\\n'; }");
    let lifetimes: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.as_str()).collect();
    assert_eq!(lifetimes, ["a", "a"]);
    let chars: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Char).map(|t| t.text.as_str()).collect();
    assert_eq!(chars, ["q", "\\n"]);
}

#[test]
fn lexer_tracks_lines() {
    let toks = lex("alpha\nbeta\n\n  gamma");
    let at = |name: &str| toks.iter().find(|t| t.is_ident(name)).unwrap().line;
    assert_eq!(at("alpha"), 1);
    assert_eq!(at("beta"), 2);
    assert_eq!(at("gamma"), 4);
}

// ---------------------------------------------------------------------------
// SourceFile: allows, test regions
// ---------------------------------------------------------------------------

#[test]
fn allows_parse_and_scope() {
    let src = "fn a() {\n    // lint:allow(nondet-guard): seeded elsewhere\n    let x = wall_clock();\n}\n// prose mentioning lint:allow(bogus): x is not an annotation\n";
    let f = SourceFile::from_source("rust/src/x.rs", src);
    assert_eq!(f.allows.len(), 1, "prose mention must not parse as an escape");
    assert_eq!(f.allows[0].pass, "nondet-guard");
    assert_eq!(f.allows[0].reason, "seeded elsewhere");
    assert!(f.allowed("nondet-guard", 2), "same line");
    assert!(f.allowed("nondet-guard", 3), "line directly below");
    assert!(!f.allowed("nondet-guard", 4), "two lines below is out of reach");
    assert!(!f.allowed("panic-guard", 3), "other passes are not excused");
}

#[test]
fn test_regions_detected() {
    let src = "fn live() { x(); }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { y(); }\n}\n#[cfg(not(test))]\nfn also_live() { z(); }\n";
    let f = SourceFile::from_source("rust/src/x.rs", src);
    assert!(!f.in_test(1));
    assert!(f.in_test(3));
    assert!(f.in_test(5));
    assert!(!f.in_test(8), "cfg(not(test)) is live code, not a test region");
}

// ---------------------------------------------------------------------------
// unsafe-audit
// ---------------------------------------------------------------------------

#[test]
fn unsafe_audit_flags_unsafe_outside_allowlist() {
    let out = findings_for(unsafe_audit::run, "rust/src/sampler/mod.rs", "fn f() { unsafe { q() } }");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].pass, "unsafe-audit");
    assert_eq!(out[0].line, 1);
    assert!(out[0].msg.contains("allowlisted"));
}

#[test]
fn unsafe_audit_requires_safety_comment_in_allowlisted_module() {
    let allowed_path = unsafe_audit::ALLOWED_MODULES[0];
    let bad = "fn f() { unsafe { q() } }";
    let out = findings_for(unsafe_audit::run, allowed_path, bad);
    assert_eq!(out.len(), 1);
    assert!(out[0].msg.contains("SAFETY"));

    let good = "fn f() {\n    // SAFETY: q only reads fds this struct owns.\n    unsafe { q() }\n}";
    assert!(findings_for(unsafe_audit::run, allowed_path, good).is_empty());

    let too_far = "fn f() {\n    // SAFETY: too far above to count.\n\n\n\n    unsafe { q() }\n}";
    assert_eq!(findings_for(unsafe_audit::run, allowed_path, too_far).len(), 1);
}

#[test]
fn unsafe_audit_ignores_masked_tokens_and_honors_allows() {
    let masked = "// unsafe in a comment\nfn f() { let s = \"unsafe\"; g(s); }";
    assert!(findings_for(unsafe_audit::run, "rust/src/sampler/mod.rs", masked).is_empty());

    let escaped = "// lint:allow(unsafe-audit): fixture proving the escape hatch\nfn f() { unsafe { q() } }";
    assert!(findings_for(unsafe_audit::run, "rust/src/sampler/mod.rs", escaped).is_empty());
}

// ---------------------------------------------------------------------------
// nondet-guard
// ---------------------------------------------------------------------------

#[test]
fn nondet_guard_flags_hashmap_clock_and_rng_in_critical_modules() {
    let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); let r = thread_rng(); }";
    let out = findings_for(nondet::run, "rust/src/sampler/noise.rs", src);
    assert_eq!(out.len(), 3, "{out:?}");
    assert!(out.iter().all(|f| f.pass == "nondet-guard"));
    assert!(out.iter().any(|f| f.msg.contains("HashMap") && f.msg.contains("BTreeMap")));
    assert!(out.iter().any(|f| f.msg.contains("Instant::now")));
    assert!(out.iter().any(|f| f.msg.contains("thread_rng")));
}

#[test]
fn nondet_guard_is_scoped_and_precise() {
    // Outside the critical modules: no jurisdiction.
    let src = "use std::collections::HashMap;\nfn f() {}";
    assert!(findings_for(nondet::run, "rust/src/coordinator/server/mod.rs", src).is_empty());

    // Test-only code is exempt.
    let test_only = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let _: HashMap<u8, u8> = HashMap::new(); }\n}";
    assert!(findings_for(nondet::run, "rust/src/sampler/mod.rs", test_only).is_empty());

    // `Instant` as a type (no `::now`) is fine — storing admission times
    // for relative ages is deterministic-output-safe.
    let typed = "pub struct S {\n    pub admitted: Instant,\n}\nfn f(s: &S) { let age = s.admitted.elapsed(); use_it(age); }";
    assert!(findings_for(nondet::run, "rust/src/sampler/mod.rs", typed).is_empty());

    // BTreeMap is the blessed replacement.
    let clean = "use std::collections::BTreeMap;\nfn f(m: &BTreeMap<u8, u8>) { m.len(); }";
    assert!(findings_for(nondet::run, "rust/src/sampler/mod.rs", clean).is_empty());

    // The escape hatch works on the same line.
    let escaped = "fn f() {\n    let t = Instant::now(); // lint:allow(nondet-guard): latency gauge only, never serialized\n    use_it(t);\n}";
    assert!(findings_for(nondet::run, "rust/src/sampler/mod.rs", escaped).is_empty());
}

// ---------------------------------------------------------------------------
// panic-guard
// ---------------------------------------------------------------------------

#[test]
fn panic_guard_flags_unwrap_expect_panic() {
    let src = "fn f() { x.unwrap(); y.expect(\"msg\"); panic!(\"boom\"); }";
    let out = findings_for(panic_guard::run, "rust/src/coordinator/server/conn.rs", src);
    assert_eq!(out.len(), 3, "{out:?}");
    assert!(out.iter().all(|f| f.pass == "panic-guard"));
}

#[test]
fn panic_guard_covers_the_federation_router() {
    // `coordinator/federation.rs` is a guarded module: a panic in the
    // route loop takes the front tier's whole fleet state down.
    let out = findings_for(panic_guard::run, "rust/src/coordinator/federation.rs", "fn f() { x.unwrap(); }");
    assert_eq!(out.len(), 1, "{out:?}");
    assert_eq!(out[0].pass, "panic-guard");

    // Test regions and the escape hatch behave exactly as in the
    // connection plane.
    let test_only = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}";
    assert!(findings_for(panic_guard::run, "rust/src/coordinator/federation.rs", test_only).is_empty());

    let escaped = "fn f() {\n    // lint:allow(panic-guard): fixture proving the escape hatch\n    x.unwrap();\n}";
    assert!(findings_for(panic_guard::run, "rust/src/coordinator/federation.rs", escaped).is_empty());
}

#[test]
fn panic_guard_permits_degraded_idioms_tests_and_allows() {
    // The degraded-handling idioms are exactly what the pass pushes
    // toward — they must never be flagged.
    let degraded = "fn f() {\n    let g = a.lock().unwrap_or_else(|e| e.into_inner());\n    let v = b.unwrap_or(0);\n    let w = c.unwrap_or_default();\n    unreachable!(\"statically matched above\");\n}";
    assert!(findings_for(panic_guard::run, "rust/src/coordinator/server/conn.rs", degraded).is_empty());

    // Outside the guarded modules: no jurisdiction.
    assert!(findings_for(panic_guard::run, "rust/src/sampler/mod.rs", "fn f() { x.unwrap(); }").is_empty());

    // Test code may panic freely.
    let test_only = "#[test]\nfn t() { x.unwrap(); }";
    assert!(findings_for(panic_guard::run, "rust/src/coordinator/server/conn.rs", test_only).is_empty());

    // Escape on the line above.
    let escaped = "fn f() {\n    // lint:allow(panic-guard): fixture proving the escape hatch\n    x.unwrap();\n}";
    assert!(findings_for(panic_guard::run, "rust/src/coordinator/server/conn.rs", escaped).is_empty());
}

// ---------------------------------------------------------------------------
// lock-discipline
// ---------------------------------------------------------------------------

#[test]
fn lock_discipline_flags_reverse_nesting() {
    let src = "fn f(p: &P) {\n    let m = p.metrics.lock().unwrap_or_else(|e| e.into_inner());\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    use_both(m, s);\n}";
    let out = findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", src);
    assert_eq!(out.len(), 1, "{out:?}");
    assert_eq!(out[0].pass, "lock-discipline");
    assert_eq!(out[0].line, 3);
    assert!(out[0].msg.contains("`state`") && out[0].msg.contains("`metrics`"));
}

#[test]
fn lock_discipline_accepts_declared_order_drop_and_scopes() {
    // Declared order: state before metrics.
    let ordered = "fn f(p: &P) {\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    let m = p.metrics.lock().unwrap_or_else(|e| e.into_inner());\n    use_both(s, m);\n}";
    assert!(findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", ordered).is_empty());

    // An explicit drop releases the hold.
    let dropped = "fn f(p: &P) {\n    let m = p.metrics.lock().unwrap_or_else(|e| e.into_inner());\n    drop(m);\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    use_it(s);\n}";
    assert!(findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", dropped).is_empty());

    // A block-scoped guard dies with its block.
    let scoped = "fn f(p: &P) {\n    {\n        let m = p.metrics.lock().unwrap_or_else(|e| e.into_inner());\n        use_it(m);\n    }\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    use_it(s);\n}";
    assert!(findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", scoped).is_empty());

    // An unbound temporary guard is released at end of statement.
    let stmt_temp = "fn f(p: &P) {\n    p.metrics.lock().unwrap_or_else(|e| e.into_inner()).record_error();\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    use_it(s);\n}";
    assert!(findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", stmt_temp).is_empty());

    // Out of the scoped modules: no jurisdiction.
    let src = "fn f(p: &P) {\n    let m = p.metrics.lock().unwrap();\n    let s = p.state.lock().unwrap();\n    use_both(m, s);\n}";
    assert!(findings_for(lock_order::run, "rust/src/sampler/mod.rs", src).is_empty());

    // The escape hatch.
    let escaped = "fn f(p: &P) {\n    let m = p.metrics.lock().unwrap_or_else(|e| e.into_inner());\n    // lint:allow(lock-discipline): shutdown path, all other threads joined\n    let s = p.state.lock().unwrap_or_else(|e| e.into_inner());\n    use_both(m, s);\n}";
    assert!(findings_for(lock_order::run, "rust/src/coordinator/server/worker.rs", escaped).is_empty());
}

// ---------------------------------------------------------------------------
// doc-parity
// ---------------------------------------------------------------------------

/// A scratch docs dir for doc-parity fixtures (it reads ARCHITECTURE.md /
/// PROTOCOL.md from disk). Distinct per test so parallel runs don't race.
fn docs_root(tag: &str, arch: &str, proto: &str) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!("predsamp-lint-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(root.join("docs")).unwrap();
    std::fs::write(root.join("docs/ARCHITECTURE.md"), arch).unwrap();
    std::fs::write(root.join("docs/PROTOCOL.md"), proto).unwrap();
    root
}

#[test]
fn doc_parity_cross_checks_docs_cli_and_keys() {
    let root = docs_root("parity", "knob table: `port` documented\n", "keys: \"requests\" documented\n");
    let files = vec![
        SourceFile::from_source(
            "rust/src/coordinator/config.rs",
            "pub struct ServeConfig {\n    pub port: u16,\n    pub max_batch: usize,\n}",
        ),
        // The CLI parses `port` and `max_batch` — so `max_batch` is only
        // missing from the knob table, not from the CLI.
        SourceFile::from_source("rust/src/main.rs", "fn main() { let cfg = ServeConfig { port: 1, max_batch: 2 }; }"),
        SourceFile::from_source(
            "rust/src/coordinator/metrics.rs",
            "impl Metrics {\n    pub fn snapshot(&self) -> Value {\n        Value::obj(vec![(\"requests\", Value::num(1.0)), (\"mystery_key\", Value::num(2.0))])\n    }\n    pub fn worker_value(&self) -> Value {\n        Value::obj(vec![])\n    }\n}",
        ),
        SourceFile::from_source("rust/src/coordinator/server/conn.rs", "fn value() {}"),
        SourceFile::from_source("rust/src/coordinator/server/mod.rs", "fn metrics_response() {}"),
    ];
    let mut out = Vec::new();
    doc_parity::run(&Ctx { files: &files, root: &root }, &mut out);
    let msgs: Vec<&str> = out.iter().map(|f| f.msg.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("max_batch") && m.contains("ARCHITECTURE")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("mystery_key") && m.contains("PROTOCOL")), "{msgs:?}");
    assert!(!msgs.iter().any(|m| m.contains("ServeConfig::port")), "documented+parsed field must be clean: {msgs:?}");
    assert!(!msgs.iter().any(|m| m.contains("\"requests\"")), "documented key must be clean: {msgs:?}");
    assert!(!msgs.iter().any(|m| m.contains("max_batch") && m.contains("CLI")), "parsed field must pass the CLI check: {msgs:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn doc_parity_covers_the_federation_router() {
    let root = docs_root("fed-parity", "knob table: `addr` and `max_hops` documented\n", "keys: \"forwards\" documented\n");
    let files = vec![
        SourceFile::from_source(
            "rust/src/coordinator/federation.rs",
            "pub struct RouterConfig {\n    pub addr: String,\n    pub max_hops: usize,\n}\nfn fleet_value() -> Value {\n    Value::obj(vec![(\"forwards\", Value::num(1.0)), (\"stray_gauge\", Value::num(2.0))])\n}\nfn router_metrics_response() -> Value {\n    Value::obj(vec![])\n}",
        ),
        // The CLI's `route` arm parses `addr` but forgot `max_hops` — so
        // `max_hops` is only missing from the CLI, not the knob table.
        SourceFile::from_source("rust/src/main.rs", "fn main() { let cfg = RouterConfig { addr: a }; }"),
    ];
    let mut out = Vec::new();
    doc_parity::run(&Ctx { files: &files, root: &root }, &mut out);
    let msgs: Vec<&str> = out.iter().map(|f| f.msg.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("RouterConfig::max_hops") && m.contains("CLI")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("stray_gauge") && m.contains("PROTOCOL")), "{msgs:?}");
    assert!(!msgs.iter().any(|m| m.contains("RouterConfig::addr")), "documented+parsed field must be clean: {msgs:?}");
    assert!(!msgs.iter().any(|m| m.contains("\"forwards\"")), "documented fleet key must be clean: {msgs:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn doc_parity_reports_blind_spots_instead_of_passing_silently() {
    let root = docs_root("blind", "", "");
    let files: Vec<SourceFile> = Vec::new();
    let mut out = Vec::new();
    doc_parity::run(&Ctx { files: &files, root: &root }, &mut out);
    assert!(!out.is_empty());
    assert!(out.iter().all(|f| f.msg.contains("blind")), "{out:?}");
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// allow-hygiene
// ---------------------------------------------------------------------------

#[test]
fn allow_hygiene_polices_escapes() {
    let files = vec![SourceFile::from_source(
        "rust/src/x.rs",
        "// lint:allow(no-such-pass): whatever\n// lint:allow(panic-guard):\n// lint:allow(nondet-guard): a real written reason\nfn f() {}",
    )];
    let mut out = Vec::new();
    passes::allow_hygiene(&Ctx { files: &files, root: Path::new(".") }, &mut out);
    assert_eq!(out.len(), 2, "{out:?}");
    assert!(out[0].msg.contains("unknown pass"));
    assert_eq!(out[0].line, 1);
    assert!(out[1].msg.contains("without a written reason"));
    assert_eq!(out[1].line, 2);
}

// ---------------------------------------------------------------------------
// Report rendering and walker determinism
// ---------------------------------------------------------------------------

#[test]
fn report_renders_text_and_json() {
    let mut r = Report {
        findings: vec![
            Finding::new("panic-guard", "b.rs", 2, "second in sort order"),
            Finding::new("unsafe-audit", "a.rs", 9, "needs \"quotes\" escaped"),
        ],
        files_scanned: 2,
        passes: vec!["unsafe-audit", "panic-guard"],
    };
    r.sort();
    assert_eq!(r.findings[0].path, "a.rs", "findings sort by path first");
    let text = r.render_text();
    assert!(text.contains("a.rs:9: [unsafe-audit]"), "{text}");
    assert!(text.contains("2 findings across 2 files"), "{text}");
    let json = r.render_json();
    assert!(json.contains("\"ok\":false"), "{json}");
    assert!(json.contains("needs \\\"quotes\\\" escaped"), "{json}");

    let empty = Report { findings: Vec::new(), files_scanned: 1, passes: vec!["unsafe-audit"] };
    assert!(empty.render_json().contains("\"ok\":true"));
    assert!(empty.render_text().contains("0 findings"));
}

#[test]
fn walker_is_sorted_and_repo_relative() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = walker::rust_sources(root);
    assert!(files.len() > 10, "expected a real source tree, got {} files", files.len());
    assert!(files.iter().all(|f| f.path.starts_with("rust/src/")));
    assert!(files.iter().any(|f| f.path == "rust/src/lib.rs"));
    let paths: Vec<&String> = files.iter().map(|f| &f.path).collect();
    let mut sorted = paths.clone();
    sorted.sort();
    assert_eq!(paths, sorted, "walker output must be deterministic");
}

#[test]
fn find_repo_root_walks_up() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let nested = root.join("rust").join("src").join("analysis");
    assert_eq!(walker::find_repo_root(&nested), Some(root.to_path_buf()));
}

// ---------------------------------------------------------------------------
// The gate: the repo passes its own linter
// ---------------------------------------------------------------------------

#[test]
fn repo_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_repo(root);
    assert!(report.findings.is_empty(), "repo lint findings:\n{}", report.render_text());
}
