//! Federation end-to-end tests: real coordinator *child processes*
//! behind an in-process front-tier router, with deterministic
//! process-level fault injection.
//!
//! The harness spawns `predsamp serve` children (the same binary under
//! test, via `CARGO_BIN_EXE_predsamp`) on ephemeral loopback ports over
//! a shared mock manifest, parses each child's "serving on" banner to
//! learn its address, captures its logs, and kills it on drop. A
//! [`FaultPlan`] scripts the failure: after `kill_after_jobs` streamed
//! job events have reached the client, the victim process is killed —
//! and optionally restarted on its old port to exercise re-admission.
//!
//! The acceptance gate mirrors the worker pool's: a fleet of three
//! processes must be bitwise-identical to a single process, including
//! with a backend killed mid-stream — re-homed requests replay on a
//! survivor, replayed events deduplicate, and the client sees zero
//! failures.

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::federation::{spawn_router, RouterConfig, RouterHandle};
use predsamp::coordinator::server::{spawn, Client, ServerHandle};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::json::Value;
use std::collections::BTreeMap;
use std::io::BufRead;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Write the shared two-model mock manifest (the same family
/// `server_test.rs` serves, so results are comparable across suites)
/// into a per-test temp dir and return it.
fn mock_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("predsamp-fed-{tag}-{}", std::process::id()));
    let mut a = MockModelSpec::new("mock_a", 11);
    a.batches = vec![1, 4];
    let mut b = MockModelSpec::new("mock_b", 7);
    b.channels = 1;
    b.pixels = 16;
    b.categories = 4;
    b.strength = 1.5;
    b.batches = vec![1, 4];
    write_mock_manifest(&dir, &[a, b]).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Child-process harness
// ---------------------------------------------------------------------------

/// One `predsamp serve` coordinator child process: spawned on a loopback
/// address, banner-parsed for the bound port, logs captured, killed on
/// drop so a panicking test never leaks a serving process.
struct ChildServer {
    child: Child,
    addr: SocketAddr,
    log: Arc<Mutex<Vec<String>>>,
    drains: Vec<std::thread::JoinHandle<()>>,
}

impl ChildServer {
    /// Spawn a child on `addr` (`127.0.0.1:0` for ephemeral) over the
    /// mock manifest in `dir`. Returns the captured log on failure so a
    /// child that dies at startup explains itself.
    fn spawn(dir: &Path, addr: &str) -> Result<ChildServer, String> {
        let mut child = Command::new(env!("CARGO_BIN_EXE_predsamp"))
            .args(["serve", "--addr", addr, "--engine-threads", "2", "--max-wait-ms", "5"])
            .env("PREDSAMP_ARTIFACTS", dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .map_err(|e| format!("spawning predsamp serve: {e}"))?;
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut out = std::io::BufReader::new(child.stdout.take().expect("stdout piped"));
        // The banner is the readiness signal: everything before it is
        // startup chatter, and EOF before it means the child died (e.g.
        // its port was taken on a restart).
        let mut bound = None;
        let mut line = String::new();
        loop {
            line.clear();
            match out.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            log.lock().unwrap().push(line.trim_end().to_string());
            if let Some(rest) = line.split("serving on ").nth(1) {
                bound = rest.split_whitespace().next().and_then(|a| a.parse::<SocketAddr>().ok());
                break;
            }
        }
        let Some(addr) = bound else {
            let _ = child.kill();
            let _ = child.wait();
            return Err(format!("child exited before its serving banner; log: {:?}", log.lock().unwrap()));
        };
        // Keep both pipes drained so the child never blocks on a full
        // pipe; every line lands in the shared captured log.
        let mut drains = Vec::new();
        for reader in [Box::new(out) as Box<dyn BufRead + Send>, Box::new(std::io::BufReader::new(child.stderr.take().expect("stderr piped")))] {
            let log = Arc::clone(&log);
            drains.push(std::thread::spawn(move || {
                for l in reader.lines() {
                    match l {
                        Ok(l) => log.lock().unwrap().push(l),
                        Err(_) => break,
                    }
                }
            }));
        }
        Ok(ChildServer { child, addr, log, drains })
    }

    /// Kill the process outright (SIGKILL — no graceful shutdown, this
    /// is the fault being injected) and reap it.
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    fn logs(&self) -> Vec<String> {
        self.log.lock().unwrap().clone()
    }
}

impl Drop for ChildServer {
    fn drop(&mut self) {
        self.kill();
        for j in self.drains.drain(..) {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet harness + fault plan
// ---------------------------------------------------------------------------

/// Deterministic process-level fault script for one scenario: kill the
/// victim backend once `kill_after_jobs` streamed job events have
/// reached the client, then (optionally) restart it on its old port so
/// the prober can re-admit it.
struct FaultPlan {
    kill_after_jobs: usize,
    restart: bool,
}

/// A federation under test: N coordinator child processes and the
/// in-process router fronting them (fast probe cadence so death and
/// re-admission are observed within test timeouts).
struct Fleet {
    dir: PathBuf,
    children: Vec<Option<ChildServer>>,
    router: Option<RouterHandle>,
}

/// Spawn `n` child coordinators plus a router over them.
fn spawn_fleet(tag: &str, n: usize) -> Fleet {
    spawn_fleet_cfg(tag, n, |_| {})
}

/// As [`spawn_fleet`], letting the test adjust the router config (the
/// backend list is filled in after the children have bound).
fn spawn_fleet_cfg(tag: &str, n: usize, tweak: impl FnOnce(&mut RouterConfig)) -> Fleet {
    let dir = mock_dir(tag);
    let children: Vec<Option<ChildServer>> = (0..n).map(|_| Some(ChildServer::spawn(&dir, "127.0.0.1:0").expect("child spawns"))).collect();
    let mut cfg = RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: children.iter().map(|c| c.as_ref().unwrap().addr.to_string()).collect(),
        probe_interval: Duration::from_millis(50),
        probe_timeout: Duration::from_secs(2),
        probe_fails: 2,
        ..RouterConfig::default()
    };
    tweak(&mut cfg);
    let router = spawn_router(cfg).expect("router spawns");
    Fleet { dir, children, router: Some(router) }
}

impl Fleet {
    fn addr(&self) -> SocketAddr {
        self.router.as_ref().unwrap().addr
    }

    /// Inject the fault: SIGKILL backend `i`.
    fn kill(&mut self, i: usize) {
        if let Some(mut c) = self.children[i].take() {
            c.kill();
        }
    }

    /// Restart backend `i` on the port it had before the kill (retried:
    /// the OS may briefly hold the port after the SIGKILL).
    fn restart(&mut self, i: usize, addr: SocketAddr) {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match ChildServer::spawn(&self.dir, &addr.to_string()) {
                Ok(c) => {
                    self.children[i] = Some(c);
                    return;
                }
                Err(e) if Instant::now() < deadline => {
                    eprintln!("restart of backend {i} on {addr} not up yet: {e}");
                    std::thread::sleep(Duration::from_millis(200));
                }
                Err(e) => panic!("backend {i} never came back on {addr}: {e}"),
            }
        }
    }

    fn stop(mut self) {
        if let Some(r) = self.router.take() {
            r.stop();
        }
        self.children.clear();
    }
}

/// Poll the router's `metrics` op until `pred` holds on the `fleet`
/// section (probe results land asynchronously). Returns the last fleet
/// object either way; the caller asserts on it.
fn fleet_eventually(addr: &SocketAddr, pred: impl Fn(&Value) -> bool) -> Value {
    let mut last = Value::Null;
    for _ in 0..200 {
        let mut c = Client::connect(addr).unwrap();
        let m = c.call(r#"{"op":"metrics"}"#).unwrap();
        last = m.get("metrics").get("fleet").clone();
        if pred(&last) {
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    last
}

/// Backend index currently owning `model`, observed through the fleet
/// metrics after a warm-up request (probes never touch the forwarding
/// counters, so exactly one backend has forwarded anything).
fn owner_of(addr: &SocketAddr, model: &str) -> usize {
    let mut c = Client::connect(addr).unwrap();
    let r = c.call(&format!(r#"{{"op":"sample","model":"{model}","method":"fpi","n":1,"seed":900,"return_samples":false}}"#)).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(true), "warm-up request must succeed: {r}");
    let fleet = c.call(r#"{"op":"metrics"}"#).unwrap().get("metrics").get("fleet").clone();
    let backends = fleet.get("backends").as_arr().unwrap();
    backends
        .iter()
        .position(|b| b.get("forwarded").as_i64().unwrap_or(0) >= 1)
        .expect("the warm-up forward must be counted somewhere")
}

// ---------------------------------------------------------------------------
// Reference + request mix
// ---------------------------------------------------------------------------

/// A single-process reference server over the same mock manifest: the
/// bitwise ground truth every fleet topology must reproduce.
fn single_process(tag: &str) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        engine_threads: 2,
        ..ServeConfig::default()
    };
    spawn(mock_dir(tag), cfg).expect("reference server spawns")
}

fn samples_of(v: &Value) -> Vec<Vec<i32>> {
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v}");
    predsamp::coordinator::protocol::parse_samples(v.get("samples")).expect("samples field")
}

/// The mixed request set used for every A/B comparison: both models,
/// two methods, and all three delivery modes (plain / streamed /
/// framed) across distinct seeds.
fn mixed_request(i: usize) -> String {
    let model = if i % 2 == 0 { "mock_a" } else { "mock_b" };
    let method = if i % 3 == 0 { "fpi" } else { "zeros" };
    let opt = match i % 3 {
        1 => r#","stream":true"#,
        2 => r#","frame":true"#,
        _ => "",
    };
    format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":3,"seed":{i},"id":{i}{opt}}}"#)
}

/// Issue requests `0..n` pipelined on one connection and return the
/// final samples in request order, skipping streamed events.
fn run_mix(addr: &SocketAddr, n: usize) -> Vec<Vec<Vec<i32>>> {
    let mut c = Client::connect(addr).unwrap();
    for i in 0..n {
        c.send_line(&mixed_request(i)).unwrap();
    }
    let mut by_id: BTreeMap<i64, Vec<Vec<i32>>> = BTreeMap::new();
    while by_id.len() < n {
        let m = c.read_message().unwrap();
        if m.get("stream").as_bool() == Some(true) {
            continue;
        }
        let id = m.get("id").as_i64().expect("finals echo their request id");
        assert!(by_id.insert(id, samples_of(&m)).is_none(), "duplicate final for id {id}");
    }
    (0..n).map(|i| by_id.remove(&(i as i64)).unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn router_answers_locally_and_probes_the_fleet_healthy() {
    let fleet = spawn_fleet("health", 3);
    let mut c = Client::connect(&fleet.addr()).unwrap();
    // Ping and metrics are the router's own (one-hop answers).
    let pong = c.call(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("pong").as_bool(), Some(true), "{pong}");
    let m = c.call(r#"{"op":"metrics"}"#).unwrap();
    let metrics = m.get("metrics");
    assert!(metrics.get("edge").get("conn_threads").as_i64().is_some(), "the router has its own edge section: {m}");
    let fleet_v = metrics.get("fleet");
    assert_eq!(fleet_v.get("fleet_placement").as_str(), Some("replicate"), "{m}");
    assert_eq!(fleet_v.get("backends").as_arr().unwrap().len(), 3, "{m}");
    // The prober converges every backend to healthy.
    let f = fleet_eventually(&fleet.addr(), |f| {
        f.get("live_backends").as_i64() == Some(3)
            && f.get("backends").as_arr().unwrap().iter().all(|b| b.get("health").as_str() == Some("healthy"))
    });
    assert_eq!(f.get("live_backends").as_i64(), Some(3), "probes must converge: {f}");
    // info is forwarded to a backend: the answer is an engine answer.
    let info = c.call(r#"{"op":"info"}"#).unwrap();
    assert_eq!(info.get("engine_workers").as_i64(), Some(2), "info must come from a backend's pool: {info}");
    fleet.stop();
}

#[test]
fn fleet_of_three_matches_single_process_bitwise() {
    // THE federation acceptance gate: the same mixed pipelined stream
    // (both models, plain/streamed/framed) against a 3-process fleet
    // and against one process must be bitwise-identical — placement
    // across processes, re-striped ids, and proxied delivery are all
    // invisible in the payload.
    const N: usize = 12;
    let reference = {
        let server = single_process("ab-single");
        let out = run_mix(&server.addr, N);
        server.stop();
        out
    };
    let fleet = spawn_fleet("ab-fleet", 3);
    let federated = run_mix(&fleet.addr(), N);
    assert_eq!(federated, reference, "a federated fleet must be bitwise-identical to a single process");
    assert!(federated.iter().all(|s| s.len() == 3));
    // The namespaces actually spread: with two models and rendezvous
    // placement, every forward is accounted to some backend and the
    // totals add up.
    let mut c = Client::connect(&fleet.addr()).unwrap();
    let f = c.call(r#"{"op":"metrics"}"#).unwrap().get("metrics").get("fleet").clone();
    let per_backend: i64 = f.get("backends").as_arr().unwrap().iter().map(|b| b.get("forwarded").as_i64().unwrap()).sum();
    assert_eq!(per_backend, f.get("forwards").as_i64().unwrap(), "per-backend forwards must sum to the total: {f}");
    assert_eq!(f.get("forwards").as_i64(), Some(N as i64), "every request was forwarded exactly once: {f}");
    fleet.stop();
}

#[test]
fn fault_plan_kill_mid_stream_stays_bitwise_with_zero_client_failures() {
    // The fault-injection gate: streamed requests are in flight when the
    // owning backend is SIGKILLed. The router re-homes the namespace,
    // re-submits the stored manifests on a survivor, deduplicates
    // replayed events, and the client sees every job exactly once,
    // bitwise-equal to a single process — zero visible failures.
    const REQS: usize = 4;
    const JOBS: usize = 4;
    let req = |i: usize| format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":{JOBS},"seed":{i},"id":{i},"stream":true}}"#);
    let reference: Vec<Vec<Vec<i32>>> = {
        let server = single_process("kill-single");
        let mut c = Client::connect(&server.addr).unwrap();
        let out = (0..REQS).map(|i| samples_of(&c.call(&req(i)).unwrap())).collect();
        server.stop();
        out
    };
    let mut fleet = spawn_fleet("kill-fleet", 3);
    let plan = FaultPlan { kill_after_jobs: 3, restart: false };
    let victim = owner_of(&fleet.addr(), "mock_a");
    let mut c = Client::connect(&fleet.addr()).unwrap();
    for i in 0..REQS {
        c.send_line(&req(i)).unwrap();
    }
    let mut killed = false;
    let mut streamed = 0usize;
    let mut events: BTreeMap<i64, Vec<i64>> = BTreeMap::new();
    let mut finals: BTreeMap<i64, Vec<Vec<i32>>> = BTreeMap::new();
    while finals.len() < REQS {
        let m = c.read_message().unwrap();
        let id = m.get("id").as_i64().expect("every reply echoes its id");
        if m.get("stream").as_bool() == Some(true) {
            streamed += 1;
            events.entry(id).or_default().push(m.get("job").as_i64().unwrap());
            if streamed >= plan.kill_after_jobs && !killed {
                fleet.kill(victim);
                killed = true;
            }
            continue;
        }
        assert!(finals.insert(id, samples_of(&m)).is_none(), "duplicate final for id {id}");
    }
    assert!(killed, "the fault plan must have fired mid-stream");
    // Zero client-visible failures and bitwise equality, kill or no kill.
    for i in 0..REQS {
        assert_eq!(finals[&(i as i64)], reference[i], "request {i} diverged after the mid-stream kill");
    }
    // Each job streamed exactly once: replayed events after the re-home
    // are deduplicated by job index.
    for (id, jobs) in &events {
        let mut sorted = jobs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), jobs.len(), "request {id} saw a duplicate streamed job: {jobs:?}");
    }
    // A post-kill request still routes: the dead backend's namespace
    // re-homed to a survivor (conn-error detection, no probe needed).
    let again = samples_of(&c.call(&req(0)).unwrap());
    assert_eq!(again, reference[0], "the re-homed namespace must keep serving bitwise-identically");
    let f = fleet_eventually(&fleet.addr(), |f| {
        f.get("backends").as_arr().unwrap()[victim].get("health").as_str() == Some("dead")
    });
    assert_eq!(f.get("backends").as_arr().unwrap()[victim].get("health").as_str(), Some("dead"), "{f}");
    assert_eq!(f.get("live_backends").as_i64(), Some(2), "{f}");
    fleet.stop();
}

#[test]
fn fault_plan_restart_readmits_the_backend() {
    // The re-admission half of the fault plan: a killed backend brought
    // back on its old port turns healthy again after one successful
    // probe, and the fleet keeps serving bitwise-identically throughout.
    // Its old namespaces do NOT move back (stability) — only fresh
    // routing may use it.
    let reference: Vec<Vec<Vec<i32>>> = {
        let server = single_process("restart-single");
        let mut c = Client::connect(&server.addr).unwrap();
        let out = (0..4).map(|i| samples_of(&c.call(&mixed_request(3 * i)).unwrap())).collect();
        server.stop();
        out
    };
    let mut fleet = spawn_fleet("restart-fleet", 3);
    let plan = FaultPlan { kill_after_jobs: 0, restart: true };
    assert!(plan.restart);
    let victim = owner_of(&fleet.addr(), "mock_b");
    let victim_addr = fleet.children[victim].as_ref().unwrap().addr;
    fleet.kill(victim);
    // Down: the prober notices within probe_fails * probe_interval.
    let f = fleet_eventually(&fleet.addr(), |f| f.get("live_backends").as_i64() == Some(2));
    assert_eq!(f.get("live_backends").as_i64(), Some(2), "{f}");
    // The fleet still serves the victim's namespace, bitwise-identically.
    let mut c = Client::connect(&fleet.addr()).unwrap();
    for (k, want) in reference.iter().enumerate() {
        assert_eq!(&samples_of(&c.call(&mixed_request(3 * k)).unwrap()), want, "request {k} diverged while a backend was down");
    }
    // Back up on the same port: re-admitted by the next probe.
    fleet.restart(victim, victim_addr);
    let f = fleet_eventually(&fleet.addr(), |f| f.get("live_backends").as_i64() == Some(3));
    assert_eq!(f.get("live_backends").as_i64(), Some(3), "restarted backend must be re-admitted: {f}");
    for (k, want) in reference.iter().enumerate() {
        assert_eq!(&samples_of(&c.call(&mixed_request(3 * k)).unwrap()), want, "request {k} diverged after re-admission");
    }
    let logs = fleet.children[victim].as_ref().unwrap().logs();
    assert!(logs.iter().any(|l| l.contains("serving on")), "restarted child must have banner-logged: {logs:?}");
    fleet.stop();
}

#[test]
fn hop_limit_kills_forwarding_cycles_through_two_tiers() {
    // Two stacked routers (client → outer → inner → process) serve
    // normally — the hop count advances per tier and stays under the
    // limit. With the inner tier's max_hops forced to 1, the outer
    // tier's forward (hop 1) dies there with a hop-limit error instead
    // of looping, and the error propagates back like any reply.
    let dir = mock_dir("hops");
    let child = ChildServer::spawn(&dir, "127.0.0.1:0").expect("child spawns");
    let inner = spawn_router(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![child.addr.to_string()],
        probe_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    })
    .expect("inner router spawns");
    let outer = spawn_router(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![inner.addr.to_string()],
        probe_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    })
    .expect("outer router spawns");
    // Two hops, bitwise-identical to the direct path.
    let req = r#"{"op":"sample","model":"mock_a","method":"fpi","n":2,"seed":6}"#;
    let mut direct = Client::connect(&child.addr).unwrap();
    let want = samples_of(&direct.call(req).unwrap());
    let mut c = Client::connect(&outer.addr).unwrap();
    assert_eq!(samples_of(&c.call(req).unwrap()), want, "two router tiers must be bitwise-invisible");
    // A pre-inflated hop count (a cycle in flight) is refused at the
    // first tier whose budget it exhausts.
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":1,"seed":0,"hop":9}"#).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "{r}");
    assert!(r.get("error").as_str().unwrap().contains("hop limit"), "{r}");
    outer.stop();
    inner.stop();
    // An inner tier with a one-hop budget rejects the outer tier's
    // forward: the cycle guard works across real processes, not just
    // inside one.
    let inner = spawn_router(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![child.addr.to_string()],
        probe_interval: Duration::from_millis(50),
        max_hops: 1,
        ..RouterConfig::default()
    })
    .expect("strict inner router spawns");
    let outer = spawn_router(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![inner.addr.to_string()],
        probe_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    })
    .expect("outer router spawns");
    let mut c = Client::connect(&outer.addr).unwrap();
    let r = c.call(req).unwrap();
    assert_eq!(r.get("ok").as_bool(), Some(false), "a 1-hop inner budget must refuse the second tier: {r}");
    assert!(r.get("error").as_str().unwrap().contains("hop limit"), "{r}");
    // Direct clients of the strict tier are under budget and still served.
    let mut c = Client::connect(&inner.addr).unwrap();
    assert_eq!(samples_of(&c.call(req).unwrap()), want, "hop 0 is under a 1-hop budget");
    outer.stop();
    inner.stop();
}

#[test]
fn pinned_fleet_placement_keeps_namespaces_on_their_backends() {
    // Fleet-level pinning mirrors worker-level pinning one tier up:
    // mock_a may only live on backend 0, mock_b only on backend 1, and
    // the forwarding counters prove nothing leaked — while samples stay
    // bitwise-identical to a single process.
    use predsamp::coordinator::placement::PlacementKind;
    let reference: Vec<Vec<Vec<i32>>> = {
        let server = single_process("pin-single");
        let mut c = Client::connect(&server.addr).unwrap();
        let out = (0..4).map(|i| samples_of(&c.call(&mixed_request(i)).unwrap())).collect();
        server.stop();
        out
    };
    let fleet = spawn_fleet_cfg("pin-fleet", 3, |cfg| {
        cfg.fleet_placement = PlacementKind::Pinned(vec![("mock_a".into(), vec![0]), ("mock_b".into(), vec![1])]);
    });
    let mut c = Client::connect(&fleet.addr()).unwrap();
    for (i, want) in reference.iter().enumerate() {
        assert_eq!(&samples_of(&c.call(&mixed_request(i)).unwrap()), want, "request {i} diverged under fleet pinning");
    }
    let f = c.call(r#"{"op":"metrics"}"#).unwrap().get("metrics").get("fleet").clone();
    let backends = f.get("backends").as_arr().unwrap();
    assert_eq!(f.get("fleet_placement").as_str(), Some("pinned"), "{f}");
    assert!(backends[0].get("forwarded").as_i64().unwrap() >= 1, "mock_a must land on its pin: {f}");
    assert!(backends[1].get("forwarded").as_i64().unwrap() >= 1, "mock_b must land on its pin: {f}");
    assert_eq!(backends[2].get("forwarded").as_i64(), Some(0), "the unpinned backend must see nothing: {f}");
    fleet.stop();
}
