//! `predsamp-lint` driver: `cargo run --bin lint [-- --json PATH] [ROOT]`.
//!
//! Lints the repo (found by walking up from the current directory to the
//! nearest `Cargo.toml`, or rooted at `ROOT` if given), prints findings
//! as `path:line: [pass] message`, writes the machine-readable report to
//! `target/lint-report.json` (or `--json PATH`), and exits nonzero iff
//! there are findings — so CI can gate on it directly.

use predsamp::analysis::{lint_repo, walker};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json_path: Option<PathBuf> = None;
    let mut root_arg: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json_path = args.next().map(PathBuf::from),
            "--help" | "-h" => {
                println!("usage: lint [--json PATH] [ROOT]\nruns the predsamp repo lint passes; exits nonzero on findings");
                return ExitCode::SUCCESS;
            }
            other => root_arg = Some(PathBuf::from(other)),
        }
    }

    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let Some(root) = root_arg.or_else(|| walker::find_repo_root(&cwd)) else {
        eprintln!("lint: no Cargo.toml above {} — pass the repo root explicitly", cwd.display());
        return ExitCode::FAILURE;
    };

    let report = lint_repo(&root);
    print!("{}", report.render_text());

    let json = json_path.unwrap_or_else(|| root.join("target").join("lint-report.json"));
    if let Some(dir) = json.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&json, report.render_json()) {
        Ok(()) => println!("lint: wrote {}", json.display()),
        Err(e) => eprintln!("lint: could not write {}: {e}", json.display()),
    }

    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
