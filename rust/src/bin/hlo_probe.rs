// Debug tool, two modes:
//
//   hlo_probe HLO XBIN B D      run an HLO-text artifact with i32 input
//                               from a .bin file, dump the tuple outputs
//                               as f32 .bin files for python comparison
//   hlo_probe --manifest DIR    print each model's exported step-shape
//                               grid (batch x span x flavor) from the
//                               manifest, failing if any model's batch
//                               lacks the full-shape fore anchor the
//                               variant catalog requires
use anyhow::{bail, Result};
use predsamp::runtime::artifact::Manifest;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 {
        bail!("usage: hlo_probe HLO XBIN B D | hlo_probe --manifest DIR");
    }
    if args[1] == "--manifest" {
        return manifest_grid(&args[2]);
    }
    if args.len() < 5 {
        bail!("usage: hlo_probe HLO XBIN B D");
    }
    let (hlo, xbin, b, d) = (&args[1], &args[2], args[3].parse::<i64>()?, args[4].parse::<i64>()?);
    let exe = predsamp::runtime::client::compile_hlo_text(hlo)?;
    let bytes = std::fs::read(xbin)?;
    let x: Vec<i32> = bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0],c[1],c[2],c[3]])).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[b, d])?;
    let res = exe.execute::<xla::Literal>(&[lit])?;
    let tup = res[0][0].to_literal_sync()?;
    let parts = tup.to_tuple()?;
    for (i, p) in parts.iter().enumerate() {
        let v: Vec<f32> = p.to_vec()?;
        let mut out = Vec::with_capacity(v.len()*4);
        for f in &v { out.extend_from_slice(&f.to_le_bytes()); }
        std::fs::write(format!("{}.out{}.bin", xbin, i), out)?;
        println!("out{} len {}", i, v.len());
    }
    Ok(())
}

/// Print the `batch x span x flavor` step grid each model exports —
/// the shapes a `VariantCatalog` would serve — and verify every batch
/// has its full-shape fore anchor (the catalog's fallback invariant).
fn manifest_grid(dir: &str) -> Result<()> {
    let man = Manifest::load(std::path::Path::new(dir))?;
    let mut missing = Vec::new();
    for (name, info) in &man.models {
        // (batch, span, has_fore) rows; mock models expose the grid the
        // engine synthesizes from MockSpec {batches, spans}, compiled
        // models the roles actually present in the file map.
        let mut grid: Vec<(usize, usize, bool)> = match &info.mock {
            Some(mock) => {
                let mut g = Vec::new();
                for &b in &info.step_batch_sizes() {
                    g.push((b, info.dim, true));
                    g.push((b, info.dim, false));
                    for &s in &mock.spans {
                        if s < info.dim {
                            g.push((b, s, true));
                            g.push((b, s, false));
                        }
                    }
                }
                g
            }
            None => info.step_variant_roles().into_iter().map(|(_, b, s, f)| (b, s, f)).collect(),
        };
        grid.sort_unstable();
        grid.dedup();
        let tag = if info.mock.is_some() { " (mock)" } else { "" };
        println!("{name}{tag}: d={} k={} shapes={}", info.dim, info.categories, grid.len());
        for &(b, s, fore) in &grid {
            let flavor = if fore { "logp+fore" } else { "logp-only" };
            let full = if s == info.dim { " [full]" } else { "" };
            println!("  b{b} s{s} {flavor}{full}");
        }
        let mut batches: Vec<usize> = grid.iter().map(|&(b, _, _)| b).collect();
        batches.sort_unstable();
        batches.dedup();
        for b in batches {
            if !grid.iter().any(|&(gb, gs, gf)| gb == b && gs == info.dim && gf) {
                missing.push(format!("{name}: batch {b} has no full-shape fore anchor"));
            }
        }
    }
    if !missing.is_empty() {
        for m in &missing {
            eprintln!("error: {m}");
        }
        bail!("{} batch grid(s) lack the full-shape anchor the variant catalog requires", missing.len());
    }
    Ok(())
}
