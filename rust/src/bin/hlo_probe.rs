// Debug tool: run an HLO-text artifact with i32 input from a .bin file,
// dump the tuple outputs as f32 .bin files for python comparison.
use anyhow::Result;
fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let (hlo, xbin, b, d) = (&args[1], &args[2], args[3].parse::<i64>()?, args[4].parse::<i64>()?);
    let exe = predsamp::runtime::client::compile_hlo_text(hlo)?;
    let bytes = std::fs::read(xbin)?;
    let x: Vec<i32> = bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0],c[1],c[2],c[3]])).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[b, d])?;
    let res = exe.execute::<xla::Literal>(&[lit])?;
    let tup = res[0][0].to_literal_sync()?;
    let parts = tup.to_tuple()?;
    for (i, p) in parts.iter().enumerate() {
        let v: Vec<f32> = p.to_vec()?;
        let mut out = Vec::with_capacity(v.len()*4);
        for f in &v { out.extend_from_slice(&f.to_le_bytes()); }
        std::fs::write(format!("{}.out{}.bin", xbin, i), out)?;
        println!("out{} len {}", i, v.len());
    }
    Ok(())
}
