//! Typed wrappers over the autoencoder executables (latent experiments).
//!
//! ```text
//! encoder: img f32[B, 3, S, S] -> (z i32[B, latent_dim],)
//! decoder: z   i32[B, latent_dim] -> (img f32[B, 3, S, S],)
//! ```

use super::{artifact::AeInfo, client};
use anyhow::{bail, Context, Result};
use std::path::Path;

pub struct EncoderExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub img_size: usize,
    pub latent_dim: usize,
}

impl EncoderExe {
    pub fn load<P: AsRef<Path>>(path: P, info: &AeInfo, batch: usize) -> Result<EncoderExe> {
        let exe = client::compile_hlo_text(&path).with_context(|| format!("encoder {}", info.name))?;
        Ok(EncoderExe { exe, batch, img_size: info.img_size, latent_dim: info.latent_dim })
    }

    /// `img` is `[B, 3, S, S]` row-major f32 in [-1, 1]; returns flat int
    /// latents `[B, latent_dim]`.
    pub fn encode(&self, img: &[f32]) -> Result<Vec<i32>> {
        let s = self.img_size;
        if img.len() != self.batch * 3 * s * s {
            bail!("encoder input len {}", img.len());
        }
        let lit = xla::Literal::vec1(img).reshape(&[self.batch as i64, 3, s as i64, s as i64])?;
        let res = self.exe.execute::<xla::Literal>(&[lit])?;
        let z = res[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(z.to_vec::<i32>()?)
    }
}

pub struct DecoderExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub img_size: usize,
    pub latent_dim: usize,
}

impl DecoderExe {
    pub fn load<P: AsRef<Path>>(path: P, info: &AeInfo, batch: usize) -> Result<DecoderExe> {
        let exe = client::compile_hlo_text(&path).with_context(|| format!("decoder {}", info.name))?;
        Ok(DecoderExe { exe, batch, img_size: info.img_size, latent_dim: info.latent_dim })
    }

    /// Flat int latents `[B, latent_dim]` -> images f32 `[B, 3, S, S]` in
    /// roughly [-1, 1] (the AE was trained on normalized images).
    pub fn decode(&self, z: &[i32]) -> Result<Vec<f32>> {
        if z.len() != self.batch * self.latent_dim {
            bail!("decoder input len {}", z.len());
        }
        let lit = xla::Literal::vec1(z).reshape(&[self.batch as i64, self.latent_dim as i64])?;
        let res = self.exe.execute::<xla::Literal>(&[lit])?;
        let img = res[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(img.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    #[test]
    fn encoder_decoder_roundtrip_shapes() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let Some(info) = man.autoencoders.get("cifar") else { return };
        let enc = EncoderExe::load(man.path(&format!("ae_{}_enc_b32.hlo.txt", info.name)), info, 32).unwrap();
        let dec = DecoderExe::load(man.path(&format!("ae_{}_dec_b32.hlo.txt", info.name)), info, 32).unwrap();
        let s = info.img_size;
        let img = vec![0.1f32; 32 * 3 * s * s];
        let z = enc.encode(&img).unwrap();
        assert_eq!(z.len(), 32 * info.latent_dim);
        assert!(z.iter().all(|&v| v >= 0 && (v as usize) < info.categories));
        let out = dec.decode(&z).unwrap();
        assert_eq!(out.len(), 32 * 3 * s * s);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
