//! Typed wrapper over an ARM step executable.
//!
//! Signature (the runtime↔coordinator contract, fixed by the python
//! AOT export under `python/compile/`):
//!
//! ```text
//! x i32[B, d]  ->  (logp f32[B, d, K],  fore f32[B, P, T, K])
//! ```
//!
//! The executable is pure — all sampling (Gumbel-max over `logp + ε`)
//! happens in the coordinator, which is what lets one artifact serve every
//! forecaster policy and ablation with ε held fixed across iterations.
//!
//! Partial inference: the sampling loop offers every backend a
//! `sampler::PassPlan` through `StepModel::run_plan`. Compiled executables
//! are shape-specialized, so they take the trait's full-shape fallback —
//! a plan is a permission to skip work, never an obligation — and instead
//! save through batch selection: the logp-only flavor below, and the
//! engine's batch down-shifting across exported batch sizes.

use super::{artifact::ModelInfo, client};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Output buffers of one step call. Reused across iterations (the hot loop
/// does not allocate; see `StepExecutable::run_into`).
///
/// Under planned passes the buffers may be only *partially* valid: a
/// backend honoring a `sampler::PassPlan` writes just the plan's live
/// spans and leaves `fore` empty when the plan says the heads go unread.
/// Consumers must read only what their plan asked for.
#[derive(Clone, Debug, Default)]
pub struct StepOutput {
    /// `[B, d, K]` ARM log-probs.
    pub logp: Vec<f32>,
    /// `[B, P, T, K]` forecast-head log-probs.
    pub fore: Vec<f32>,
}

/// A compiled ARM step executable for one fixed batch size.
///
/// Two flavors exist per model (both exported by the python AOT
/// path): the full step
/// `(logp, fore)` and a logp-only variant (`has_fore = false`) that skips
/// the forecast-head compute *and* its device→host transfer — the
/// dominant per-pass cost at B=32 for the K=256 models.
pub struct StepExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub dim: usize,
    pub categories: usize,
    pub pixels: usize,
    pub t_fore: usize,
    pub has_fore: bool,
    /// Number of step invocations since load (telemetry).
    calls: std::cell::Cell<u64>,
}

impl StepExecutable {
    /// Compile `path` for a model with `info` metadata at batch size `batch`.
    pub fn load<P: AsRef<Path>>(path: P, info: &ModelInfo, batch: usize) -> Result<StepExecutable> {
        Self::load_variant(path, info, batch, true)
    }

    /// Compile either flavor; `has_fore = false` for logp-only artifacts.
    pub fn load_variant<P: AsRef<Path>>(path: P, info: &ModelInfo, batch: usize, has_fore: bool) -> Result<StepExecutable> {
        let exe = client::compile_hlo_text(&path)
            .with_context(|| format!("loading step executable for {}", info.name))?;
        Ok(StepExecutable {
            exe,
            batch,
            dim: info.dim,
            categories: info.categories,
            pixels: info.pixels,
            t_fore: if has_fore { info.t_fore } else { 0 },
            has_fore,
            calls: std::cell::Cell::new(0),
        })
    }

    pub fn logp_len(&self) -> usize {
        self.batch * self.dim * self.categories
    }
    pub fn fore_len(&self) -> usize {
        self.batch * self.pixels * self.t_fore * self.categories
    }
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// One parallel inference pass, writing into reusable output buffers.
    /// `x` is `[B, d]` row-major i32 with values in `[0, K)`.
    pub fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        if x.len() != self.batch * self.dim {
            bail!("step input len {} != {}x{}", x.len(), self.batch, self.dim);
        }
        let lit = xla::Literal::vec1(x).reshape(&[self.batch as i64, self.dim as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?;
        let tuple = result[0][0].to_literal_sync()?;
        out.logp.resize(self.logp_len(), 0.0);
        if self.has_fore {
            let (lp, fo) = tuple.to_tuple2()?;
            out.fore.resize(self.fore_len(), 0.0);
            lp.copy_raw_to(&mut out.logp)?;
            fo.copy_raw_to(&mut out.fore)?;
        } else {
            let lp = tuple.to_tuple1()?;
            out.fore.clear();
            lp.copy_raw_to(&mut out.logp)?;
        }
        self.calls.set(self.calls.get() + 1);
        Ok(())
    }

    /// Convenience allocating variant.
    pub fn run(&self, x: &[i32]) -> Result<StepOutput> {
        let mut out = StepOutput::default();
        self.run_into(x, &mut out)?;
        Ok(out)
    }
}

/// Log-likelihood of a batch in bits/dim, computed from a step output.
/// (The rust-side mirror of the paper's bpd metric; used by `predsamp eval`.)
pub fn bpd_of(x: &[i32], out: &StepOutput, batch: usize, dim: usize, k: usize) -> Vec<f64> {
    let mut res = Vec::with_capacity(batch);
    for b in 0..batch {
        let mut ll = 0.0f64;
        for j in 0..dim {
            let cat = x[b * dim + j] as usize;
            ll += out.logp[(b * dim + j) * k + cat] as f64;
        }
        res.push(-ll / dim as f64 / std::f64::consts::LN_2);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    fn with_model<F: FnOnce(&Manifest, &StepExecutable)>(name: &str, b: usize, f: F) {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let info = man.model(name).unwrap();
        let file = info.file(&format!("step_b{b}")).unwrap();
        let exe = StepExecutable::load(man.path(file), info, b).unwrap();
        f(&man, &exe);
    }

    #[test]
    fn step_shapes_and_normalization() {
        with_model("mnist_bin", 1, |_, exe| {
            let x = vec![0i32; exe.dim];
            let out = exe.run(&x).unwrap();
            assert_eq!(out.logp.len(), exe.dim * exe.categories);
            assert_eq!(out.fore.len(), exe.pixels * exe.t_fore * exe.categories);
            // log-probs normalized
            for j in 0..exe.dim {
                let row = &out.logp[j * exe.categories..(j + 1) * exe.categories];
                let s: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
                assert!((s - 1.0).abs() < 1e-4, "pos {j}: sum {s}");
            }
            assert_eq!(exe.calls(), 1);
        });
    }

    #[test]
    fn step_is_autoregressive_through_runtime() {
        // Changing x at position j must not change logp at positions <= j —
        // the same property pytest checks on the jax side, verified here
        // through the compiled artifact.
        with_model("mnist_bin", 1, |_, exe| {
            let x0 = vec![0i32; exe.dim];
            let mut x1 = x0.clone();
            let j = exe.dim / 2;
            x1[j] = 1;
            let o0 = exe.run(&x0).unwrap();
            let o1 = exe.run(&x1).unwrap();
            let k = exe.categories;
            assert_eq!(&o0.logp[..(j + 1) * k], &o1.logp[..(j + 1) * k]);
            assert_ne!(&o0.logp[(j + 1) * k..], &o1.logp[(j + 1) * k..]);
        });
    }

    #[test]
    fn bpd_matches_python_build_number() {
        // The build recorded test-set bpd in the manifest; recompute the
        // same quantity through the artifact and require agreement.
        with_model("mnist_bin", 32, |man, exe| {
            let test = man.load_test_batch("mnist_bin").unwrap();
            let n = exe.batch.min(test.len());
            let mut x = vec![0i32; exe.batch * exe.dim];
            for (b, row) in test.iter().take(n).enumerate() {
                x[b * exe.dim..(b + 1) * exe.dim].copy_from_slice(row);
            }
            let out = exe.run(&x).unwrap();
            let bpds = bpd_of(&x, &out, n, exe.dim, exe.categories);
            let mean = bpds.iter().sum::<f64>() / n as f64;
            let expected = man.model("mnist_bin").unwrap().bpd;
            assert!(
                (mean - expected).abs() < 0.15,
                "rust bpd {mean:.4} vs python {expected:.4}"
            );
        });
    }

    #[test]
    fn wrong_input_len_rejected() {
        with_model("mnist_bin", 1, |_, exe| {
            assert!(exe.run(&[0i32; 3]).is_err());
        });
    }
}
