//! Typed wrapper over an ARM step executable, and the shape-variant
//! catalog that gives compiled backends real partial inference.
//!
//! Signature (the runtime↔coordinator contract, fixed by the python
//! AOT export under `python/compile/`):
//!
//! ```text
//! x i32[B, d]  ->  (logp f32[B, S, K],  fore f32[B, P, T, K])
//! ```
//!
//! where `S` is the export's **logp span**: a full-shape export computes
//! all `d` positions (`S = d`), a span export (`step_b{B}_s{S}` roles)
//! takes the same full `[B, d]` input but computes and transfers log-probs
//! only for the trailing window `[d - S, d)`. Autoregression makes the
//! sliced output bitwise identical to the same window of a full pass.
//!
//! The executable is pure — all sampling (Gumbel-max over `logp + ε`)
//! happens in the coordinator, which is what lets one artifact serve every
//! forecaster policy and ablation with ε held fixed across iterations.
//!
//! Partial inference: the sampling loop offers every backend a
//! `sampler::PassPlan` through `StepModel::run_plan`. A lone
//! shape-specialized executable can only take the trait's full-shape
//! fallback, but a [`VariantCatalog`] — a family of executables along the
//! `{batch, span, fore-flavor}` axes — serves the plan by compacting live
//! rows into the smallest covering exported batch, picking the cheapest
//! variant whose span covers the hull of the plan's frontiers, and
//! scattering the results back into the caller's full-shape buffers.

use super::{artifact::ModelInfo, client};
use crate::sampler::PassPlan;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Output buffers of one step call. Reused across iterations (the hot loop
/// does not allocate; see `StepExecutable::run_into`).
///
/// Under planned passes the buffers may be only *partially* valid: a
/// backend honoring a `sampler::PassPlan` writes just the plan's live
/// spans and leaves `fore` empty when the plan says the heads go unread.
/// Consumers must read only what their plan asked for.
#[derive(Clone, Debug, Default)]
pub struct StepOutput {
    /// `[B, d, K]` ARM log-probs (`[B, S, K]` for a span variant's raw
    /// output before the catalog scatters it back to full shape).
    pub logp: Vec<f32>,
    /// `[B, P, T, K]` forecast-head log-probs.
    pub fore: Vec<f32>,
}

/// A compiled ARM step executable for one fixed `(batch, span, fore)`
/// shape.
///
/// Per model the python AOT path exports the full step `(logp, fore)`, a
/// logp-only flavor (`has_fore = false`) that skips the forecast-head
/// compute *and* its device→host transfer — the dominant per-pass cost at
/// B=32 for the K=256 models — and trailing-window span variants
/// (`span < dim`) for both flavors, which a [`VariantCatalog`] selects
/// among per pass.
pub struct StepExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub dim: usize,
    /// Trailing logp positions this export computes (`dim` for full shape).
    pub span: usize,
    pub categories: usize,
    pub pixels: usize,
    pub t_fore: usize,
    pub has_fore: bool,
    /// Number of step invocations since load (telemetry; atomic so a
    /// catalog of executables is `Sync` and shareable across workers).
    calls: AtomicU64,
}

impl StepExecutable {
    /// Compile `path` for a model with `info` metadata at batch size `batch`.
    pub fn load<P: AsRef<Path>>(path: P, info: &ModelInfo, batch: usize) -> Result<StepExecutable> {
        Self::load_variant(path, info, batch, true)
    }

    /// Compile either flavor; `has_fore = false` for logp-only artifacts.
    pub fn load_variant<P: AsRef<Path>>(path: P, info: &ModelInfo, batch: usize, has_fore: bool) -> Result<StepExecutable> {
        Self::load_span_variant(path, info, batch, has_fore, info.dim)
    }

    /// Compile a trailing-window span variant (`step_b{B}_s{S}` exports):
    /// full `[B, d]` input, logp output restricted to `[d - span, d)`.
    pub fn load_span_variant<P: AsRef<Path>>(
        path: P,
        info: &ModelInfo,
        batch: usize,
        has_fore: bool,
        span: usize,
    ) -> Result<StepExecutable> {
        ensure!(span >= 1 && span <= info.dim, "span {} out of range for {} (d={})", span, info.name, info.dim);
        let exe = client::compile_hlo_text(&path)
            .with_context(|| format!("loading step executable for {}", info.name))?;
        Ok(StepExecutable {
            exe,
            batch,
            dim: info.dim,
            span,
            categories: info.categories,
            pixels: info.pixels,
            t_fore: if has_fore { info.t_fore } else { 0 },
            has_fore,
            calls: AtomicU64::new(0),
        })
    }

    pub fn logp_len(&self) -> usize {
        self.batch * self.span * self.categories
    }
    pub fn fore_len(&self) -> usize {
        self.batch * self.pixels * self.t_fore * self.categories
    }
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// One parallel inference pass, writing into reusable output buffers.
    /// `x` is `[B, d]` row-major i32 with values in `[0, K)`; `out.logp`
    /// receives `[B, span, K]` (the trailing window; full shape when
    /// `span == dim`).
    pub fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        if x.len() != self.batch * self.dim {
            bail!("step input len {} != {}x{}", x.len(), self.batch, self.dim);
        }
        let lit = xla::Literal::vec1(x).reshape(&[self.batch as i64, self.dim as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?;
        let tuple = result[0][0].to_literal_sync()?;
        out.logp.resize(self.logp_len(), 0.0);
        if self.has_fore {
            let (lp, fo) = tuple.to_tuple2()?;
            out.fore.resize(self.fore_len(), 0.0);
            lp.copy_raw_to(&mut out.logp)?;
            fo.copy_raw_to(&mut out.fore)?;
        } else {
            let lp = tuple.to_tuple1()?;
            out.fore.clear();
            lp.copy_raw_to(&mut out.logp)?;
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Convenience allocating variant.
    pub fn run(&self, x: &[i32]) -> Result<StepOutput> {
        let mut out = StepOutput::default();
        self.run_into(x, &mut out)?;
        Ok(out)
    }
}

/// A pure-rust backend that can run one `(batch, span, fore)` device shape
/// — the mock ARM implements this so variant catalogs (and everything
/// built on them) run offline, bitwise identical to the compiled path's
/// semantics.
pub trait SpanBackend: Send + Sync {
    /// One device-shape pass: full `[batch, dim]` input; write
    /// `out.logp = [batch, span, K]` for the trailing positions
    /// `[dim - span, dim)` and, when `has_fore`, the full forecast heads
    /// `out.fore = [batch, P, T, K]` (cleared otherwise). Values must be
    /// bitwise identical to the same window of a full pass.
    fn run_span(&self, batch: usize, span: usize, has_fore: bool, x: &[i32], out: &mut StepOutput) -> Result<()>;
}

enum VariantBackend {
    Compiled(StepExecutable),
    Pure(Box<dyn SpanBackend>),
}

/// One exported shape in a [`VariantCatalog`].
pub struct Variant {
    pub batch: usize,
    /// Trailing logp window length (`dim` = full shape).
    pub span: usize,
    pub has_fore: bool,
    backend: VariantBackend,
    hits: AtomicU64,
}

impl Variant {
    /// Device cost of one pass on this variant, in K-length output rows
    /// (the `positions_evaluated` unit): every batch row pays the span,
    /// plus the forecast heads when the flavor computes them.
    fn cost(&self, pixels: usize, t_fore: usize) -> usize {
        self.batch * self.span + if self.has_fore { self.batch * pixels * t_fore } else { 0 }
    }

    /// Histogram label, e.g. `b8_s64` / `b8_s64_lp`.
    pub fn label(&self) -> String {
        if self.has_fore {
            format!("b{}_s{}", self.batch, self.span)
        } else {
            format!("b{}_s{}_lp", self.batch, self.span)
        }
    }

    fn run(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        match &self.backend {
            VariantBackend::Compiled(exe) => exe.run_into(x, out),
            VariantBackend::Pure(b) => b.run_span(self.batch, self.span, self.has_fore, x, out),
        }
    }
}

/// Point-in-time snapshot of one variant's selection count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VariantStat {
    pub batch: usize,
    pub span: usize,
    pub has_fore: bool,
    pub hits: u64,
}

/// Point-in-time snapshot of a catalog's telemetry.
#[derive(Clone, Debug, Default)]
pub struct CatalogStats {
    /// Passes served by a variant strictly smaller than full shape.
    pub variant_hits: u64,
    /// Passes where the cheapest covering variant *was* the full shape.
    pub full_shape_fallbacks: u64,
    /// Total K-length output rows computed on-device by this catalog.
    pub positions_evaluated: u64,
    /// Selected-shape histogram, one entry per variant (label, hits).
    pub shapes: Vec<(String, u64)>,
}

impl CatalogStats {
    /// Element-wise accumulate (for per-worker / fleet aggregation).
    pub fn merge(&mut self, other: &CatalogStats) {
        self.variant_hits += other.variant_hits;
        self.full_shape_fallbacks += other.full_shape_fallbacks;
        self.positions_evaluated += other.positions_evaluated;
        for (label, hits) in &other.shapes {
            match self.shapes.iter_mut().find(|(l, _)| l == label) {
                Some((_, h)) => *h += hits,
                None => self.shapes.push((label.clone(), *hits)),
            }
        }
    }
}

// Per-thread compaction scratch (compacted input + variant-shaped raw
// output). A catalog is shared (`Sync`) and `run_plan` takes `&self`, so
// the scratch cannot live on the catalog; thread-locals keep the hot loop
// allocation-free after the first pass per thread.
thread_local! {
    static SCRATCH: std::cell::RefCell<(Vec<i32>, StepOutput)> =
        std::cell::RefCell::new((Vec::new(), StepOutput::default()));
}

/// A family of step executables for one model along the
/// `{batch, span, fore-flavor}` axes, serving frontier-aware plans on
/// compiled (or mock device-shape) backends.
///
/// `run_plan` (1) compacts live rows into the smallest covering exported
/// batch, (2) picks the cheapest variant whose trailing span covers the
/// hull of the plan's `{lo, hi}` frontiers and whose fore flavor matches
/// `need_fore`, (3) scatters results back into the caller's full-shape
/// [`StepOutput`]. Every position the plan promises is bitwise identical
/// to a full-shape pass — spans slice an autoregressive output, batch
/// rows are independent, and compaction/scatter is pure data movement.
///
/// All telemetry is atomic: one catalog is `Sync` and can be shared
/// across engine workers instead of cloned per worker.
pub struct VariantCatalog {
    pub model: String,
    pub dim: usize,
    pub categories: usize,
    pub pixels: usize,
    pub t_fore: usize,
    /// Sorted by `(batch, span, has_fore)` so minimal-cost selection
    /// tie-breaks toward the smallest batch, then the shortest span.
    variants: Vec<Variant>,
    variant_hits: AtomicU64,
    full_shape_fallbacks: AtomicU64,
    positions_evaluated: AtomicU64,
}

impl VariantCatalog {
    pub fn new(model: &str, dim: usize, categories: usize, pixels: usize, t_fore: usize) -> VariantCatalog {
        VariantCatalog {
            model: model.to_string(),
            dim,
            categories,
            pixels,
            t_fore,
            variants: Vec::new(),
            variant_hits: AtomicU64::new(0),
            full_shape_fallbacks: AtomicU64::new(0),
            positions_evaluated: AtomicU64::new(0),
        }
    }

    /// Add a compiled executable (its own shape fields describe it).
    pub fn push_compiled(&mut self, exe: StepExecutable) -> Result<()> {
        ensure!(exe.dim == self.dim, "{}: variant dim {} != catalog dim {}", self.model, exe.dim, self.dim);
        let v = Variant {
            batch: exe.batch,
            span: exe.span,
            has_fore: exe.has_fore,
            backend: VariantBackend::Compiled(exe),
            hits: AtomicU64::new(0),
        };
        self.push(v)
    }

    /// Add a pure-rust device-shape backend (the mock path).
    pub fn push_backend(&mut self, batch: usize, span: usize, has_fore: bool, backend: Box<dyn SpanBackend>) -> Result<()> {
        ensure!(span >= 1 && span <= self.dim, "{}: span {} out of range (d={})", self.model, span, self.dim);
        ensure!(batch >= 1, "{}: zero-batch variant", self.model);
        self.push(Variant { batch, span, has_fore, backend: VariantBackend::Pure(backend), hits: AtomicU64::new(0) })
    }

    fn push(&mut self, v: Variant) -> Result<()> {
        ensure!(
            !self.variants.iter().any(|o| (o.batch, o.span, o.has_fore) == (v.batch, v.span, v.has_fore)),
            "{}: duplicate variant {}",
            self.model,
            v.label()
        );
        let at = self
            .variants
            .partition_point(|o| (o.batch, o.span, o.has_fore) < (v.batch, v.span, v.has_fore));
        self.variants.insert(at, v);
        Ok(())
    }

    /// Exported batch sizes that have a full-shape fore variant — the
    /// anchors every plan can fall back to.
    pub fn anchored_batches(&self) -> Vec<usize> {
        let mut out: Vec<usize> =
            self.variants.iter().filter(|v| v.span == self.dim && v.has_fore).map(|v| v.batch).collect();
        out.dedup();
        out
    }

    /// A usable catalog needs, per exported batch size, a full-shape fore
    /// variant (the fallback anchor `hlo_probe --manifest` also gates on);
    /// otherwise some plan would have no covering variant.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.variants.is_empty(), "{}: empty variant catalog", self.model);
        let anchors = self.anchored_batches();
        for v in &self.variants {
            ensure!(
                anchors.contains(&v.batch),
                "{}: variant {} has no full-shape anchor (step_b{} missing)",
                self.model,
                v.label(),
                v.batch
            );
        }
        Ok(())
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Cheapest variant covering `live` rows whose frontiers reach down to
    /// `need_lo`, with the fore flavor `need_fore` requires. Variants are
    /// sorted, so the first strict cost improvement also tie-breaks toward
    /// the smallest batch, then the shortest span, then the fore flavor.
    fn select(&self, live: usize, need_lo: usize, need_fore: bool) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, v) in self.variants.iter().enumerate() {
            if v.batch < live || self.dim - v.span > need_lo || (need_fore && !v.has_fore) {
                continue;
            }
            let cost = v.cost(self.pixels, self.t_fore);
            if best.map_or(true, |(c, _)| cost < c) {
                best = Some((cost, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Serve one planned pass for a view of `view_batch` slots (see the
    /// type-level docs for the three phases). Returns the device cost in
    /// K-length output rows. `view_fore` gates whether the heads may be
    /// produced at all (a logp-only engine view never reads them).
    pub fn run_plan(&self, view_batch: usize, view_fore: bool, x: &[i32], out: &mut StepOutput, plan: &PassPlan) -> Result<usize> {
        let d = self.dim;
        let k = self.categories;
        ensure!(x.len() == view_batch * d, "{}: plan input len {} != {}x{}", self.model, x.len(), view_batch, d);
        ensure!(plan.slots.len() <= view_batch, "{}: plan has {} slots for a b={} view", self.model, plan.slots.len(), view_batch);
        let need = plan.need_fore && view_fore && self.t_fore > 0;
        let live: Vec<usize> = (0..plan.slots.len()).filter(|&i| plan.slots[i].active).collect();
        if !need {
            out.fore.clear();
        }
        if live.is_empty() {
            return Ok(0);
        }
        // The frontier hull: the lowest position any live slot will read.
        let need_lo = live
            .iter()
            .map(|&i| {
                let s = &plan.slots[i];
                s.lo.min(s.hi).min(d)
            })
            .min()
            .unwrap_or(0);
        let vi = match self.select(live.len(), need_lo, need) {
            Some(vi) => vi,
            None => bail!(
                "{}: no exported variant covers {} live rows at frontier {} (need_fore={}) — full-shape anchor missing",
                self.model,
                live.len(),
                need_lo,
                need
            ),
        };
        let v = &self.variants[vi];
        let base = d - v.span;
        SCRATCH.with(|s| -> Result<()> {
            let (cx, tmp) = &mut *s.borrow_mut();
            // (1) compact live rows into the variant's batch (padding rows
            // keep whatever the scratch held — any in-range value is fine,
            // their outputs are never scattered back).
            cx.resize(v.batch * d, 0);
            for (r, &slot) in live.iter().enumerate() {
                cx[r * d..(r + 1) * d].copy_from_slice(&x[slot * d..(slot + 1) * d]);
            }
            // (2) run the selected shape.
            v.run(cx, tmp)?;
            // (3) scatter back into the caller's full-shape buffers.
            out.logp.resize(view_batch * d * k, 0.0);
            for (r, &slot) in live.iter().enumerate() {
                let src = &tmp.logp[r * v.span * k..(r + 1) * v.span * k];
                out.logp[(slot * d + base) * k..(slot + 1) * d * k].copy_from_slice(src);
            }
            if need {
                let row = self.pixels * self.t_fore * k;
                out.fore.resize(view_batch * row, 0.0);
                for (r, &slot) in live.iter().enumerate() {
                    out.fore[slot * row..(slot + 1) * row].copy_from_slice(&tmp.fore[r * row..(r + 1) * row]);
                }
            }
            Ok(())
        })?;
        let cost = v.cost(self.pixels, self.t_fore);
        v.hits.fetch_add(1, Ordering::Relaxed);
        if v.span < d || v.batch < plan.slots.len() {
            self.variant_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.full_shape_fallbacks.fetch_add(1, Ordering::Relaxed);
        }
        self.positions_evaluated.fetch_add(cost as u64, Ordering::Relaxed);
        Ok(cost)
    }

    /// A full-shape pass for a view of `view_batch` slots (eval, ancestral
    /// references, plan-mode off): every row live over the whole dim.
    pub fn run_full(&self, view_batch: usize, view_fore: bool, x: &[i32], out: &mut StepOutput) -> Result<usize> {
        let mut plan = PassPlan::full(view_batch, self.dim);
        plan.need_fore = view_fore;
        self.run_plan(view_batch, view_fore, x, out, &plan)
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> CatalogStats {
        CatalogStats {
            variant_hits: self.variant_hits.load(Ordering::Relaxed),
            full_shape_fallbacks: self.full_shape_fallbacks.load(Ordering::Relaxed),
            positions_evaluated: self.positions_evaluated.load(Ordering::Relaxed),
            shapes: self.variants.iter().map(|v| (v.label(), v.hits.load(Ordering::Relaxed))).collect(),
        }
    }
}

/// Log-likelihood of a batch in bits/dim, computed from a step output.
/// (The rust-side mirror of the paper's bpd metric; used by `predsamp eval`.)
pub fn bpd_of(x: &[i32], out: &StepOutput, batch: usize, dim: usize, k: usize) -> Vec<f64> {
    let mut res = Vec::with_capacity(batch);
    for b in 0..batch {
        let mut ll = 0.0f64;
        for j in 0..dim {
            let cat = x[b * dim + j] as usize;
            ll += out.logp[(b * dim + j) * k + cat] as f64;
        }
        res.push(-ll / dim as f64 / std::f64::consts::LN_2);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;
    use crate::sampler::SlotSpan;

    fn with_model<F: FnOnce(&Manifest, &StepExecutable)>(name: &str, b: usize, f: F) {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let info = man.model(name).unwrap();
        let file = info.file(&format!("step_b{b}")).unwrap();
        let exe = StepExecutable::load(man.path(file), info, b).unwrap();
        f(&man, &exe);
    }

    #[test]
    fn step_shapes_and_normalization() {
        with_model("mnist_bin", 1, |_, exe| {
            let x = vec![0i32; exe.dim];
            let out = exe.run(&x).unwrap();
            assert_eq!(out.logp.len(), exe.dim * exe.categories);
            assert_eq!(out.fore.len(), exe.pixels * exe.t_fore * exe.categories);
            // log-probs normalized
            for j in 0..exe.dim {
                let row = &out.logp[j * exe.categories..(j + 1) * exe.categories];
                let s: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
                assert!((s - 1.0).abs() < 1e-4, "pos {j}: sum {s}");
            }
            assert_eq!(exe.calls(), 1);
        });
    }

    #[test]
    fn step_is_autoregressive_through_runtime() {
        // Changing x at position j must not change logp at positions <= j —
        // the same property pytest checks on the jax side, verified here
        // through the compiled artifact.
        with_model("mnist_bin", 1, |_, exe| {
            let x0 = vec![0i32; exe.dim];
            let mut x1 = x0.clone();
            let j = exe.dim / 2;
            x1[j] = 1;
            let o0 = exe.run(&x0).unwrap();
            let o1 = exe.run(&x1).unwrap();
            let k = exe.categories;
            assert_eq!(&o0.logp[..(j + 1) * k], &o1.logp[..(j + 1) * k]);
            assert_ne!(&o0.logp[(j + 1) * k..], &o1.logp[(j + 1) * k..]);
        });
    }

    #[test]
    fn bpd_matches_python_build_number() {
        // The build recorded test-set bpd in the manifest; recompute the
        // same quantity through the artifact and require agreement.
        with_model("mnist_bin", 32, |man, exe| {
            let test = man.load_test_batch("mnist_bin").unwrap();
            let n = exe.batch.min(test.len());
            let mut x = vec![0i32; exe.batch * exe.dim];
            for (b, row) in test.iter().take(n).enumerate() {
                x[b * exe.dim..(b + 1) * exe.dim].copy_from_slice(row);
            }
            let out = exe.run(&x).unwrap();
            let bpds = bpd_of(&x, &out, n, exe.dim, exe.categories);
            let mean = bpds.iter().sum::<f64>() / n as f64;
            let expected = man.model("mnist_bin").unwrap().bpd;
            assert!(
                (mean - expected).abs() < 0.15,
                "rust bpd {mean:.4} vs python {expected:.4}"
            );
        });
    }

    #[test]
    fn wrong_input_len_rejected() {
        with_model("mnist_bin", 1, |_, exe| {
            assert!(exe.run(&[0i32; 3]).is_err());
        });
    }

    // ---- variant-catalog unit tests (pure backend, no artifacts) -------

    /// A deterministic span-consistent backend: logp at position j depends
    /// only on (x[j-1], j), fore on (pixel, t), so any span window of any
    /// batch compaction is bitwise identical to the full pass.
    struct TestBackend {
        dim: usize,
        k: usize,
        pixels: usize,
        t_fore: usize,
    }

    impl SpanBackend for TestBackend {
        fn run_span(&self, batch: usize, span: usize, has_fore: bool, x: &[i32], out: &mut StepOutput) -> Result<()> {
            let (d, k) = (self.dim, self.k);
            ensure!(x.len() == batch * d, "bad input");
            out.logp.resize(batch * span * k, 0.0);
            let base = d - span;
            for b in 0..batch {
                for j in base..d {
                    let prev = if j == 0 { -1 } else { x[b * d + j - 1] };
                    for c in 0..k {
                        out.logp[(b * span + (j - base)) * k + c] = (prev * 31 + j as i32 * 7 + c as i32) as f32;
                    }
                }
            }
            if has_fore {
                out.fore.resize(batch * self.pixels * self.t_fore * k, 0.0);
                for (i, v) in out.fore.iter_mut().enumerate() {
                    *v = (i % 97) as f32;
                }
            } else {
                out.fore.clear();
            }
            Ok(())
        }
    }

    fn test_catalog(dim: usize, k: usize, pixels: usize, t_fore: usize, shapes: &[(usize, usize, bool)]) -> VariantCatalog {
        let mut cat = VariantCatalog::new("test", dim, k, pixels, t_fore);
        for &(b, s, f) in shapes {
            cat.push_backend(b, s, f, Box::new(TestBackend { dim, k, pixels, t_fore })).unwrap();
        }
        cat
    }

    fn plan_of(spans: &[(bool, usize, usize)], need_fore: bool) -> PassPlan {
        PassPlan {
            slots: spans.iter().map(|&(active, lo, hi)| SlotSpan { active, lo, hi }).collect(),
            need_fore,
            need_full_scan: true,
        }
    }

    #[test]
    fn catalog_requires_full_shape_anchor() {
        let cat = test_catalog(8, 3, 4, 1, &[(2, 4, true), (2, 8, true)]);
        cat.validate().unwrap();
        // A batch with only a short span has no anchor.
        let cat = test_catalog(8, 3, 4, 1, &[(1, 4, true), (2, 8, true)]);
        assert!(cat.validate().unwrap_err().to_string().contains("full-shape anchor"));
        // A logp-only full shape is not an anchor either (fore plans
        // could not fall back to it).
        let cat = test_catalog(8, 3, 4, 1, &[(2, 8, false)]);
        assert!(cat.validate().is_err());
    }

    #[test]
    fn catalog_selects_cheapest_covering_variant() {
        let cat = test_catalog(16, 3, 8, 2, &[(1, 16, true), (4, 16, true), (4, 8, true), (4, 8, false), (4, 16, false)]);
        cat.validate().unwrap();
        // Frontier at 10 with one live row: span 8 covers (16-8 <= 10);
        // without fore the lp flavor wins, but batch 1 full-fore is
        // 16+8*2=32 vs b4 lp span8 = 32 — tie broken toward smaller batch.
        assert_eq!(cat.select(1, 10, false).map(|i| cat.variants()[i].label()), Some("b1_s16".into()));
        // Fore needed: b4 span-8 fore costs 4*8+4*16=96 > b1 full 48.
        assert_eq!(cat.select(1, 10, true).map(|i| cat.variants()[i].label()), Some("b1_s16".into()));
        // Two live rows at a deep frontier: lp span wins.
        assert_eq!(cat.select(2, 12, false).map(|i| cat.variants()[i].label()), Some("b4_s8_lp".into()));
        // Frontier 0 forces full span.
        assert_eq!(cat.select(2, 0, true).map(|i| cat.variants()[i].label()), Some("b4_s16".into()));
        // No variant covers 5 live rows.
        assert_eq!(cat.select(5, 0, true), None);
    }

    #[test]
    fn catalog_roundtrips_bitwise_and_counts_hits() {
        let (d, k, px, t) = (12, 4, 6, 2);
        let cat = test_catalog(d, k, px, t, &[(1, d, true), (4, d, true), (4, 6, true), (4, 6, false), (4, d, false), (1, d, false)]);
        cat.validate().unwrap();
        let backend = TestBackend { dim: d, k, pixels: px, t_fore: t };
        let x: Vec<i32> = (0..4 * d as i32).map(|i| i % 3).collect();
        // Full reference on the same 4 rows.
        let mut full = StepOutput::default();
        backend.run_span(4, d, true, &x, &mut full).unwrap();

        // A plan with dead rows and a deep frontier hull.
        let plan = plan_of(&[(true, 7, d), (false, 0, 0), (true, 9, d), (false, 0, 0)], true);
        let mut out = StepOutput::default();
        let cost = cat.run_plan(4, true, &x, &mut out, &plan).unwrap();
        // 2 live rows, hull 7 → d - span <= 7 → span 6; fore needed.
        assert_eq!(cost, 4 * 6 + 4 * px * t);
        for &slot in &[0usize, 2] {
            let lo = plan.slots[slot].lo;
            assert_eq!(
                &out.logp[(slot * d + lo) * k..(slot + 1) * d * k],
                &full.logp[(slot * d + lo) * k..(slot + 1) * d * k],
                "slot {slot} logp window"
            );
            let row = px * t * k;
            assert_eq!(&out.fore[slot * row..(slot + 1) * row], &full.fore[slot * row..(slot + 1) * row], "slot {slot} fore");
        }
        let st = cat.stats();
        assert_eq!((st.variant_hits, st.full_shape_fallbacks), (1, 0));
        assert_eq!(st.positions_evaluated, cost as u64);
        assert_eq!(st.shapes.iter().find(|(l, _)| l == "b4_s6").map(|(_, h)| *h), Some(1));

        // need_fore=false must clear fore and pick an lp flavor — here
        // compacting to batch 1 (b1_s12_lp, cost 12) beats b4_s6_lp (24).
        let plan = plan_of(&[(true, 9, d), (false, 0, 0), (false, 0, 0), (false, 0, 0)], false);
        let mut out2 = StepOutput::default();
        let cost2 = cat.run_plan(4, true, &x, &mut out2, &plan).unwrap();
        assert_eq!(cost2, d);
        assert!(out2.fore.is_empty());
        assert_eq!(&out2.logp[(0 * d + 9) * k..d * k], &full.logp[9 * k..d * k]);
    }

    #[test]
    fn catalog_degenerate_plans() {
        let (d, k, px, t) = (10, 3, 5, 1);
        let cat = test_catalog(d, k, px, t, &[(1, d, true), (2, d, true), (4, d, true), (4, 5, true), (1, 2, true)]);
        cat.validate().unwrap();
        let x = vec![0i32; 4 * d];
        let mut out = StepOutput::default();
        // All-dead: no work, no telemetry.
        let plan = plan_of(&[(false, 0, 0); 4], true);
        assert_eq!(cat.run_plan(4, true, &x, &mut out, &plan).unwrap(), 0);
        let st = cat.stats();
        assert_eq!((st.variant_hits, st.full_shape_fallbacks, st.positions_evaluated), (0, 0, 0));
        // Single trailing position (ancestral's last step): the shortest
        // covering span at the smallest batch — this all-fore catalog has
        // no lp flavor, so the heads ride along in the cost.
        let plan = plan_of(&[(true, d - 1, d), (false, 0, 0), (false, 0, 0), (false, 0, 0)], false);
        assert_eq!(cat.run_plan(4, true, &x, &mut out, &plan).unwrap(), 2 + px * t);
        assert_eq!(cat.stats().shapes.iter().find(|(l, _)| l == "b1_s2").map(|(_, h)| *h), Some(1));
        // Full batch at frontier 0: the full-shape anchor — counted as a
        // fallback, not a variant hit.
        let plan = plan_of(&[(true, 0, d); 4], true);
        assert_eq!(cat.run_plan(4, true, &x, &mut out, &plan).unwrap(), 4 * d + 4 * px * t);
        let st = cat.stats();
        assert_eq!(st.full_shape_fallbacks, 1);
    }

    #[test]
    fn catalog_is_sync_and_shareable() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<VariantCatalog>();
        assert_sync::<StepExecutable>();
        // Concurrent planned passes on one shared catalog stay exact.
        let (d, k, px, t) = (8, 3, 4, 1);
        let cat = std::sync::Arc::new(test_catalog(d, k, px, t, &[(1, d, true), (1, 4, true)]));
        let backend = TestBackend { dim: d, k, pixels: px, t_fore: t };
        let x: Vec<i32> = (0..d as i32).collect();
        let mut full = StepOutput::default();
        backend.run_span(1, d, true, &x, &mut full).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let cat = cat.clone();
                let (x, full) = (x.clone(), full.logp.clone());
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let plan = plan_of(&[(true, 5, d)], false);
                        let mut out = StepOutput::default();
                        cat.run_plan(1, true, &x, &mut out, &plan).unwrap();
                        assert_eq!(&out.logp[5 * k..d * k], &full[5 * k..d * k]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cat.stats().variant_hits, 200);
    }
}
