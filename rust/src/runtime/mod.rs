//! Runtime: AOT artifact loading + execution on the PJRT CPU client.
//!
//! The contract with the python build path (under `python/compile/`):
//! `artifacts/*.hlo.txt` (HLO **text**, the xla_extension-0.5.1-safe
//! interchange) are compiled once at startup and executed from the
//! coordinator's hot loop; `artifacts/manifest.json` describes shapes and
//! model metadata. Python never runs here.

pub mod artifact;
pub mod autoenc;
pub mod client;
pub mod step;
