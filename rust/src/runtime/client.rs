//! PJRT CPU client + HLO-text compilation.
//!
//! The `xla` crate's client/executable handles are `Rc`-based (not
//! `Send`/`Sync`), so all PJRT objects live on the thread that created
//! them. The client is cached **per thread**; the serving architecture
//! keeps every executable on a single engine thread and talks to it over
//! channels (see `coordinator::server`).

use anyhow::{Context, Result};
use std::cell::OnceCell;
use std::path::Path;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// The calling thread's PJRT CPU client (created on first use).
pub fn client() -> Result<xla::PjRtClient> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let _ = cell.set(c);
        }
        Ok(cell.get().expect("client initialized").clone())
    })
}

/// Load an HLO-text artifact and compile it on this thread's client.
///
/// HLO text (not serialized proto) is the interchange format: jax >= 0.5
/// emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
/// the text parser reassigns ids cleanly.
pub fn compile_hlo_text<P: AsRef<Path>>(path: P) -> Result<xla::PjRtLoadedExecutable> {
    let path = path.as_ref();
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client()?
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_cpu() {
        let c = client().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.device_count() >= 1);
    }

    #[test]
    fn compile_missing_file_errors() {
        assert!(compile_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}
