//! Artifact manifest: metadata for every trained model + file registry.
//!
//! `artifacts/manifest.json` is emitted by `python/compile/aot.py`. This
//! module parses it into typed structs and resolves artifact paths.

use crate::substrate::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which task a model belongs to (paper §4.1 vs §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Explicit likelihood modeling on images (Table 1).
    Explicit,
    /// ARM over the discrete latent space of an autoencoder (Table 2).
    Latent,
}

/// Static description of one ARM, mirrored from `ArmConfig.to_manifest()`.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: ModelKind,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub categories: usize,
    pub t_fore: usize,
    pub share_repr: bool,
    pub dim: usize,
    pub pixels: usize,
    /// Test-set bits/dim achieved at build time.
    pub bpd: f64,
    /// Artifact files keyed by role ("step_b1", "step_b32", "test_x", ...).
    pub files: BTreeMap<String, String>,
    /// For latent models: the paired autoencoder name.
    pub autoencoder: Option<String>,
    pub test_n: usize,
}

impl ModelInfo {
    /// Batch sizes for which a step executable exists.
    pub fn step_batch_sizes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .files
            .keys()
            .filter_map(|k| k.strip_prefix("step_b").and_then(|b| b.parse().ok()))
            .collect();
        out.sort_unstable();
        out
    }

    pub fn file(&self, role: &str) -> Result<&str> {
        self.files
            .get(role)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("model {} has no artifact role {role:?}", self.name))
    }
}

/// Autoencoder metadata (latent experiments).
#[derive(Clone, Debug)]
pub struct AeInfo {
    pub name: String,
    pub img_size: usize,
    pub latent_channels: usize,
    pub latent_hw: usize,
    pub categories: usize,
    pub latent_dim: usize,
    pub mse: f64,
}

/// Parsed manifest + artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub autoencoders: BTreeMap<String, AeInfo>,
    pub quick: bool,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_value(dir, &v)
    }

    fn from_value(dir: PathBuf, v: &Value) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        let model_obj = v.get("models").as_obj().ok_or_else(|| anyhow!("manifest: missing models"))?;
        for (name, m) in model_obj {
            let kind = match m.get("kind").as_str() {
                Some("explicit") => ModelKind::Explicit,
                Some("latent") => ModelKind::Latent,
                other => bail!("model {name}: bad kind {other:?}"),
            };
            let files = m
                .get("files")
                .as_obj()
                .ok_or_else(|| anyhow!("model {name}: missing files"))?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str().ok_or_else(|| anyhow!("bad file entry {k}"))?.to_string())))
                .collect::<Result<BTreeMap<_, _>>>()?;
            let req = |key: &str| -> Result<usize> {
                m.get(key).as_usize().ok_or_else(|| anyhow!("model {name}: missing {key}"))
            };
            let info = ModelInfo {
                name: name.clone(),
                kind,
                channels: req("channels")?,
                height: req("height")?,
                width: req("width")?,
                categories: req("categories")?,
                t_fore: req("t_fore")?,
                share_repr: m.get("share_repr").as_bool().unwrap_or(true),
                dim: req("dim")?,
                pixels: req("pixels")?,
                bpd: m.get("bpd").as_f64().unwrap_or(f64::NAN),
                files,
                autoencoder: m.get("autoencoder").as_str().map(String::from),
                test_n: m.get("test_n").as_usize().unwrap_or(0),
            };
            if info.dim != info.channels * info.pixels {
                bail!("model {name}: inconsistent dim");
            }
            models.insert(name.clone(), info);
        }

        let mut autoencoders = BTreeMap::new();
        if let Some(obj) = v.get("autoencoders").as_obj() {
            for (name, a) in obj {
                autoencoders.insert(
                    name.clone(),
                    AeInfo {
                        name: name.clone(),
                        img_size: a.get("img_size").as_usize().unwrap_or(0),
                        latent_channels: a.get("latent_channels").as_usize().unwrap_or(0),
                        latent_hw: a.get("latent_hw").as_usize().unwrap_or(0),
                        categories: a.get("categories").as_usize().unwrap_or(0),
                        latent_dim: a.get("latent_dim").as_usize().unwrap_or(0),
                        mse: a.get("mse").as_f64().unwrap_or(f64::NAN),
                    },
                );
            }
        }

        Ok(Manifest {
            dir,
            models,
            autoencoders,
            quick: v.get("quick").as_bool().unwrap_or(false),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}; have {:?}", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn ae(&self, name: &str) -> Result<&AeInfo> {
        self.autoencoders
            .get(name)
            .ok_or_else(|| anyhow!("unknown autoencoder {name:?}"))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a `<cfg>_test_x.bin` test batch (row-major i32 LE, [n, dim]).
    pub fn load_test_batch(&self, model: &str) -> Result<Vec<Vec<i32>>> {
        let info = self.model(model)?;
        let path = self.path(info.file("test_x")?);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % (4 * info.dim) != 0 {
            bail!("test batch size {} not a multiple of dim {}", bytes.len(), info.dim);
        }
        let n = bytes.len() / (4 * info.dim);
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let row = (0..info.dim)
                .map(|j| {
                    let o = (r * info.dim + j) * 4;
                    i32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]])
                })
                .collect();
            out.push(row);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Value {
        json::parse(
            r#"{
              "quick": true,
              "models": {
                "m1": {"kind": "explicit", "channels": 3, "height": 4, "width": 5,
                        "categories": 8, "t_fore": 2, "share_repr": true,
                        "dim": 60, "pixels": 20, "bpd": 2.5, "test_n": 4,
                        "files": {"step_b1": "m1_step_b1.hlo.txt", "step_b32": "m1_step_b32.hlo.txt"}},
                "m2": {"kind": "latent", "channels": 4, "height": 8, "width": 8,
                        "categories": 64, "t_fore": 5, "share_repr": true,
                        "dim": 256, "pixels": 64, "bpd": 1.1, "autoencoder": "ae1", "test_n": 32,
                        "files": {"step_b1": "x.hlo.txt"}}
              },
              "autoencoders": {"ae1": {"img_size": 16, "latent_channels": 4, "latent_hw": 8,
                               "categories": 64, "latent_dim": 256, "mse": 0.01}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_models_and_aes() {
        let m = Manifest::from_value("/tmp".into(), &sample_manifest()).unwrap();
        let m1 = m.model("m1").unwrap();
        assert_eq!(m1.kind, ModelKind::Explicit);
        assert_eq!(m1.dim, 60);
        assert_eq!(m1.step_batch_sizes(), vec![1, 32]);
        let m2 = m.model("m2").unwrap();
        assert_eq!(m2.autoencoder.as_deref(), Some("ae1"));
        assert_eq!(m.ae("ae1").unwrap().latent_dim, 256);
        assert!(m.quick);
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::from_value("/tmp".into(), &sample_manifest()).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.model("m1").unwrap().file("step_b64").is_err());
    }

    #[test]
    fn inconsistent_dim_rejected() {
        let mut v = sample_manifest();
        if let Value::Obj(o) = &mut v {
            if let Some(Value::Obj(models)) = o.get_mut("models") {
                if let Some(Value::Obj(m1)) = models.get_mut("m1") {
                    m1.insert("dim".into(), Value::Num(61.0));
                }
            }
        }
        assert!(Manifest::from_value("/tmp".into(), &v).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("cifar8"));
            let info = m.model("cifar8").unwrap();
            assert_eq!(info.dim, info.channels * info.height * info.width);
            let tb = m.load_test_batch("cifar8").unwrap();
            assert_eq!(tb[0].len(), info.dim);
            assert!(tb.iter().all(|r| r.iter().all(|&v| v >= 0 && (v as usize) < info.categories)));
        }
    }
}
