//! Artifact manifest: metadata for every trained model + file registry.
//!
//! `artifacts/manifest.json` is emitted by `python/compile/aot.py`. This
//! module parses it into typed structs and resolves artifact paths.

use crate::substrate::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which task a model belongs to (paper §4.1 vs §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Explicit likelihood modeling on images (Table 1).
    Explicit,
    /// ARM over the discrete latent space of an autoencoder (Table 2).
    Latent,
}

/// Pure-rust mock backend parameters. A model whose manifest entry
/// carries a `"mock"` object is served by [`crate::sampler::mock::MockArm`]
/// instead of compiled PJRT executables — used by tests, benches and the
/// serving demo to exercise the full serving stack without artifacts.
#[derive(Clone, Debug)]
pub struct MockSpec {
    /// Conditional coupling strength (0 = near-iid, large = slow FPI).
    pub strength: f32,
    /// Table seed: different seeds give different "models".
    pub seed: u64,
    /// Batch sizes to expose (stands in for the step_b* artifact set).
    pub batches: Vec<usize>,
    /// Trailing logp span lengths to expose in addition to the full-shape
    /// pass (stands in for the step_b*_s* span-variant artifact set).
    /// Empty means the model serves full-shape passes only.
    pub spans: Vec<usize>,
}

/// Static description of one ARM, mirrored from `ArmConfig.to_manifest()`.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: ModelKind,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub categories: usize,
    pub t_fore: usize,
    pub share_repr: bool,
    pub dim: usize,
    pub pixels: usize,
    /// Test-set bits/dim achieved at build time.
    pub bpd: f64,
    /// Artifact files keyed by role ("step_b1", "step_b32", "test_x", ...).
    pub files: BTreeMap<String, String>,
    /// For latent models: the paired autoencoder name.
    pub autoencoder: Option<String>,
    pub test_n: usize,
    /// Present when the model is backed by the pure-rust mock ARM.
    pub mock: Option<MockSpec>,
    /// Engine-worker indices this model is pinned to (`"pin": [0, 2]`).
    /// Consumed by the server's placement plane when it runs under the
    /// `pinned` policy; `None` means the model may replicate anywhere.
    pub pin: Option<Vec<usize>>,
}

impl ModelInfo {
    /// Batch sizes for which a step executable exists.
    pub fn step_batch_sizes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = if let Some(mock) = &self.mock {
            mock.batches.clone()
        } else {
            self.files
                .keys()
                .filter_map(|k| k.strip_prefix("step_b").and_then(|b| b.parse().ok()))
                .collect()
        };
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Every exported step-executable role as `(role, batch, span,
    /// has_fore)`: `step_b{B}` / `steplp_b{B}` full-shape entries (span ==
    /// `dim`) plus `step_b{B}_s{S}` / `steplp_b{B}_s{S}` span variants that
    /// compute logp only for the trailing `S` positions. Malformed keys are
    /// skipped, matching `step_batch_sizes`. Mock models have no files;
    /// their variant grid comes from `MockSpec::{batches, spans}`.
    pub fn step_variant_roles(&self) -> Vec<(String, usize, usize, bool)> {
        let mut out = Vec::new();
        for key in self.files.keys() {
            let (rest, has_fore) = if let Some(r) = key.strip_prefix("steplp_b") {
                (r, false)
            } else if let Some(r) = key.strip_prefix("step_b") {
                (r, true)
            } else {
                continue;
            };
            let parsed = match rest.split_once("_s") {
                Some((b, s)) => b.parse().ok().zip(s.parse().ok()),
                None => rest.parse().ok().map(|b| (b, self.dim)),
            };
            if let Some((batch, span)) = parsed {
                out.push((key.clone(), batch, span, has_fore));
            }
        }
        out
    }

    pub fn file(&self, role: &str) -> Result<&str> {
        self.files
            .get(role)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("model {} has no artifact role {role:?}", self.name))
    }
}

/// Autoencoder metadata (latent experiments).
#[derive(Clone, Debug)]
pub struct AeInfo {
    pub name: String,
    pub img_size: usize,
    pub latent_channels: usize,
    pub latent_hw: usize,
    pub categories: usize,
    pub latent_dim: usize,
    pub mse: f64,
}

/// Parsed manifest + artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub autoencoders: BTreeMap<String, AeInfo>,
    pub quick: bool,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_value(dir, &v)
    }

    fn from_value(dir: PathBuf, v: &Value) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        let model_obj = v.get("models").as_obj().ok_or_else(|| anyhow!("manifest: missing models"))?;
        for (name, m) in model_obj {
            let kind = match m.get("kind").as_str() {
                Some("explicit") => ModelKind::Explicit,
                Some("latent") => ModelKind::Latent,
                other => bail!("model {name}: bad kind {other:?}"),
            };
            let files = m
                .get("files")
                .as_obj()
                .ok_or_else(|| anyhow!("model {name}: missing files"))?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str().ok_or_else(|| anyhow!("bad file entry {k}"))?.to_string())))
                .collect::<Result<BTreeMap<_, _>>>()?;
            let req = |key: &str| -> Result<usize> {
                m.get(key).as_usize().ok_or_else(|| anyhow!("model {name}: missing {key}"))
            };
            let mock = if m.get("mock").as_obj().is_some() {
                let mo = m.get("mock");
                let batches: Vec<usize> = mo
                    .get("batches")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                    .unwrap_or_else(|| vec![1]);
                if batches.is_empty() {
                    bail!("model {name}: mock spec has no batch sizes");
                }
                let spans: Vec<usize> = mo
                    .get("spans")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                    .unwrap_or_default();
                // Seed travels as a string: JSON numbers are f64 here and
                // would silently corrupt u64 seeds above 2^53.
                let seed = match mo.get("seed") {
                    Value::Str(s) => s.parse().map_err(|_| anyhow!("model {name}: bad mock seed {s:?}"))?,
                    other => other.as_i64().unwrap_or(0) as u64,
                };
                Some(MockSpec {
                    strength: mo.get("strength").as_f64().unwrap_or(2.0) as f32,
                    seed,
                    batches,
                    spans,
                })
            } else {
                None
            };
            // Pin entries parse strictly: a malformed pin must fail the
            // manifest load, not launder into a valid-looking worker set
            // (`as_usize` would coerce -1 to 0 and drop strings).
            let pin = match m.get("pin") {
                Value::Null => None,
                Value::Arr(a) => {
                    let mut ws = Vec::with_capacity(a.len());
                    for v in a {
                        match v.as_f64() {
                            Some(f) if f >= 0.0 && f.fract() == 0.0 => ws.push(f as usize),
                            _ => bail!("model {name}: pin entries must be non-negative worker indices, got {v}"),
                        }
                    }
                    Some(ws)
                }
                other => bail!("model {name}: pin must be an array of worker indices, got {other}"),
            };
            let info = ModelInfo {
                name: name.clone(),
                kind,
                channels: req("channels")?,
                height: req("height")?,
                width: req("width")?,
                categories: req("categories")?,
                t_fore: req("t_fore")?,
                share_repr: m.get("share_repr").as_bool().unwrap_or(true),
                dim: req("dim")?,
                pixels: req("pixels")?,
                bpd: m.get("bpd").as_f64().unwrap_or(f64::NAN),
                files,
                autoencoder: m.get("autoencoder").as_str().map(String::from),
                test_n: m.get("test_n").as_usize().unwrap_or(0),
                mock,
                pin,
            };
            if info.dim != info.channels * info.pixels {
                bail!("model {name}: inconsistent dim");
            }
            if let Some(mock) = &info.mock {
                if let Some(&bad) = mock.spans.iter().find(|&&s| s == 0 || s > info.dim) {
                    bail!("model {name}: mock span {bad} outside 1..={}", info.dim);
                }
            }
            models.insert(name.clone(), info);
        }

        let mut autoencoders = BTreeMap::new();
        if let Some(obj) = v.get("autoencoders").as_obj() {
            for (name, a) in obj {
                autoencoders.insert(
                    name.clone(),
                    AeInfo {
                        name: name.clone(),
                        img_size: a.get("img_size").as_usize().unwrap_or(0),
                        latent_channels: a.get("latent_channels").as_usize().unwrap_or(0),
                        latent_hw: a.get("latent_hw").as_usize().unwrap_or(0),
                        categories: a.get("categories").as_usize().unwrap_or(0),
                        latent_dim: a.get("latent_dim").as_usize().unwrap_or(0),
                        mse: a.get("mse").as_f64().unwrap_or(f64::NAN),
                    },
                );
            }
        }

        Ok(Manifest {
            dir,
            models,
            autoencoders,
            quick: v.get("quick").as_bool().unwrap_or(false),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}; have {:?}", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn ae(&self, name: &str) -> Result<&AeInfo> {
        self.autoencoders
            .get(name)
            .ok_or_else(|| anyhow!("unknown autoencoder {name:?}"))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a `<cfg>_test_x.bin` test batch (row-major i32 LE, [n, dim]).
    pub fn load_test_batch(&self, model: &str) -> Result<Vec<Vec<i32>>> {
        let info = self.model(model)?;
        let path = self.path(info.file("test_x")?);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % (4 * info.dim) != 0 {
            bail!("test batch size {} not a multiple of dim {}", bytes.len(), info.dim);
        }
        let n = bytes.len() / (4 * info.dim);
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let row = (0..info.dim)
                .map(|j| {
                    let o = (r * info.dim + j) * 4;
                    i32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]])
                })
                .collect();
            out.push(row);
        }
        Ok(out)
    }
}

/// Parameters for one model of a mock-manifest fixture (see
/// [`write_mock_manifest`]). The flat layout is `channels * pixels`
/// variables with `height = pixels, width = 1`.
#[derive(Clone, Debug)]
pub struct MockModelSpec {
    pub name: String,
    pub channels: usize,
    pub pixels: usize,
    pub categories: usize,
    pub t_fore: usize,
    pub strength: f32,
    pub seed: u64,
    pub batches: Vec<usize>,
    /// Trailing logp span lengths exported next to the full-shape pass;
    /// empty means no span variants (full-shape serving only).
    pub spans: Vec<usize>,
    /// Optional worker pin list, written as the manifest `"pin"` field.
    pub pin: Option<Vec<usize>>,
}

impl MockModelSpec {
    /// A small, fast default spec; adjust fields as needed.
    pub fn new(name: &str, seed: u64) -> MockModelSpec {
        MockModelSpec {
            name: name.to_string(),
            channels: 2,
            pixels: 12,
            categories: 5,
            t_fore: 1,
            strength: 2.5,
            seed,
            batches: vec![1, 4],
            spans: Vec::new(),
            pin: None,
        }
    }

    /// The two-model fixture the serving bench and demo share — distinct
    /// shapes and coupling strengths so a mixed `(model, method)` stream
    /// forms incompatible batching groups that contend for engine workers.
    pub fn demo_pair() -> Vec<MockModelSpec> {
        let mut a = MockModelSpec::new("mock_a", 31);
        a.channels = 3;
        a.pixels = 64;
        a.categories = 8;
        a.strength = 3.0;
        a.batches = vec![1, 8];
        a.spans = vec![24, 48, 96]; // dim 192: span ladder for the catalog
        let mut b = MockModelSpec::new("mock_b", 17);
        b.channels = 1;
        b.pixels = 96;
        b.categories = 6;
        b.strength = 2.0;
        b.batches = vec![1, 8];
        b.spans = vec![12, 24, 48]; // dim 96
        vec![a, b]
    }
}

/// Write `<dir>/manifest.json` describing pure-mock models, creating the
/// directory. The resulting directory is a drop-in artifacts dir for
/// [`Manifest::load`] / `server::spawn` — no compiled artifacts or PJRT
/// needed — so the serving stack can be tested and benchmarked offline.
pub fn write_mock_manifest(dir: &Path, models: &[MockModelSpec]) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let mut model_objs = BTreeMap::new();
    for s in models {
        let mut entry = Value::obj(vec![
            ("kind", Value::str("explicit")),
            ("channels", Value::num(s.channels as f64)),
            ("height", Value::num(s.pixels as f64)),
            ("width", Value::num(1.0)),
            ("categories", Value::num(s.categories as f64)),
            ("t_fore", Value::num(s.t_fore as f64)),
            ("share_repr", Value::Bool(true)),
            ("dim", Value::num((s.channels * s.pixels) as f64)),
            ("pixels", Value::num(s.pixels as f64)),
            ("bpd", Value::num(0.0)),
            ("test_n", Value::num(0.0)),
            ("files", Value::Obj(BTreeMap::new())),
            (
                "mock",
                Value::obj(vec![
                    ("strength", Value::num(s.strength as f64)),
                    ("seed", Value::str(s.seed.to_string())),
                    ("batches", Value::Arr(s.batches.iter().map(|&b| Value::num(b as f64)).collect())),
                    ("spans", Value::Arr(s.spans.iter().map(|&sp| Value::num(sp as f64)).collect())),
                ]),
            ),
        ]);
        if let (Some(pin), Value::Obj(obj)) = (&s.pin, &mut entry) {
            obj.insert("pin".into(), Value::Arr(pin.iter().map(|&w| Value::num(w as f64)).collect()));
        }
        model_objs.insert(s.name.clone(), entry);
    }
    let root = Value::obj(vec![
        ("quick", Value::Bool(true)),
        ("models", Value::Obj(model_objs)),
        ("autoencoders", Value::Obj(BTreeMap::new())),
    ]);
    let path = dir.join("manifest.json");
    std::fs::write(&path, root.to_string()).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Value {
        json::parse(
            r#"{
              "quick": true,
              "models": {
                "m1": {"kind": "explicit", "channels": 3, "height": 4, "width": 5,
                        "categories": 8, "t_fore": 2, "share_repr": true,
                        "dim": 60, "pixels": 20, "bpd": 2.5, "test_n": 4,
                        "files": {"step_b1": "m1_step_b1.hlo.txt", "step_b32": "m1_step_b32.hlo.txt",
                                  "step_b1_s16": "m1_step_b1_s16.hlo.txt",
                                  "steplp_b32_s8": "m1_steplp_b32_s8.hlo.txt"}},
                "m2": {"kind": "latent", "channels": 4, "height": 8, "width": 8,
                        "categories": 64, "t_fore": 5, "share_repr": true,
                        "dim": 256, "pixels": 64, "bpd": 1.1, "autoencoder": "ae1", "test_n": 32,
                        "files": {"step_b1": "x.hlo.txt"}}
              },
              "autoencoders": {"ae1": {"img_size": 16, "latent_channels": 4, "latent_hw": 8,
                               "categories": 64, "latent_dim": 256, "mse": 0.01}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_models_and_aes() {
        let m = Manifest::from_value("/tmp".into(), &sample_manifest()).unwrap();
        let m1 = m.model("m1").unwrap();
        assert_eq!(m1.kind, ModelKind::Explicit);
        assert_eq!(m1.dim, 60);
        assert_eq!(m1.step_batch_sizes(), vec![1, 32]);
        let m2 = m.model("m2").unwrap();
        assert_eq!(m2.autoencoder.as_deref(), Some("ae1"));
        assert_eq!(m.ae("ae1").unwrap().latent_dim, 256);
        assert!(m.quick);
    }

    #[test]
    fn span_variant_roles_parse() {
        let m = Manifest::from_value("/tmp".into(), &sample_manifest()).unwrap();
        let m1 = m.model("m1").unwrap();
        // Span-variant keys must not pollute the anchor batch list.
        assert_eq!(m1.step_batch_sizes(), vec![1, 32]);
        let mut roles = m1.step_variant_roles();
        roles.sort();
        assert_eq!(
            roles,
            vec![
                ("step_b1".to_string(), 1, 60, true),
                ("step_b1_s16".to_string(), 1, 16, true),
                ("step_b32".to_string(), 32, 60, true),
                ("steplp_b32_s8".to_string(), 32, 8, false),
            ]
        );
    }

    #[test]
    fn mock_spans_roundtrip_and_validate() {
        let dir = std::env::temp_dir().join(format!("predsamp-spanman-{}", std::process::id()));
        let mut spec = MockModelSpec::new("span_m", 9);
        spec.spans = vec![6, 12];
        write_mock_manifest(&dir, &[spec]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let mock = man.model("span_m").unwrap().mock.as_ref().unwrap();
        assert_eq!(mock.spans, vec![6, 12]);
        let _ = std::fs::remove_dir_all(&dir);

        // A span wider than dim must fail the load, not surface later as a
        // catalog with an impossible variant.
        let dir2 = std::env::temp_dir().join(format!("predsamp-badspan-{}", std::process::id()));
        let mut bad = MockModelSpec::new("span_m", 9);
        bad.spans = vec![bad.channels * bad.pixels + 1];
        write_mock_manifest(&dir2, &[bad]).unwrap();
        assert!(Manifest::load(&dir2).is_err(), "span > dim must be rejected");
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::from_value("/tmp".into(), &sample_manifest()).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.model("m1").unwrap().file("step_b64").is_err());
    }

    #[test]
    fn inconsistent_dim_rejected() {
        let mut v = sample_manifest();
        if let Value::Obj(o) = &mut v {
            if let Some(Value::Obj(models)) = o.get_mut("models") {
                if let Some(Value::Obj(m1)) = models.get_mut("m1") {
                    m1.insert("dim".into(), Value::Num(61.0));
                }
            }
        }
        assert!(Manifest::from_value("/tmp".into(), &v).is_err());
    }

    #[test]
    fn mock_manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("predsamp-mockman-{}", std::process::id()));
        // A seed above 2^53 exercises the string encoding (f64 JSON
        // numbers would corrupt it silently).
        let big_seed = u64::MAX - 12345;
        let mut spec = MockModelSpec::new("mock_m", big_seed);
        spec.batches = vec![4, 1, 4];
        write_mock_manifest(&dir, &[spec]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let info = man.model("mock_m").unwrap();
        let mock = info.mock.as_ref().expect("mock spec survives roundtrip");
        assert_eq!(mock.seed, big_seed);
        assert!((mock.strength - 2.5).abs() < 1e-6);
        assert_eq!(info.step_batch_sizes(), vec![1, 4], "sorted + deduped");
        assert_eq!(info.dim, info.channels * info.pixels);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pin_field_roundtrips() {
        let dir = std::env::temp_dir().join(format!("predsamp-pinman-{}", std::process::id()));
        let mut pinned = MockModelSpec::new("pinned_m", 1);
        pinned.pin = Some(vec![0, 2]);
        let free = MockModelSpec::new("free_m", 2);
        write_mock_manifest(&dir, &[pinned, free]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.model("pinned_m").unwrap().pin, Some(vec![0, 2]), "manifest pin must survive the roundtrip");
        assert_eq!(man.model("free_m").unwrap().pin, None, "unpinned models carry no pin");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_pin_fails_manifest_load() {
        // A typo'd pin must fail the load, not launder into a
        // valid-looking worker set (as_usize would coerce -1 to 0).
        for bad in [r#"[-1]"#, r#"["2"]"#, r#"[0, 1.5]"#, r#"2"#] {
            let mut v = sample_manifest();
            if let Value::Obj(o) = &mut v {
                if let Some(Value::Obj(models)) = o.get_mut("models") {
                    if let Some(Value::Obj(m1)) = models.get_mut("m1") {
                        m1.insert("pin".into(), json::parse(bad).unwrap());
                    }
                }
            }
            assert!(Manifest::from_value("/tmp".into(), &v).is_err(), "pin {bad} must be rejected");
        }
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("cifar8"));
            let info = m.model("cifar8").unwrap();
            assert_eq!(info.dim, info.channels * info.height * info.width);
            let tb = m.load_test_batch("cifar8").unwrap();
            assert_eq!(tb[0].len(), info.dim);
            assert!(tb.iter().all(|r| r.iter().all(|&v| v >= 0 && (v as usize) < info.categories)));
        }
    }
}
