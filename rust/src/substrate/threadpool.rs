//! Fixed-size worker pool over std::thread + mpsc (tokio is unavailable
//! offline). Used by the TCP server for connection handling, by the bench
//! workload generators, and (via [`shared`]) by the mock ARM's row-parallel
//! pass-plan execution.

use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("predsamp-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("worker alive");
    }

    /// Map a function over items in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("job completed")).collect()
    }
}

/// Process-wide pool for data-parallel compute helpers (e.g. the mock
/// ARM's per-row pass-plan fill). Sized to the host's parallelism, spawned
/// on first use, and deliberately never torn down — workers idle on an
/// empty channel and cost nothing between bursts.
pub fn shared() -> &'static ThreadPool {
    static SHARED: OnceLock<ThreadPool> = OnceLock::new();
    SHARED.get_or_init(|| ThreadPool::new(thread::available_parallelism().map(|n| n.get()).unwrap_or(4)))
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn shared_pool_is_reusable() {
        let a = shared().map(vec![1, 2, 3], |x| x * 10);
        assert_eq!(a, vec![10, 20, 30]);
        let b = shared().map((0..20).collect::<Vec<i32>>(), |x| x + 1);
        assert_eq!(b, (1..21).collect::<Vec<i32>>());
    }

    #[test]
    fn zero_size_clamped() {
        let pool = ThreadPool::new(0);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
