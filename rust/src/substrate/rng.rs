//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ stream.
//!
//! `rand` is unavailable offline; this is the standard xoshiro256++
//! generator (Blackman & Vigna), which is more than adequate for sampling
//! noise. Every sampling job derives an independent stream from
//! `(global_seed, slot_id)` via splitmix64 so batched and continuous
//! scheduling produce identical per-job noise regardless of slot placement
//! — a property the scheduler tests rely on.

/// splitmix64 step — used for seeding and cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// New stream from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Independent stream for a (seed, stream-id) pair.
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ 0xA5A5_5A5A_DEAD_BEEF;
        let a = splitmix64(&mut sm);
        let mut sm2 = stream.wrapping_mul(0xD1B5_4A32_D192_ED03) ^ a;
        Rng::new(splitmix64(&mut sm2))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn uniform_open0(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free for our (non-cryptographic) needs.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn stream_independence() {
        let mut a = Rng::for_stream(7, 0);
        let mut b = Rng::for_stream(7, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
        // and reproducible
        let mut a2 = Rng::for_stream(7, 0);
        assert_eq!(va, (0..8).map(|_| a2.next_u64()).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn uniform_open0_never_zero() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.uniform_open0() > 0.0);
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
