//! Tiny argv parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown-flag detection is the caller's job via `finish()`.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(n)`).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let val = if let Some(v) = inline_val {
                    Some(v)
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    it.next()
                } else {
                    None
                };
                out.flags.entry(key).or_default().push(val.unwrap_or_default());
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// Present-or-not boolean flag (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    /// String value with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .filter(|s| !s.is_empty())
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string value.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).and_then(|v| v.last()).filter(|s| !s.is_empty()).cloned()
    }

    /// Parsed numeric value with default.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Every occurrence of a repeatable flag, in argv order, e.g.
    /// `--pin a=0 --pin b=1,2` → `["a=0", "b=1,2"]`. Empty values (a
    /// trailing `--pin` with nothing after it) are dropped.
    pub fn all(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.flags.get(key).map(|v| v.iter().filter(|s| !s.is_empty()).cloned().collect()).unwrap_or_default()
    }

    /// Comma-separated list, e.g. `--models cifar8,svhn8`.
    pub fn list(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect())
            .unwrap_or_default()
    }

    /// Error on flags that were provided but never queried.
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self.flags.keys().filter(|k| !seen.contains(k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        // Note: a non-flag token directly after `--verbose` would be
        // consumed as its value (documented ambiguity) — positionals come
        // first, or use `--key=value`.
        let a = args("sample out.ppm --model cifar8 --batch 32 --verbose");
        assert_eq!(a.positional, vec!["sample", "out.ppm"]);
        assert_eq!(a.get("model", "x"), "cifar8");
        assert_eq!(a.num::<usize>("batch", 1), 32);
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn eq_syntax_and_defaults() {
        let a = args("--seeds=5");
        assert_eq!(a.num::<u64>("seeds", 1), 5);
        assert_eq!(a.get("missing", "dflt"), "dflt");
        assert_eq!(a.opt("missing"), None);
    }

    #[test]
    fn lists() {
        let a = args("--models cifar8,svhn8, mnist_bin");
        // note: space after comma splits the token; only the attached ones count
        assert_eq!(a.list("models"), vec!["cifar8", "svhn8"]);
    }

    #[test]
    fn repeated_flags_all_collected() {
        let a = args("--pin a=0 --pin b=1,2 --other x");
        assert_eq!(a.all("pin"), vec!["a=0", "b=1,2"]);
        assert_eq!(a.all("absent"), Vec::<String>::new());
        let _ = a.get("other", "");
        assert!(a.finish().is_ok(), "all() must mark the flag as seen");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = args("--oops 1 --fine 2");
        let _ = a.get("fine", "");
        let err = a.finish().unwrap_err();
        assert!(err.contains("oops"));
    }

    #[test]
    fn flag_without_value_before_flag() {
        let a = args("--dry-run --n 3");
        assert!(a.flag("dry-run"));
        assert_eq!(a.num::<u32>("n", 0), 3);
    }
}
