//! Gumbel-max reparametrization noise (paper §2.2, Appendix B).
//!
//! The coordinator owns the reparametrization: it samples ε ~ G^{d×K}
//! once per job and computes `x_i = argmax_c(logp_i,c + ε_i,c)` against the
//! ARM's log-probs. Because ε is fixed across fixed-point iterations, the
//! sampling pass is a deterministic function — the insight that lets
//! predictive sampling verify forecasts by exact value equality.
//!
//! `posterior_gumbel` mirrors Appendix B (used by tests and by tooling
//! that needs noise consistent with a given sample); the python twin lives
//! in `python/compile/gumbel.py`.

use super::rng::Rng;

/// One standard Gumbel(0,1) draw.
#[inline]
pub fn sample_gumbel(rng: &mut Rng) -> f64 {
    -(-rng.uniform_open0().ln()).ln()
}

/// Fill a buffer with standard Gumbel noise (f32 storage, f64 math).
pub fn fill_gumbel(rng: &mut Rng, out: &mut [f32]) {
    for v in out.iter_mut() {
        *v = sample_gumbel(rng) as f32;
    }
}

/// `argmax_c(logp[c] + eps[c])` — the reparametrized categorical sample.
#[inline]
pub fn gumbel_argmax(logp: &[f32], eps: &[f32]) -> usize {
    debug_assert_eq!(logp.len(), eps.len());
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (c, (&lp, &e)) in logp.iter().zip(eps.iter()).enumerate() {
        let v = lp + e;
        if v > best_v {
            best_v = v;
            best = c;
        }
    }
    best
}

/// Plain argmax over logp (the "without reparametrization" ablation's
/// greedy forecast, Table 3).
#[inline]
pub fn argmax(logp: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (c, &lp) in logp.iter().enumerate() {
        if lp > best_v {
            best_v = lp;
            best = c;
        }
    }
    best
}

/// Sample Gumbel(mu) truncated to (-inf, bound] via the max-coupling
/// identity `TG = -log(exp(-bound) + exp(-G))` (Maddison et al. 2014).
#[inline]
fn trunc_gumbel(rng: &mut Rng, mu: f64, bound: f64) -> f64 {
    let g = mu + sample_gumbel(rng);
    // -logaddexp(-bound, -g), computed stably.
    let (hi, lo) = if -bound > -g { (-bound, -g) } else { (-g, -bound) };
    -(hi + (1.0 + (lo - hi).exp()).ln())
}

/// Posterior noise p(ε | x) for one categorical: given log-probs `logp`
/// and the observed sample `x`, returns ε such that
/// `gumbel_argmax(logp, ε) == x` and every component is marginally G(0,1).
///
/// Uses the max-trick decomposition (Maddison et al. 2014; Kool et al.
/// 2019): the maximum `M = max_c(μ_c + ε_c)` is Gumbel(logsumexp μ) and
/// independent of the argmax, so sample M first, pin the winning
/// coordinate's value to it, and truncate the losers below it.
pub fn posterior_gumbel(rng: &mut Rng, logp: &[f32], x: usize, out: &mut [f32]) {
    let mu_x = logp[x] as f64;
    // logsumexp(μ); 0 for normalized log-probs, computed for robustness.
    let mx = logp.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = mx + logp.iter().map(|&l| ((l as f64) - mx).exp()).sum::<f64>().ln();
    let max_val = lse + sample_gumbel(rng);
    for (c, (&lp, o)) in logp.iter().zip(out.iter_mut()).enumerate() {
        if c == x {
            *o = (max_val - mu_x) as f32;
        } else {
            *o = (trunc_gumbel(rng, lp as f64, max_val) - lp as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    const EULER: f64 = 0.577_215_664_901_532_9;

    #[test]
    fn gumbel_moments() {
        let mut rng = Rng::new(0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = sample_gumbel(&mut rng);
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - EULER).abs() < 0.02, "mean {mean}");
        assert!((var - std::f64::consts::PI.powi(2) / 6.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn argmax_matches_frequencies() {
        // Gumbel-max over log [0.5, 0.3, 0.2] reproduces the categorical.
        let logp: Vec<f32> = [0.5f32, 0.3, 0.2].iter().map(|p| p.ln()).collect();
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 3];
        let n = 50_000;
        let mut eps = [0f32; 3];
        for _ in 0..n {
            fill_gumbel(&mut rng, &mut eps);
            counts[gumbel_argmax(&logp, &eps)] += 1;
        }
        for (c, &p) in [0.5f64, 0.3, 0.2].iter().enumerate() {
            let f = counts[c] as f64 / n as f64;
            assert!((f - p).abs() < 0.01, "cat {c}: {f} vs {p}");
        }
    }

    #[test]
    fn posterior_is_argmax_consistent() {
        let mut rng = Rng::new(2);
        for k in [2usize, 5, 64, 256] {
            let mut logits: Vec<f32> = (0..k).map(|_| rng.uniform() as f32 * 4.0 - 2.0).collect();
            // log-softmax normalize
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = logits.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
            for l in logits.iter_mut() {
                *l -= z;
            }
            let mut eps = vec![0f32; k];
            for x in 0..k.min(8) {
                posterior_gumbel(&mut rng, &logits, x, &mut eps);
                assert_eq!(gumbel_argmax(&logits, &eps), x, "k={k} x={x}");
            }
        }
    }

    #[test]
    fn posterior_marginal_is_gumbel() {
        // x ~ model, then ε|x: marginals must be standard Gumbel.
        let probs = [0.4f64, 0.35, 0.25];
        let logp: Vec<f32> = probs.iter().map(|p| p.ln() as f32).collect();
        let mut rng = Rng::new(3);
        let n = 60_000;
        let mut sums = [0.0f64; 3];
        let mut eps = [0f32; 3];
        let mut post = [0f32; 3];
        for _ in 0..n {
            fill_gumbel(&mut rng, &mut eps);
            let x = gumbel_argmax(&logp, &eps);
            posterior_gumbel(&mut rng, &logp, x, &mut post);
            for c in 0..3 {
                sums[c] += post[c] as f64;
            }
        }
        for c in 0..3 {
            let mean = sums[c] / n as f64;
            assert!((mean - EULER).abs() < 0.03, "cat {c} mean {mean}");
        }
    }

    #[test]
    fn plain_argmax() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
