//! Offline-friendly substrates.
//!
//! This build environment has no crates.io access beyond the `xla` crate's
//! vendored closure, so the usual ecosystem crates are re-implemented here
//! at the scale this project needs: [`rng`] (rand), [`json`] (serde_json),
//! [`cli`] (clap), [`stats`]/[`timer`] (criterion internals),
//! [`threadpool`] (tokio's blocking pool), [`proptest_lite`] (proptest),
//! [`readiness`] (mio's poll/epoll core, as inline FFI), plus domain
//! substrates [`gumbel`] (reparametrization noise) and [`image`] (PPM
//! figure output).

pub mod cli;
pub mod gumbel;
pub mod image;
pub mod json;
pub mod proptest_lite;
pub mod readiness;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod threadpool;
pub mod timer;
