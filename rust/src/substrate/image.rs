//! PPM/PGM figure output: samples, red forecast-mistake overlays (paper
//! Figs. 3-5), and log-scale convergence heatmaps (Fig. 6).
//!
//! Binary PPM (P6) needs no external codecs and is readable by every image
//! tool; figures are written under `results/`.

use std::io::Write;
use std::path::Path;

/// 8-bit RGB raster.
#[derive(Clone, Debug)]
pub struct Image {
    pub w: usize,
    pub h: usize,
    pub data: Vec<[u8; 3]>, // row-major
}

impl Image {
    pub fn new(w: usize, h: usize) -> Image {
        Image { w, h, data: vec![[0, 0, 0]; w * h] }
    }

    pub fn set(&mut self, x: usize, y: usize, px: [u8; 3]) {
        self.data[y * self.w + x] = px;
    }
    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        self.data[y * self.w + x]
    }

    /// From per-pixel grayscale values in [0, 1].
    pub fn from_gray(w: usize, h: usize, vals: &[f32]) -> Image {
        assert_eq!(vals.len(), w * h);
        let mut im = Image::new(w, h);
        for (i, &v) in vals.iter().enumerate() {
            let g = (v.clamp(0.0, 1.0) * 255.0) as u8;
            im.data[i] = [g, g, g];
        }
        im
    }

    /// From per-pixel RGB values in [0, 1], channel-major [3, h, w].
    pub fn from_rgb_chw(w: usize, h: usize, vals: &[f32]) -> Image {
        assert_eq!(vals.len(), 3 * w * h);
        let mut im = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                let px = [
                    (vals[y * w + x].clamp(0.0, 1.0) * 255.0) as u8,
                    (vals[h * w + y * w + x].clamp(0.0, 1.0) * 255.0) as u8,
                    (vals[2 * h * w + y * w + x].clamp(0.0, 1.0) * 255.0) as u8,
                ];
                im.set(x, y, px);
            }
        }
        im
    }

    /// Red-shaded mistake overlay (paper Figs. 3-4): `frac` in [0,1] is the
    /// fraction of channels mispredicted at each pixel; 0 keeps the base
    /// pixel, 1 is fully red.
    pub fn overlay_mistakes(&mut self, frac: &[f32]) {
        assert_eq!(frac.len(), self.w * self.h);
        for (px, &f) in self.data.iter_mut().zip(frac.iter()) {
            let f = f.clamp(0.0, 1.0);
            if f > 0.0 {
                px[0] = (px[0] as f32 * (1.0 - f) + 255.0 * f) as u8;
                px[1] = (px[1] as f32 * (1.0 - f)) as u8;
                px[2] = (px[2] as f32 * (1.0 - f)) as u8;
            }
        }
    }

    /// Log-scale heat colormap (black → red → yellow → white), as used for
    /// the Fig. 6 convergence comparison. `vals` are positive iteration
    /// counts; `vmax` the color scale maximum.
    pub fn from_heat_log(w: usize, h: usize, vals: &[f32], vmax: f32) -> Image {
        assert_eq!(vals.len(), w * h);
        let lmax = (1.0 + vmax.max(1.0)).ln();
        let mut im = Image::new(w, h);
        for (i, &v) in vals.iter().enumerate() {
            let t = ((1.0 + v.max(0.0)).ln() / lmax).clamp(0.0, 1.0);
            im.data[i] = heat_color(t);
        }
        im
    }

    /// Nearest-neighbour upscale (for 8×8 latent maps shown at 32×32).
    pub fn upscale(&self, factor: usize) -> Image {
        let mut out = Image::new(self.w * factor, self.h * factor);
        for y in 0..out.h {
            for x in 0..out.w {
                out.set(x, y, self.get(x / factor, y / factor));
            }
        }
        out
    }

    /// Tile a gallery of images into a grid with 1px separators.
    pub fn grid(tiles: &[Image], cols: usize) -> Image {
        assert!(!tiles.is_empty());
        let (tw, th) = (tiles[0].w, tiles[0].h);
        let rows = tiles.len().div_ceil(cols);
        let mut out = Image::new(cols * (tw + 1) + 1, rows * (th + 1) + 1);
        for px in out.data.iter_mut() {
            *px = [40, 40, 40];
        }
        for (i, t) in tiles.iter().enumerate() {
            let (r, c) = (i / cols, i % cols);
            for y in 0..th {
                for x in 0..tw {
                    out.set(c * (tw + 1) + 1 + x, r * (th + 1) + 1 + y, t.get(x, y));
                }
            }
        }
        out
    }

    /// Write binary PPM (P6).
    pub fn write_ppm<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "P6\n{} {}\n255\n", self.w, self.h)?;
        for px in &self.data {
            f.write_all(px)?;
        }
        Ok(())
    }

    /// Coarse ASCII rendering for terminal output (benches print these).
    pub fn to_ascii(&self) -> String {
        const RAMP: &[u8] = b" .:-=+*#%@";
        let mut s = String::new();
        for y in 0..self.h {
            for x in 0..self.w {
                let [r, g, b] = self.get(x, y);
                let lum = (0.3 * r as f32 + 0.6 * g as f32 + 0.1 * b as f32) / 255.0;
                let idx = ((lum * (RAMP.len() - 1) as f32) as usize).min(RAMP.len() - 1);
                s.push(RAMP[idx] as char);
            }
            s.push('\n');
        }
        s
    }
}

fn heat_color(t: f32) -> [u8; 3] {
    // piecewise black -> red -> yellow -> white
    let t = t.clamp(0.0, 1.0);
    if t < 1.0 / 3.0 {
        let u = t * 3.0;
        [(u * 255.0) as u8, 0, 0]
    } else if t < 2.0 / 3.0 {
        let u = (t - 1.0 / 3.0) * 3.0;
        [255, (u * 255.0) as u8, 0]
    } else {
        let u = (t - 2.0 / 3.0) * 3.0;
        [255, 255, (u * 255.0) as u8]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_roundtrip() {
        let im = Image::from_gray(2, 2, &[0.0, 0.5, 1.0, 0.25]);
        assert_eq!(im.get(0, 0), [0, 0, 0]);
        assert_eq!(im.get(1, 1), [63, 63, 63]);
        assert_eq!(im.get(0, 1), [255, 255, 255]);
    }

    #[test]
    fn rgb_chw_layout() {
        // r=1 at (0,0), g=1 at (1,0), b=1 at (0,1)
        let mut vals = vec![0.0f32; 12];
        vals[0] = 1.0; // r channel, pixel (0,0)
        vals[4 + 1] = 1.0; // g channel, pixel (1,0)
        vals[8 + 2] = 1.0; // b channel, pixel (0,1)
        let im = Image::from_rgb_chw(2, 2, &vals);
        assert_eq!(im.get(0, 0), [255, 0, 0]);
        assert_eq!(im.get(1, 0), [0, 255, 0]);
        assert_eq!(im.get(0, 1), [0, 0, 255]);
    }

    #[test]
    fn mistakes_shading() {
        let mut im = Image::from_gray(2, 1, &[1.0, 1.0]);
        im.overlay_mistakes(&[0.0, 1.0]);
        assert_eq!(im.get(0, 0), [255, 255, 255]);
        assert_eq!(im.get(1, 0), [255, 0, 0]);
    }

    #[test]
    fn heatmap_monotone() {
        let im = Image::from_heat_log(3, 1, &[0.0, 10.0, 100.0], 100.0);
        let lum = |p: [u8; 3]| p[0] as u32 + p[1] as u32 + p[2] as u32;
        assert!(lum(im.get(0, 0)) < lum(im.get(1, 0)));
        assert!(lum(im.get(1, 0)) < lum(im.get(2, 0)));
    }

    #[test]
    fn upscale_and_grid() {
        let im = Image::from_gray(2, 2, &[0.0, 1.0, 1.0, 0.0]).upscale(3);
        assert_eq!((im.w, im.h), (6, 6));
        assert_eq!(im.get(4, 0), [255, 255, 255]);
        let g = Image::grid(&[im.clone(), im.clone(), im], 2);
        assert_eq!(g.w, 2 * 7 + 1);
        assert_eq!(g.h, 2 * 7 + 1);
    }

    #[test]
    fn ppm_write(
    ) {
        let dir = std::env::temp_dir().join("predsamp_img_test");
        let p = dir.join("t.ppm");
        Image::from_gray(4, 4, &[0.5; 16]).write_ppm(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n4 4\n255\n"));
        assert_eq!(bytes.len(), 11 + 48);
    }

    #[test]
    fn ascii_render() {
        let s = Image::from_gray(2, 1, &[0.0, 1.0]).to_ascii();
        assert_eq!(s, " @\n");
    }
}
