//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |gen| ...)` runs a property over `cases` seeded
//! random inputs. On failure it reports the failing case's seed so the
//! case can be replayed with `check_seed`. No shrinking — cases here are
//! small enough to debug directly from the seed.

use super::rng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f64_in(lo as f64, hi as f64) as f32).collect()
    }
    pub fn vec_i32_in(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.rng.range(lo as i64, hi as i64) as i32).collect()
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

/// Run `prop` over `cases` seeded random generators; panics with the
/// failing seed on the first property violation.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = 0x5EED_0000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let mut g = Gen { rng: Rng::for_stream(seed, 0), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (replay seed {seed}): {msg}");
        }
    }
}

/// Replay a single case by seed (for debugging a reported failure).
pub fn check_seed<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Rng::for_stream(seed, 0), case: 0 };
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed (seed {seed}): {msg}");
    }
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {
        if $a != $b {
            return Err(format!("{:?} != {:?}: {}", $a, $b, format!($($fmt)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check("sum-commutes", 25, |g| {
            **counter.borrow_mut() += 1;
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-12, "{a} {b}");
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        check("gen-bounds", 50, |g| {
            let n = g.usize_in(1, 10);
            prop_assert!((1..10).contains(&n), "usize_in out of range: {n}");
            let v = g.vec_i32_in(n, -3, 7);
            prop_assert!(v.iter().all(|&x| (-3..7).contains(&x)), "vec_i32_in out of range");
            let f = g.vec_f32(n, 0.0, 1.0);
            prop_assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)), "vec_f32 out of range");
            Ok(())
        });
    }
}
