//! Socket-readiness sources for the sharded connection plane.
//!
//! The connection plane (`coordinator::server::conn`) needs exactly one
//! answer per loop iteration: *which registered sockets are worth
//! servicing right now?* This module abstracts that question behind the
//! [`ReadinessSource`] trait — `register`/`deregister`/`rearm`/`wait`
//! over opaque [`Token`]s — with two implementations:
//!
//! * [`ScanSource`] — the portable fallback. `wait` sleeps on a condvar
//!   (interruptible by the [`Waker`]) and then reports **every**
//!   registered token, reproducing the pre-sharding nonblocking scan
//!   bit for bit: each tick costs O(open connections).
//! * [`EpollSource`] (Linux only) — a thin FFI shim over raw
//!   `epoll_create1`/`epoll_ctl`/`epoll_wait`, edge-triggered with
//!   `EPOLLONESHOT` and explicit [`ReadinessSource::rearm`]. `wait`
//!   reports only sockets the kernel flagged, so a tick costs O(ready)
//!   regardless of how many idle connections are parked. The waker is
//!   an `eventfd` registered like any other fd: an engine completion
//!   interrupts `epoll_wait` instantly instead of waiting out the idle
//!   tick.
//!
//! The FFI is declared inline in the vendored style (no new crates):
//! std already links libc on every supported platform, so the symbols
//! resolve without adding a dependency. Call sites stay std-only — raw
//! fds come from `std::os::fd::AsRawFd`.
//!
//! Token [`Token::MAX`](u64::MAX) is reserved for the source's internal
//! waker; callers must register user fds with smaller tokens.

use std::io;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Raw file descriptor, as registered with a source. On non-Unix
/// platforms (where only [`ScanSource`] exists and the value is
/// ignored) any placeholder works.
#[cfg(unix)]
pub type RawFd = std::os::fd::RawFd;
/// Raw file descriptor placeholder for non-Unix platforms.
#[cfg(not(unix))]
pub type RawFd = i32;

/// Opaque registration token; reported back by [`ReadinessSource::wait`].
/// `u64::MAX` is reserved for the source's internal waker.
pub type Token = u64;

/// Which readiness classes a registration currently cares about.
///
/// Hangup/error conditions are always reported by kernel backends even
/// when both flags are off, so a parked connection (nothing to write,
/// unwilling to read) still wakes its shard when the peer disconnects.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Interest {
    /// Wake when the fd has bytes to read (or the peer half-closed).
    pub read: bool,
    /// Wake when the fd can accept writes.
    pub write: bool,
}

impl Interest {
    /// Read-readiness only.
    pub const READ: Interest = Interest { read: true, write: false };
    /// Write-readiness only.
    pub const WRITE: Interest = Interest { read: false, write: true };
    /// Both classes.
    pub const BOTH: Interest = Interest { read: true, write: true };
    /// Neither class — hangup/error notifications only.
    pub const NONE: Interest = Interest { read: false, write: false };
}

/// Handle that interrupts a blocked [`ReadinessSource::wait`] from any
/// thread. Cloned (via `Arc`) into completion senders so engine replies
/// wake the owning shard immediately.
pub trait Waker: Send + Sync {
    /// Interrupt the source's current (or next) `wait`.
    fn wake(&self);
}

/// A waker that does nothing. Fixture for completions that have no
/// event loop behind them (tests, discarded replies).
pub struct NoopWaker;

impl Waker for NoopWaker {
    fn wake(&self) {}
}

/// One shard's answer to "which sockets should I service this tick?".
///
/// Implementations are single-owner (`&mut self` everywhere): a source
/// lives on exactly one shard thread, and only its [`Waker`] is shared.
pub trait ReadinessSource: Send {
    /// Stable label for metrics and logs (`"scan"` / `"epoll"`).
    fn backend(&self) -> &'static str;
    /// Start watching `fd` under `token` with the given interest.
    fn register(&mut self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()>;
    /// Refresh `fd`'s interest after servicing it. Kernel backends are
    /// one-shot: a token is reported at most once per `register`/`rearm`,
    /// so the loop must rearm every serviced fd it keeps.
    fn rearm(&mut self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()>;
    /// Stop watching `fd`. Must be called before the fd is closed.
    fn deregister(&mut self, fd: RawFd, token: Token) -> io::Result<()>;
    /// Block up to `timeout` for readiness and fill `out` (cleared
    /// first) with the ready tokens. Returns early — possibly with an
    /// empty `out` — when the [`Waker`] fires.
    fn wait(&mut self, timeout: Duration, out: &mut Vec<Token>) -> io::Result<()>;
    /// This source's waker. Safe to hold beyond the source's lifetime.
    fn waker(&self) -> Arc<dyn Waker>;
}

/// Which readiness backend to use; `ServeConfig::readiness` /
/// `--readiness {scan,epoll,auto}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadinessKind {
    /// Pick the best backend for this platform (epoll on Linux,
    /// scan elsewhere).
    Auto,
    /// Portable full-scan fallback; O(open connections) per tick.
    Scan,
    /// Linux epoll; O(ready) per tick.
    Epoll,
}

impl ReadinessKind {
    /// Parse a CLI/config spelling (`"auto"` / `"scan"` / `"epoll"`).
    pub fn parse(s: &str) -> Option<ReadinessKind> {
        match s {
            "auto" => Some(ReadinessKind::Auto),
            "scan" => Some(ReadinessKind::Scan),
            "epoll" => Some(ReadinessKind::Epoll),
            _ => None,
        }
    }

    /// The spelling `parse` accepts, also used as the metrics label.
    pub fn label(&self) -> &'static str {
        match self {
            ReadinessKind::Auto => "auto",
            ReadinessKind::Scan => "scan",
            ReadinessKind::Epoll => "epoll",
        }
    }

    /// Resolve `Auto` to the concrete backend for this platform.
    pub fn resolve(&self) -> ReadinessKind {
        match self {
            ReadinessKind::Auto => {
                if cfg!(target_os = "linux") {
                    ReadinessKind::Epoll
                } else {
                    ReadinessKind::Scan
                }
            }
            k => *k,
        }
    }

    /// Whether the resolved backend can be constructed on this platform.
    pub fn supported(&self) -> bool {
        match self.resolve() {
            ReadinessKind::Epoll => cfg!(target_os = "linux"),
            _ => true,
        }
    }
}

/// Construct a fresh source of the resolved kind. Each connection shard
/// owns one.
pub fn source(kind: ReadinessKind) -> io::Result<Box<dyn ReadinessSource>> {
    match kind.resolve() {
        ReadinessKind::Scan => Ok(Box::new(ScanSource::new())),
        #[cfg(target_os = "linux")]
        ReadinessKind::Epoll => Ok(Box::new(EpollSource::new()?)),
        #[cfg(not(target_os = "linux"))]
        ReadinessKind::Epoll => {
            Err(io::Error::new(io::ErrorKind::Unsupported, "epoll readiness requires linux; use --readiness scan (or auto)"))
        }
        ReadinessKind::Auto => unreachable!("resolve() never returns Auto"),
    }
}

// ---------------------------------------------------------------------------
// ScanSource: portable condvar-paced full scan
// ---------------------------------------------------------------------------

struct ScanSignal {
    woken: Mutex<bool>,
    cv: Condvar,
}

impl Waker for ScanSignal {
    fn wake(&self) {
        let mut woken = self.woken.lock().unwrap_or_else(|e| e.into_inner());
        *woken = true;
        self.cv.notify_one();
    }
}

/// Portable fallback: every registered token is reported every tick, so
/// the loop scans all its sockets exactly as the pre-sharding edge did.
/// `wait` sleeps on a condvar between ticks; the waker cuts the sleep
/// short (a wake that lands while the loop is servicing is latched and
/// consumed by the next `wait`, so no wakeup is ever lost).
pub struct ScanSource {
    tokens: Vec<Token>,
    signal: Arc<ScanSignal>,
}

impl ScanSource {
    /// New empty source.
    pub fn new() -> ScanSource {
        ScanSource { tokens: Vec::new(), signal: Arc::new(ScanSignal { woken: Mutex::new(false), cv: Condvar::new() }) }
    }
}

impl Default for ScanSource {
    fn default() -> ScanSource {
        ScanSource::new()
    }
}

impl ReadinessSource for ScanSource {
    fn backend(&self) -> &'static str {
        "scan"
    }

    fn register(&mut self, _fd: RawFd, token: Token, _interest: Interest) -> io::Result<()> {
        self.tokens.push(token);
        Ok(())
    }

    fn rearm(&mut self, _fd: RawFd, _token: Token, _interest: Interest) -> io::Result<()> {
        Ok(())
    }

    fn deregister(&mut self, _fd: RawFd, token: Token) -> io::Result<()> {
        self.tokens.retain(|&t| t != token);
        Ok(())
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Token>) -> io::Result<()> {
        out.clear();
        if !timeout.is_zero() {
            let mut woken = self.signal.woken.lock().unwrap_or_else(|e| e.into_inner());
            if !*woken {
                let (guard, _) = self.signal.cv.wait_timeout(woken, timeout).unwrap_or_else(|e| e.into_inner());
                woken = guard;
            }
            *woken = false;
        }
        out.extend_from_slice(&self.tokens);
        Ok(())
    }

    fn waker(&self) -> Arc<dyn Waker> {
        self.signal.clone()
    }
}

// ---------------------------------------------------------------------------
// EpollSource: Linux edge-triggered epoll + eventfd waker
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    //! Minimal inline FFI for epoll/eventfd. std links libc on Linux,
    //! so these symbols resolve with no added dependency.
    use std::os::raw::{c_int, c_void};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLONESHOT: u32 = 1 << 30;
    pub const EPOLLET: u32 = 1 << 31;
    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// Kernel `struct epoll_event`. Packed on x86-64 (the kernel ABI
    /// there has no padding between `events` and `data`); naturally
    /// aligned everywhere else.
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    /// Kernel `struct epoll_event` (non-x86-64 layout).
    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout_ms: c_int) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
    }

    /// Turn a `-1`-on-error libc return into an `io::Result`.
    pub fn cvt(ret: c_int) -> std::io::Result<c_int> {
        if ret < 0 {
            Err(std::io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }
}

/// Internal token for the eventfd waker; never emitted to callers.
#[cfg(target_os = "linux")]
const WAKER_TOKEN: Token = Token::MAX;

#[cfg(target_os = "linux")]
struct EventFdWaker {
    fd: RawFd,
}

#[cfg(target_os = "linux")]
impl Waker for EventFdWaker {
    fn wake(&self) {
        let one: u64 = 1;
        // A full eventfd counter (EAGAIN) already guarantees a pending
        // wakeup, so the result is ignorable.
        // SAFETY: `self.fd` is the eventfd this waker owns (open until our
        // Drop), and the buffer is a valid, live 8-byte u64 on this stack.
        unsafe { sys::write(self.fd, (&one as *const u64).cast(), 8) };
    }
}

#[cfg(target_os = "linux")]
impl Drop for EventFdWaker {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the eventfd opened in `EpollSource::new`,
        // owned uniquely by this waker; nothing closes it before Drop, so
        // this cannot double-close or hit a recycled descriptor.
        unsafe { sys::close(self.fd) };
    }
}

/// Linux epoll backend: edge-triggered, `EPOLLONESHOT` per registration
/// (the loop rearms each serviced fd explicitly, so a slow connection
/// can never be reported twice before it is handled). The waker is a
/// nonblocking `eventfd` registered under a reserved token; `wait`
/// drains it internally and never reports it to the caller.
///
/// The waker `Arc` owns the eventfd, so completion senders holding it
/// stay safe even if the source (and its epoll fd) is dropped first.
#[cfg(target_os = "linux")]
pub struct EpollSource {
    epfd: RawFd,
    wake: Arc<EventFdWaker>,
    events: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollSource {
    /// Create the epoll instance and its eventfd waker.
    pub fn new() -> io::Result<EpollSource> {
        // SAFETY: epoll_create1 takes no pointers; flags are a valid flag
        // set and the return value is error-checked by `cvt`.
        let epfd = sys::cvt(unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) })?;
        // SAFETY: eventfd takes no pointers; initval/flags are valid and
        // the return value is error-checked by `cvt`.
        let efd = match sys::cvt(unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) }) {
            Ok(fd) => fd,
            Err(e) => {
                // SAFETY: `epfd` was just opened above, is owned by this
                // function, and nothing else has closed it on this path.
                unsafe { sys::close(epfd) };
                return Err(e);
            }
        };
        let wake = Arc::new(EventFdWaker { fd: efd });
        let mut src = EpollSource { epfd, wake, events: vec![sys::EpollEvent { events: 0, data: 0 }; 256] };
        // Level-triggered is fine for the waker: it is drained to zero
        // every time it is seen, and a write after the drain re-raises.
        if let Err(e) = src.ctl(sys::EPOLL_CTL_ADD, efd, sys::EPOLLIN, WAKER_TOKEN) {
            // SAFETY: `epfd` is still open (only this function owns it);
            // `efd` is left to the waker's Drop, so no fd leaks or
            // double-closes on this error path.
            unsafe { sys::close(epfd) };
            return Err(e);
        }
        Ok(src)
    }

    fn ctl(&mut self, op: std::os::raw::c_int, fd: RawFd, events: u32, token: Token) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        // SAFETY: `self.epfd` is the live epoll fd this source owns; `ev`
        // is a valid, `#[repr(C, packed)]`-compatible event struct that
        // outlives the call (the kernel copies it before returning).
        sys::cvt(unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) })?;
        Ok(())
    }

    fn interest_bits(interest: Interest) -> u32 {
        let mut ev = sys::EPOLLRDHUP | sys::EPOLLET | sys::EPOLLONESHOT;
        if interest.read {
            ev |= sys::EPOLLIN;
        }
        if interest.write {
            ev |= sys::EPOLLOUT;
        }
        ev
    }

    fn drain_waker(&self) {
        let mut buf = [0u8; 8];
        // One read zeroes a (non-semaphore) eventfd counter.
        // SAFETY: the waker's eventfd is open for our lifetime (the Arc
        // keeps it alive) and `buf` is a live 8-byte buffer on this stack.
        unsafe { sys::read(self.wake.fd, buf.as_mut_ptr().cast(), 8) };
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollSource {
    fn drop(&mut self) {
        // SAFETY: `self.epfd` was opened in `new` and is owned uniquely by
        // this source (the waker holds only the eventfd), so this is the
        // single close of a still-open descriptor.
        unsafe { sys::close(self.epfd) };
    }
}

#[cfg(target_os = "linux")]
impl ReadinessSource for EpollSource {
    fn backend(&self) -> &'static str {
        "epoll"
    }

    fn register(&mut self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()> {
        debug_assert_ne!(token, WAKER_TOKEN, "Token::MAX is reserved for the waker");
        self.ctl(sys::EPOLL_CTL_ADD, fd, Self::interest_bits(interest), token)
    }

    fn rearm(&mut self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()> {
        // MOD re-delivers an edge if the fd is *currently* ready, so a
        // readiness change that raced the servicing pass is never lost.
        self.ctl(sys::EPOLL_CTL_MOD, fd, Self::interest_bits(interest), token)
    }

    fn deregister(&mut self, fd: RawFd, token: Token) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, token)
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Token>) -> io::Result<()> {
        out.clear();
        // Round sub-millisecond timeouts up so a near-term deadline
        // cannot degenerate into a busy spin.
        let ms = if timeout.is_zero() { 0 } else { timeout.as_millis().clamp(1, i32::MAX as u128) as std::os::raw::c_int };
        loop {
            // SAFETY: `self.epfd` is live; the events pointer/len describe
            // our owned, correctly-sized buffer, which the kernel fills
            // with at most `len` entries before returning.
            let n = unsafe { sys::epoll_wait(self.epfd, self.events.as_mut_ptr(), self.events.len() as std::os::raw::c_int, ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            let mut saw_waker = false;
            for ev in self.events.iter().take(n as usize) {
                let token = ev.data;
                if token == WAKER_TOKEN {
                    saw_waker = true;
                } else {
                    out.push(token);
                }
            }
            if saw_waker {
                self.drain_waker();
            }
            return Ok(());
        }
    }

    fn waker(&self) -> Arc<dyn Waker> {
        self.wake.clone()
    }
}

// ---------------------------------------------------------------------------
// File-descriptor budget (used by the high-connection bench)
// ---------------------------------------------------------------------------

/// Best-effort raise of this process's open-file soft limit to its hard
/// limit, returning the resulting soft limit. High-connection scenarios
/// (the `serving_load` edge-scale bench holds thousands of sockets per
/// process) call this first and size themselves to the answer. On
/// non-Linux platforms this is a no-op that reports "no limit".
pub fn raise_nofile_limit() -> u64 {
    #[cfg(target_os = "linux")]
    {
        use std::os::raw::c_int;
        #[repr(C)]
        struct RLimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
            fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
        }
        const RLIMIT_NOFILE: c_int = 7;
        let mut lim = RLimit { cur: 0, max: 0 };
        // SAFETY: `lim` is a live, `#[repr(C)]` rlimit-shaped struct the
        // kernel writes both fields of; the resource id is a valid constant.
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 1024;
        }
        if lim.cur < lim.max {
            let raised = RLimit { cur: lim.max, max: lim.max };
            // SAFETY: `raised` is a live `#[repr(C)]` rlimit-shaped struct
            // read (never written) by the kernel for the duration of the call.
            if unsafe { setrlimit(RLIMIT_NOFILE, &raised) } == 0 {
                return lim.max;
            }
        }
        lim.cur
    }
    #[cfg(not(target_os = "linux"))]
    {
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Instant;

    #[test]
    fn scan_reports_every_registered_token() {
        let mut src = ScanSource::new();
        src.register(-1, 7, Interest::READ).unwrap();
        src.register(-1, 9, Interest::BOTH).unwrap();
        let mut out = Vec::new();
        src.wait(Duration::ZERO, &mut out).unwrap();
        assert_eq!(out, vec![7, 9]);
        // rearm is a no-op; the next tick reports both again.
        src.rearm(-1, 7, Interest::NONE).unwrap();
        src.wait(Duration::ZERO, &mut out).unwrap();
        assert_eq!(out, vec![7, 9]);
        src.deregister(-1, 7).unwrap();
        src.wait(Duration::ZERO, &mut out).unwrap();
        assert_eq!(out, vec![9]);
    }

    #[test]
    fn scan_waker_interrupts_the_sleep_and_latches() {
        let mut src = ScanSource::new();
        let waker = src.waker();
        // A wake issued before wait is latched: the wait returns
        // immediately instead of sleeping the full timeout.
        waker.wake();
        let t0 = Instant::now();
        let mut out = Vec::new();
        src.wait(Duration::from_secs(5), &mut out).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1));
        // And a wake from another thread interrupts a blocked wait.
        let fired = Arc::new(AtomicBool::new(false));
        let fired2 = fired.clone();
        let waker2 = src.waker();
        let join = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            fired2.store(true, Ordering::SeqCst);
            waker2.wake();
        });
        let t0 = Instant::now();
        src.wait(Duration::from_secs(5), &mut out).unwrap();
        assert!(fired.load(Ordering::SeqCst));
        assert!(t0.elapsed() < Duration::from_secs(2));
        join.join().unwrap();
    }

    #[test]
    fn kind_parsing_and_resolution() {
        assert_eq!(ReadinessKind::parse("scan"), Some(ReadinessKind::Scan));
        assert_eq!(ReadinessKind::parse("epoll"), Some(ReadinessKind::Epoll));
        assert_eq!(ReadinessKind::parse("auto"), Some(ReadinessKind::Auto));
        assert_eq!(ReadinessKind::parse("kqueue"), None);
        assert_ne!(ReadinessKind::Auto.resolve(), ReadinessKind::Auto);
        assert!(ReadinessKind::Scan.supported());
        let auto = source(ReadinessKind::Auto).unwrap();
        assert_eq!(auto.backend(), ReadinessKind::Auto.resolve().label());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_only_ready_fds() {
        use std::io::Write;
        use std::net::{TcpListener, TcpStream};
        use std::os::fd::AsRawFd;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client_a = TcpStream::connect(addr).unwrap();
        let (server_a, _) = listener.accept().unwrap();
        let _client_b = TcpStream::connect(addr).unwrap();
        let (server_b, _) = listener.accept().unwrap();

        let mut src = EpollSource::new().unwrap();
        src.register(server_a.as_raw_fd(), 1, Interest::READ).unwrap();
        src.register(server_b.as_raw_fd(), 2, Interest::READ).unwrap();

        // Nothing readable yet: a short wait reports nothing.
        let mut out = Vec::new();
        src.wait(Duration::from_millis(10), &mut out).unwrap();
        assert!(out.is_empty(), "idle fds reported: {out:?}");

        // Only the written-to socket is reported — O(ready), not O(open).
        client_a.write_all(b"x").unwrap();
        src.wait(Duration::from_secs(5), &mut out).unwrap();
        assert_eq!(out, vec![1]);

        // One-shot: without a rearm the same readiness is not re-reported…
        src.wait(Duration::from_millis(10), &mut out).unwrap();
        assert!(out.is_empty(), "one-shot fd re-reported: {out:?}");
        // …and a rearm re-delivers it because the byte is still unread.
        src.rearm(server_a.as_raw_fd(), 1, Interest::READ).unwrap();
        src.wait(Duration::from_secs(5), &mut out).unwrap();
        assert_eq!(out, vec![1]);

        src.deregister(server_a.as_raw_fd(), 1).unwrap();
        src.deregister(server_b.as_raw_fd(), 2).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_waker_interrupts_wait_without_emitting_a_token() {
        let mut src = EpollSource::new().unwrap();
        let waker = src.waker();
        let join = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let t0 = Instant::now();
        let mut out = Vec::new();
        src.wait(Duration::from_secs(5), &mut out).unwrap();
        assert!(out.is_empty(), "waker leaked a token: {out:?}");
        assert!(t0.elapsed() < Duration::from_secs(2));
        join.join().unwrap();
    }
}
