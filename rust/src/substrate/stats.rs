//! Summary statistics for bench reporting (paper tables report
//! mean ± Bessel-corrected std over 10 seeded runs).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Bessel-corrected sample standard deviation (0.0 for n < 2) — the
/// paper's reported deviation.
pub fn std_bessel(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// mean ± std summary with convenience formatting.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_bessel(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// "12.3 ±0.4"-style cell used in the table printers.
    pub fn cell(&self, decimals: usize) -> String {
        format!("{:.*} ±{:.*}", decimals, self.mean, decimals, self.std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // Bessel-corrected: var = 32/7
        assert!((std_bessel(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_bessel(&[1.0]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn summary_cell_format() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.cell(1), "2.0 ±1.0");
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
