//! Small dense-tensor helpers for the hot path: flat buffers + explicit
//! strides, no generic ndarray machinery. The sampler's inner loops index
//! `[B, d, K]` log-prob blocks and `[B, P, T, K]` forecast blocks; these
//! helpers keep that indexing readable and bounds-checked in debug builds.

/// Row-major view over `[B, d, K]` f32 data.
#[derive(Clone, Copy, Debug)]
pub struct View3<'a> {
    pub data: &'a [f32],
    pub d1: usize,
    pub d2: usize,
}

impl<'a> View3<'a> {
    pub fn new(data: &'a [f32], d0: usize, d1: usize, d2: usize) -> View3<'a> {
        debug_assert_eq!(data.len(), d0 * d1 * d2);
        View3 { data, d1, d2 }
    }
    /// Row `[i0, i1, :]`.
    #[inline]
    pub fn row(&self, i0: usize, i1: usize) -> &'a [f32] {
        let off = (i0 * self.d1 + i1) * self.d2;
        &self.data[off..off + self.d2]
    }
}

/// Row-major view over `[B, P, T, K]` f32 data.
#[derive(Clone, Copy, Debug)]
pub struct View4<'a> {
    pub data: &'a [f32],
    pub d1: usize,
    pub d2: usize,
    pub d3: usize,
}

impl<'a> View4<'a> {
    pub fn new(data: &'a [f32], d0: usize, d1: usize, d2: usize, d3: usize) -> View4<'a> {
        debug_assert_eq!(data.len(), d0 * d1 * d2 * d3);
        View4 { data, d1, d2, d3 }
    }
    /// Row `[i0, i1, i2, :]`.
    #[inline]
    pub fn row(&self, i0: usize, i1: usize, i2: usize) -> &'a [f32] {
        let off = ((i0 * self.d1 + i1) * self.d2 + i2) * self.d3;
        &self.data[off..off + self.d3]
    }
}

/// Flat index for `(pixel, channel)` in the raster-scan,
/// channel-innermost layout shared with the python side.
#[inline]
pub fn flat_index(pixel: usize, channel: usize, channels: usize) -> usize {
    pixel * channels + channel
}

/// Inverse of `flat_index`: (pixel, channel).
#[inline]
pub fn pixel_channel(flat: usize, channels: usize) -> (usize, usize) {
    (flat / channels, flat % channels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view3_rows() {
        let data: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let v = View3::new(&data, 2, 3, 4);
        assert_eq!(v.row(0, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(v.row(1, 2), &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn view4_rows() {
        let data: Vec<f32> = (0..48).map(|x| x as f32).collect();
        let v = View4::new(&data, 2, 3, 2, 4);
        assert_eq!(v.row(0, 0, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(v.row(1, 2, 1), &[44.0, 45.0, 46.0, 47.0]);
    }

    #[test]
    fn flat_layout_roundtrip() {
        for p in 0..10 {
            for c in 0..3 {
                let f = flat_index(p, c, 3);
                assert_eq!(pixel_channel(f, 3), (p, c));
            }
        }
        assert_eq!(flat_index(5, 2, 3), 17);
    }
}
