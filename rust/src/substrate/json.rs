//! Minimal JSON codec (serde_json is unavailable offline).
//!
//! Supports the full JSON data model with the ergonomics this project
//! needs: parsing `artifacts/manifest.json`, the TCP serving protocol, and
//! emitting bench/metrics reports. Numbers are f64 (manifest values fit).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Value::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let b = input.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Value::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "models": {"cifar8": {"dim": 300, "bpd": 3.12, "files": {"step_b1": "a.hlo.txt"}, "share_repr": true}}, "quick": false}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("version").as_i64(), Some(1));
        let m = v.get("models").get("cifar8");
        assert_eq!(m.get("dim").as_usize(), Some(300));
        assert_eq!(m.get("share_repr").as_bool(), Some(true));
        assert_eq!(m.get("files").get("step_b1").as_str(), Some("a.hlo.txt"));
        // re-emit + re-parse is stable
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_arrays_and_escapes() {
        let v = parse(r#"[1, -2.5, "a\"b\nc", null, true, [  ], {}]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 7);
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_str(), Some("a\"b\nc"));
        assert_eq!(a[3], Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn emit_escapes_control_chars() {
        let v = Value::str("a\nb\"c");
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap().as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(parse("1.5e3").unwrap().as_f64(), Some(1500.0));
        assert_eq!(parse("-2E-2").unwrap().as_f64(), Some(-0.02));
    }
}
