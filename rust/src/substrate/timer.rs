//! Wall-clock timing helpers for the bench harness and metrics.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human-friendly duration: "1.23s", "45.6ms", "789µs".
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 100.0 {
        format!("{secs:.0}s")
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.1}ms", secs * 1e3)
    } else {
        format!("{:.0}µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
    }

    #[test]
    fn restart_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let e = t.restart();
        assert!(e.as_secs_f64() >= 0.002);
        assert!(t.secs() < e.as_secs_f64());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(123.4), "123s");
        assert_eq!(fmt_duration(1.234), "1.23s");
        assert_eq!(fmt_duration(0.0456), "45.6ms");
        assert_eq!(fmt_duration(0.000789), "789µs");
    }
}
