//! Serving metrics: counters + latency reservoir, snapshot as JSON.
//!
//! Each engine worker owns a `Metrics` behind a mutex it holds only while
//! recording (never across an ARM pass); the dispatcher aggregates all
//! workers with [`Metrics::merge`] for the `metrics` protocol op and
//! attaches per-worker gauges ([`Metrics::worker_value`]): queue depth,
//! occupancy (busy wall-seconds over uptime), loaded engines.
//!
//! The policy layer reports through here too: per-sizing-policy schedule
//! counters (`schedules_by_policy`), mid-flight absorption counters
//! (`absorbed` jobs, `absorb_denials` events), and a queue-age histogram
//! ([`AGE_BUCKET_MS`]) sampled once per request at the moment it enters
//! execution — queued time under the admission policy is exactly what
//! the age buckets make visible.

use crate::substrate::json::Value;
use crate::substrate::stats;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Upper bounds (milliseconds) of the queue-age histogram buckets; the
/// last bucket is the overflow (`>= 500ms`).
pub const AGE_BUCKET_MS: [u64; 5] = [1, 5, 20, 100, 500];

/// Number of histogram buckets: one per bound plus the overflow.
pub const AGE_BUCKETS: usize = AGE_BUCKET_MS.len() + 1;

#[derive(Debug)]
pub struct Metrics {
    pub requests: u64,
    pub samples: u64,
    pub arm_calls: u64,
    pub errors: u64,
    pub batches: u64,
    /// Whole `(model, method)` groups this worker stole from a loaded
    /// peer's queue (work-conservation gauge: nonzero means the fleet
    /// rebalanced instead of idling).
    pub steals: u64,
    /// Wall-seconds spent executing batches (occupancy numerator).
    pub busy_secs: f64,
    /// Jobs absorbed into an executing group's live schedule mid-flight
    /// (admission-policy accepts; the initial window is not counted).
    pub absorbed: u64,
    /// Mid-flight admission denials (events at poll granularity: a
    /// deferred request is re-evaluated — and re-counted — each poll).
    pub absorb_denials: u64,
    /// Queue-age histogram: each request sampled once when it enters
    /// execution, bucketed per [`AGE_BUCKET_MS`] (+ overflow).
    age_buckets: [u64; AGE_BUCKETS],
    /// Executed schedule windows per sizing-policy label ("occupancy",
    /// "latency", "slo", "sync", ...). A long-lived elastic schedule
    /// flushes one window per `record_batch`, so these always track
    /// `batches`.
    by_policy: BTreeMap<String, u64>,
    started: Instant,
    /// Per-batch wall latencies (seconds), bounded reservoir.
    latencies: Vec<f64>,
    /// Per-batch ARM calls per job as a percentage of the baseline's d.
    calls_pct: Vec<f64>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: 0,
            samples: 0,
            arm_calls: 0,
            errors: 0,
            batches: 0,
            steals: 0,
            busy_secs: 0.0,
            absorbed: 0,
            absorb_denials: 0,
            age_buckets: [0; AGE_BUCKETS],
            by_policy: BTreeMap::new(),
            started: Instant::now(),
            latencies: Vec::new(),
            calls_pct: Vec::new(),
        }
    }

    /// Record one executed batch. `calls_pct` is the per-job ARM-call
    /// percentage of baseline (the caller normalizes: chunked sync and
    /// continuous batching have different cost models).
    pub fn record_batch(&mut self, n_jobs: usize, arm_calls: usize, calls_pct: f64, wall_secs: f64) {
        self.batches += 1;
        self.samples += n_jobs as u64;
        self.arm_calls += arm_calls as u64;
        self.busy_secs += wall_secs;
        if self.calls_pct.len() < RESERVOIR {
            self.calls_pct.push(calls_pct);
        }
        if self.latencies.len() < RESERVOIR {
            self.latencies.push(wall_secs);
        }
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }
    pub fn record_error(&mut self) {
        self.errors += 1;
    }
    pub fn record_steal(&mut self) {
        self.steals += 1;
    }

    /// Record `n` jobs absorbed into an executing live schedule.
    pub fn record_absorbed(&mut self, n: usize) {
        self.absorbed += n as u64;
    }

    /// Record one mid-flight admission denial event.
    pub fn record_absorb_denial(&mut self) {
        self.absorb_denials += 1;
    }

    /// Record one executed schedule under sizing policy `name`.
    pub fn record_policy(&mut self, name: &str) {
        *self.by_policy.entry(name.to_string()).or_insert(0) += 1;
    }

    /// Record one request's queue age at the moment it enters execution
    /// (window close or mid-flight absorption).
    pub fn record_admission_age(&mut self, age: Duration) {
        let ms = age.as_millis() as u64;
        let bucket = AGE_BUCKET_MS.iter().position(|&b| ms < b).unwrap_or(AGE_BUCKET_MS.len());
        self.age_buckets[bucket] += 1;
    }

    /// The queue-age histogram (tests and the aggregation gauges).
    pub fn age_buckets(&self) -> &[u64; AGE_BUCKETS] {
        &self.age_buckets
    }

    /// Fraction of this worker's uptime spent executing batches.
    pub fn occupancy(&self) -> f64 {
        let uptime = self.started.elapsed().as_secs_f64();
        if uptime > 0.0 {
            (self.busy_secs / uptime).min(1.0)
        } else {
            0.0
        }
    }

    /// Fold another worker's counters and reservoirs into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.samples += other.samples;
        self.arm_calls += other.arm_calls;
        self.errors += other.errors;
        self.batches += other.batches;
        self.steals += other.steals;
        self.busy_secs += other.busy_secs;
        self.absorbed += other.absorbed;
        self.absorb_denials += other.absorb_denials;
        for (b, o) in self.age_buckets.iter_mut().zip(other.age_buckets.iter()) {
            *b += o;
        }
        for (name, n) in &other.by_policy {
            *self.by_policy.entry(name.clone()).or_insert(0) += n;
        }
        for &l in other.latencies.iter().take(RESERVOIR.saturating_sub(self.latencies.len())) {
            self.latencies.push(l);
        }
        for &p in other.calls_pct.iter().take(RESERVOIR.saturating_sub(self.calls_pct.len())) {
            self.calls_pct.push(p);
        }
    }

    /// The queue-age histogram as a JSON array (counts per bucket).
    fn age_buckets_value(&self) -> Value {
        Value::Arr(self.age_buckets.iter().map(|&c| Value::num(c as f64)).collect())
    }

    pub fn snapshot(&self) -> Value {
        let by_policy: BTreeMap<String, Value> = self.by_policy.iter().map(|(k, &v)| (k.clone(), Value::num(v as f64))).collect();
        Value::obj(vec![
            ("requests", Value::num(self.requests as f64)),
            ("samples", Value::num(self.samples as f64)),
            ("arm_calls", Value::num(self.arm_calls as f64)),
            ("errors", Value::num(self.errors as f64)),
            ("batches", Value::num(self.batches as f64)),
            ("steals", Value::num(self.steals as f64)),
            ("absorbed", Value::num(self.absorbed as f64)),
            ("absorb_denials", Value::num(self.absorb_denials as f64)),
            ("busy_secs", Value::num(self.busy_secs)),
            ("latency_p50_s", Value::num(stats::percentile(&self.latencies, 50.0))),
            ("latency_p95_s", Value::num(stats::percentile(&self.latencies, 95.0))),
            ("calls_pct_mean", Value::num(stats::mean(&self.calls_pct))),
            ("admission_age_bounds_ms", Value::Arr(AGE_BUCKET_MS.iter().map(|&b| Value::num(b as f64)).collect())),
            ("admission_age_buckets", self.age_buckets_value()),
            ("schedules_by_policy", Value::Obj(by_policy)),
        ])
    }

    /// Per-worker gauge object for the aggregated `metrics`/`info`
    /// responses. The [`WorkerGauges`] are sampled by the dispatcher at
    /// snapshot time (queue depth and the placement-plane residency
    /// gauges live on the worker, not in its `Metrics`).
    pub fn worker_value(&self, g: &WorkerGauges) -> Value {
        Value::obj(vec![
            ("id", Value::num(g.id as f64)),
            ("batches", Value::num(self.batches as f64)),
            ("samples", Value::num(self.samples as f64)),
            ("arm_calls", Value::num(self.arm_calls as f64)),
            ("errors", Value::num(self.errors as f64)),
            ("steals", Value::num(self.steals as f64)),
            ("queue_depth", Value::num(g.queue_depth as f64)),
            ("engines_loaded", Value::num(g.engines_loaded as f64)),
            ("engine_loads", Value::num(g.engine_loads as f64)),
            ("evictions", Value::num(g.evictions as f64)),
            ("variant_hits", Value::num(g.variant_hits as f64)),
            ("full_shape_fallbacks", Value::num(g.full_shape_fallbacks as f64)),
            ("variant_positions", Value::num(g.variant_positions as f64)),
            ("resident_models", Value::Arr(g.resident.iter().map(|m| Value::str(m.clone())).collect())),
            ("occupancy", Value::num(self.occupancy())),
            ("absorbed", Value::num(self.absorbed as f64)),
            ("admission_age_buckets", self.age_buckets_value()),
            ("latency_p50_s", Value::num(stats::percentile(&self.latencies, 50.0))),
        ])
    }
}

/// Dispatcher-sampled per-worker gauges that live outside the worker's
/// `Metrics`: queue depth plus the placement plane's residency view —
/// currently-resident engines, cumulative lazy engine loads (reloads
/// after eviction included), and cumulative LRU evictions.
pub struct WorkerGauges {
    pub id: usize,
    pub queue_depth: usize,
    pub engines_loaded: usize,
    pub engine_loads: usize,
    pub evictions: usize,
    /// Shape-variant catalog passes served by a partial variant (all of
    /// this worker's engines, evicted ones included).
    pub variant_hits: u64,
    /// Catalog passes that fell back to the full-shape anchor.
    pub full_shape_fallbacks: u64,
    /// Positions actually evaluated through the catalogs (device cost).
    pub variant_positions: u64,
    pub resident: Vec<String>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(4, 50, 50.0, 0.5);
        m.record_batch(4, 100, 100.0, 1.5);
        m.record_error();
        m.record_steal();
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_i64(), Some(2));
        assert_eq!(s.get("steals").as_i64(), Some(1));
        assert_eq!(s.get("samples").as_i64(), Some(8));
        assert_eq!(s.get("arm_calls").as_i64(), Some(150));
        assert_eq!(s.get("errors").as_i64(), Some(1));
        assert!((s.get("calls_pct_mean").as_f64().unwrap() - 75.0).abs() < 1e-9);
        assert!(s.get("latency_p95_s").as_f64().unwrap() >= 0.5);
        assert!((s.get("busy_secs").as_f64().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_workers() {
        let mut a = Metrics::new();
        a.record_request();
        a.record_batch(2, 10, 40.0, 0.25);
        let mut b = Metrics::new();
        b.record_batch(3, 20, 60.0, 0.75);
        b.record_error();
        b.record_steal();
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.get("steals").as_i64(), Some(1));
        assert_eq!(s.get("requests").as_i64(), Some(1));
        assert_eq!(s.get("samples").as_i64(), Some(5));
        assert_eq!(s.get("arm_calls").as_i64(), Some(30));
        assert_eq!(s.get("errors").as_i64(), Some(1));
        assert_eq!(s.get("batches").as_i64(), Some(2));
        assert!((s.get("calls_pct_mean").as_f64().unwrap() - 50.0).abs() < 1e-9);
        assert!((s.get("busy_secs").as_f64().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn age_buckets_bucket_correctly() {
        let mut m = Metrics::new();
        m.record_admission_age(Duration::from_micros(200)); // < 1ms
        m.record_admission_age(Duration::from_millis(3)); // < 5ms
        m.record_admission_age(Duration::from_millis(5)); // boundary: < 20ms
        m.record_admission_age(Duration::from_millis(99)); // < 100ms
        m.record_admission_age(Duration::from_secs(2)); // overflow
        assert_eq!(m.age_buckets(), &[1, 1, 1, 1, 0, 1]);
        let s = m.snapshot();
        let arr = s.get("admission_age_buckets").as_arr().unwrap();
        assert_eq!(arr.len(), AGE_BUCKETS);
        let total: i64 = arr.iter().map(|v| v.as_i64().unwrap()).sum();
        assert_eq!(total, 5, "every recorded age lands in exactly one bucket");
        assert_eq!(s.get("admission_age_bounds_ms").as_arr().unwrap().len(), AGE_BUCKET_MS.len());
    }

    #[test]
    fn merge_sums_age_buckets_policy_counters_and_absorption() {
        // The cross-worker aggregation invariant the server's `metrics`
        // op relies on: merging N workers must sum bucket-wise and
        // key-wise, so the aggregate equals the per-worker sums even
        // when a worker died mid-run (its Metrics is still merged) or a
        // group was stolen (its counters just land on the thief).
        let workers: Vec<Metrics> = (0..3)
            .map(|i| {
                let mut m = Metrics::new();
                for _ in 0..=i {
                    m.record_admission_age(Duration::from_millis(2));
                    m.record_policy("occupancy");
                }
                m.record_admission_age(Duration::from_millis(800));
                m.record_absorbed(2 * i);
                if i == 2 {
                    m.record_absorb_denial();
                    m.record_policy("slo");
                }
                m
            })
            .collect();
        let mut total = Metrics::new();
        for w in &workers {
            total.merge(w);
        }
        let mut expect = [0u64; AGE_BUCKETS];
        for w in &workers {
            for (e, b) in expect.iter_mut().zip(w.age_buckets()) {
                *e += b;
            }
        }
        assert_eq!(total.age_buckets(), &expect, "aggregate buckets must equal the per-worker sums");
        assert_eq!(total.age_buckets()[1], 6, "1+2+3 sub-5ms ages");
        assert_eq!(total.age_buckets()[AGE_BUCKETS - 1], 3, "one overflow age per worker");
        assert_eq!(total.absorbed, 6, "2*i absorbed jobs per worker");
        assert_eq!(total.absorb_denials, 1);
        let s = total.snapshot();
        let by_policy = s.get("schedules_by_policy");
        assert_eq!(by_policy.get("occupancy").as_i64(), Some(6));
        assert_eq!(by_policy.get("slo").as_i64(), Some(1));
    }

    #[test]
    fn worker_gauges_present_and_bounded() {
        let mut m = Metrics::new();
        m.record_batch(4, 12, 30.0, 0.001);
        let g = WorkerGauges {
            id: 3,
            queue_depth: 7,
            engines_loaded: 2,
            engine_loads: 5,
            evictions: 3,
            variant_hits: 11,
            full_shape_fallbacks: 4,
            variant_positions: 1234,
            resident: vec!["mock_a".into(), "mock_b".into()],
        };
        let w = m.worker_value(&g);
        assert_eq!(w.get("id").as_i64(), Some(3));
        assert_eq!(w.get("queue_depth").as_i64(), Some(7));
        assert_eq!(w.get("engines_loaded").as_i64(), Some(2));
        assert_eq!(w.get("engine_loads").as_i64(), Some(5));
        assert_eq!(w.get("evictions").as_i64(), Some(3));
        assert_eq!(w.get("variant_hits").as_i64(), Some(11));
        assert_eq!(w.get("full_shape_fallbacks").as_i64(), Some(4));
        assert_eq!(w.get("variant_positions").as_i64(), Some(1234));
        let resident = w.get("resident_models").as_arr().unwrap();
        assert_eq!(resident.len(), 2);
        assert_eq!(resident[0].as_str(), Some("mock_a"));
        let occ = w.get("occupancy").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ} outside [0, 1]");
    }
}
