//! Serving metrics: counters + latency reservoir, snapshot as JSON.
//!
//! Owned by the engine thread (no locks on the hot path); the `metrics`
//! protocol op returns a snapshot.

use crate::substrate::json::Value;
use crate::substrate::stats;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub samples: u64,
    pub arm_calls: u64,
    pub errors: u64,
    pub batches: u64,
    /// Per-request wall latencies (seconds), bounded reservoir.
    latencies: Vec<f64>,
    /// Per-batch ARM-call percentages of baseline.
    calls_pct: Vec<f64>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&mut self, n_jobs: usize, arm_calls: usize, dim: usize, wall_secs: f64) {
        self.batches += 1;
        self.samples += n_jobs as u64;
        self.arm_calls += arm_calls as u64;
        if self.calls_pct.len() < RESERVOIR {
            self.calls_pct.push(100.0 * arm_calls as f64 / dim as f64);
        }
        if self.latencies.len() < RESERVOIR {
            self.latencies.push(wall_secs);
        }
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }
    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn snapshot(&self) -> Value {
        Value::obj(vec![
            ("requests", Value::num(self.requests as f64)),
            ("samples", Value::num(self.samples as f64)),
            ("arm_calls", Value::num(self.arm_calls as f64)),
            ("errors", Value::num(self.errors as f64)),
            ("batches", Value::num(self.batches as f64)),
            ("latency_p50_s", Value::num(stats::percentile(&self.latencies, 50.0))),
            ("latency_p95_s", Value::num(stats::percentile(&self.latencies, 95.0))),
            ("calls_pct_mean", Value::num(stats::mean(&self.calls_pct))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(4, 50, 100, 0.5);
        m.record_batch(4, 100, 100, 1.5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_i64(), Some(2));
        assert_eq!(s.get("samples").as_i64(), Some(8));
        assert_eq!(s.get("arm_calls").as_i64(), Some(150));
        assert_eq!(s.get("errors").as_i64(), Some(1));
        assert!((s.get("calls_pct_mean").as_f64().unwrap() - 75.0).abs() < 1e-9);
        assert!(s.get("latency_p95_s").as_f64().unwrap() >= 0.5);
    }
}
