//! Serving metrics: counters + latency reservoir, snapshot as JSON.
//!
//! Each engine worker owns a `Metrics` behind a mutex it holds only while
//! recording (never across an ARM pass); the dispatcher aggregates all
//! workers with [`Metrics::merge`] for the `metrics` protocol op and
//! attaches per-worker gauges ([`Metrics::worker_value`]): queue depth,
//! occupancy (busy wall-seconds over uptime), loaded engines.

use crate::substrate::json::Value;
use crate::substrate::stats;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    pub requests: u64,
    pub samples: u64,
    pub arm_calls: u64,
    pub errors: u64,
    pub batches: u64,
    /// Whole `(model, method)` groups this worker stole from a loaded
    /// peer's queue (work-conservation gauge: nonzero means the fleet
    /// rebalanced instead of idling).
    pub steals: u64,
    /// Wall-seconds spent executing batches (occupancy numerator).
    pub busy_secs: f64,
    started: Instant,
    /// Per-batch wall latencies (seconds), bounded reservoir.
    latencies: Vec<f64>,
    /// Per-batch ARM calls per job as a percentage of the baseline's d.
    calls_pct: Vec<f64>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: 0,
            samples: 0,
            arm_calls: 0,
            errors: 0,
            batches: 0,
            steals: 0,
            busy_secs: 0.0,
            started: Instant::now(),
            latencies: Vec::new(),
            calls_pct: Vec::new(),
        }
    }

    /// Record one executed batch. `calls_pct` is the per-job ARM-call
    /// percentage of baseline (the caller normalizes: chunked sync and
    /// continuous batching have different cost models).
    pub fn record_batch(&mut self, n_jobs: usize, arm_calls: usize, calls_pct: f64, wall_secs: f64) {
        self.batches += 1;
        self.samples += n_jobs as u64;
        self.arm_calls += arm_calls as u64;
        self.busy_secs += wall_secs;
        if self.calls_pct.len() < RESERVOIR {
            self.calls_pct.push(calls_pct);
        }
        if self.latencies.len() < RESERVOIR {
            self.latencies.push(wall_secs);
        }
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }
    pub fn record_error(&mut self) {
        self.errors += 1;
    }
    pub fn record_steal(&mut self) {
        self.steals += 1;
    }

    /// Fraction of this worker's uptime spent executing batches.
    pub fn occupancy(&self) -> f64 {
        let uptime = self.started.elapsed().as_secs_f64();
        if uptime > 0.0 {
            (self.busy_secs / uptime).min(1.0)
        } else {
            0.0
        }
    }

    /// Fold another worker's counters and reservoirs into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.samples += other.samples;
        self.arm_calls += other.arm_calls;
        self.errors += other.errors;
        self.batches += other.batches;
        self.steals += other.steals;
        self.busy_secs += other.busy_secs;
        for &l in other.latencies.iter().take(RESERVOIR.saturating_sub(self.latencies.len())) {
            self.latencies.push(l);
        }
        for &p in other.calls_pct.iter().take(RESERVOIR.saturating_sub(self.calls_pct.len())) {
            self.calls_pct.push(p);
        }
    }

    pub fn snapshot(&self) -> Value {
        Value::obj(vec![
            ("requests", Value::num(self.requests as f64)),
            ("samples", Value::num(self.samples as f64)),
            ("arm_calls", Value::num(self.arm_calls as f64)),
            ("errors", Value::num(self.errors as f64)),
            ("batches", Value::num(self.batches as f64)),
            ("steals", Value::num(self.steals as f64)),
            ("busy_secs", Value::num(self.busy_secs)),
            ("latency_p50_s", Value::num(stats::percentile(&self.latencies, 50.0))),
            ("latency_p95_s", Value::num(stats::percentile(&self.latencies, 95.0))),
            ("calls_pct_mean", Value::num(stats::mean(&self.calls_pct))),
        ])
    }

    /// Per-worker gauge object for the aggregated `metrics`/`info`
    /// responses. `queue_depth` and `engines_loaded` are sampled by the
    /// dispatcher at snapshot time.
    pub fn worker_value(&self, id: usize, queue_depth: usize, engines_loaded: usize) -> Value {
        Value::obj(vec![
            ("id", Value::num(id as f64)),
            ("batches", Value::num(self.batches as f64)),
            ("samples", Value::num(self.samples as f64)),
            ("arm_calls", Value::num(self.arm_calls as f64)),
            ("errors", Value::num(self.errors as f64)),
            ("steals", Value::num(self.steals as f64)),
            ("queue_depth", Value::num(queue_depth as f64)),
            ("engines_loaded", Value::num(engines_loaded as f64)),
            ("occupancy", Value::num(self.occupancy())),
            ("latency_p50_s", Value::num(stats::percentile(&self.latencies, 50.0))),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(4, 50, 50.0, 0.5);
        m.record_batch(4, 100, 100.0, 1.5);
        m.record_error();
        m.record_steal();
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_i64(), Some(2));
        assert_eq!(s.get("steals").as_i64(), Some(1));
        assert_eq!(s.get("samples").as_i64(), Some(8));
        assert_eq!(s.get("arm_calls").as_i64(), Some(150));
        assert_eq!(s.get("errors").as_i64(), Some(1));
        assert!((s.get("calls_pct_mean").as_f64().unwrap() - 75.0).abs() < 1e-9);
        assert!(s.get("latency_p95_s").as_f64().unwrap() >= 0.5);
        assert!((s.get("busy_secs").as_f64().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_workers() {
        let mut a = Metrics::new();
        a.record_request();
        a.record_batch(2, 10, 40.0, 0.25);
        let mut b = Metrics::new();
        b.record_batch(3, 20, 60.0, 0.75);
        b.record_error();
        b.record_steal();
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.get("steals").as_i64(), Some(1));
        assert_eq!(s.get("requests").as_i64(), Some(1));
        assert_eq!(s.get("samples").as_i64(), Some(5));
        assert_eq!(s.get("arm_calls").as_i64(), Some(30));
        assert_eq!(s.get("errors").as_i64(), Some(1));
        assert_eq!(s.get("batches").as_i64(), Some(2));
        assert!((s.get("calls_pct_mean").as_f64().unwrap() - 50.0).abs() < 1e-9);
        assert!((s.get("busy_secs").as_f64().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn worker_gauges_present_and_bounded() {
        let mut m = Metrics::new();
        m.record_batch(4, 12, 30.0, 0.001);
        let w = m.worker_value(3, 7, 2);
        assert_eq!(w.get("id").as_i64(), Some(3));
        assert_eq!(w.get("queue_depth").as_i64(), Some(7));
        assert_eq!(w.get("engines_loaded").as_i64(), Some(2));
        let occ = w.get("occupancy").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ} outside [0, 1]");
    }
}
