//! Dynamic batching queue: flush on size or deadline.
//!
//! Pure data structure (callers supply the clock), so the policy is unit-
//! testable without threads. The server pushes incoming jobs grouped by
//! (model, method) and drains a batch when either `max_batch` jobs are
//! waiting or the oldest job has waited `max_wait`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Batcher<T> {
    max_batch: usize,
    max_wait: Duration,
    queue: VecDeque<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher<T> {
        assert!(max_batch >= 1);
        Batcher { max_batch, max_wait, queue: VecDeque::new() }
    }

    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back((item, now));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be flushed at `now`.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        match self.queue.front() {
            Some((_, t)) => now.duration_since(*t) >= self.max_wait,
            None => false,
        }
    }

    /// Time until the deadline would force a flush (None if empty).
    pub fn deadline_in(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|(_, t)| {
            let waited = now.duration_since(*t);
            self.max_wait.saturating_sub(waited)
        })
    }

    /// Drain up to `max_batch` items (oldest first) if ready; `force`
    /// drains regardless (used at shutdown).
    pub fn pop_batch(&mut self, now: Instant, force: bool) -> Option<Vec<T>> {
        if self.queue.is_empty() || (!force && !self.ready(now)) {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.queue.drain(..n).map(|(x, _)| x).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_on_size() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        assert!(!b.ready(now));
        b.push(3, now);
        assert!(b.ready(now));
        assert_eq!(b.pop_batch(now, false), Some(vec![1, 2, 3]));
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(100, Duration::from_millis(5));
        let now = t0();
        b.push("a", now);
        assert!(!b.ready(now));
        let later = now + Duration::from_millis(6);
        assert!(b.ready(later));
        assert_eq!(b.pop_batch(later, false), Some(vec!["a"]));
    }

    #[test]
    fn preserves_fifo_and_caps_batch() {
        let mut b = Batcher::new(2, Duration::from_secs(0));
        let now = t0();
        for i in 0..5 {
            b.push(i, now);
        }
        assert_eq!(b.pop_batch(now, false), Some(vec![0, 1]));
        assert_eq!(b.pop_batch(now, false), Some(vec![2, 3]));
        assert_eq!(b.pop_batch(now, false), Some(vec![4]));
        assert_eq!(b.pop_batch(now, false), None);
    }

    #[test]
    fn force_drains_early() {
        let mut b = Batcher::new(10, Duration::from_secs(10));
        let now = t0();
        b.push(7, now);
        assert_eq!(b.pop_batch(now, false), None);
        assert_eq!(b.pop_batch(now, true), Some(vec![7]));
    }

    #[test]
    fn deadline_countdown() {
        let mut b = Batcher::new(10, Duration::from_millis(20));
        let now = t0();
        assert_eq!(b.deadline_in(now), None);
        b.push(1, now);
        let d = b.deadline_in(now + Duration::from_millis(5)).unwrap();
        assert!(d <= Duration::from_millis(15));
    }
}
