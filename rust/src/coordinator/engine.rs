//! Engine: one model's step backends + the sampling methods.
//!
//! An `Engine` owns a step backend for each exported batch size (and the
//! paired decoder for latent models), and exposes the paper's methods
//! uniformly. A backend is either a compiled PJRT executable or — for
//! manifest entries carrying a `"mock"` spec — the pure-rust [`MockArm`],
//! which lets the whole serving stack run without artifacts. PJRT handles
//! are thread-affine, so an `Engine` never leaves the thread that created
//! it; the server replicates engines per worker for the same reason.

use crate::coordinator::config::Method;
use crate::coordinator::scheduler::{self, JobFeed, LiveJob, ScheduleReport};
use crate::runtime::artifact::{Manifest, ModelInfo, ModelKind};
use crate::runtime::autoenc::DecoderExe;
use crate::runtime::step::{bpd_of, CatalogStats, StepExecutable, StepOutput, VariantCatalog};
use crate::sampler::ancestral::ancestral_batch;
use crate::sampler::forecast::{self, Forecaster};
use crate::sampler::mock::MockArm;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::PredictiveSampler;
use crate::sampler::{BatchResult, PassPlan, StepModel};
use crate::substrate::json::Value;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One fixed-batch-size inference backend: a compiled PJRT step
/// executable, the deterministic pure-rust mock ARM, or a fixed
/// `(batch, fore)` view of a shared [`VariantCatalog`], which serves each
/// planned pass on the cheapest exported `{batch, span, flavor}` shape
/// (real partial inference for compiled models).
pub enum StepBackend {
    Compiled(StepExecutable),
    Mock { arm: MockArm, calls: AtomicU64 },
    Catalog { cat: Arc<VariantCatalog>, batch: usize, has_fore: bool },
}

impl StepBackend {
    /// Step invocations since load (telemetry). Catalog views report the
    /// shared catalog's total passes — the quantity a capacity dashboard
    /// wants, since the catalog is one device resource.
    pub fn calls(&self) -> u64 {
        match self {
            StepBackend::Compiled(exe) => exe.calls(),
            StepBackend::Mock { calls, .. } => calls.load(Ordering::Relaxed),
            StepBackend::Catalog { cat, .. } => {
                let st = cat.stats();
                st.variant_hits + st.full_shape_fallbacks
            }
        }
    }
}

impl StepModel for StepBackend {
    fn batch(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.batch,
            StepBackend::Mock { arm, .. } => arm.batch(),
            StepBackend::Catalog { batch, .. } => *batch,
        }
    }
    fn dim(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.dim,
            StepBackend::Mock { arm, .. } => arm.dim(),
            StepBackend::Catalog { cat, .. } => cat.dim,
        }
    }
    fn categories(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.categories,
            StepBackend::Mock { arm, .. } => arm.categories(),
            StepBackend::Catalog { cat, .. } => cat.categories,
        }
    }
    fn pixels(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.pixels,
            StepBackend::Mock { arm, .. } => arm.pixels(),
            StepBackend::Catalog { cat, .. } => cat.pixels,
        }
    }
    fn t_fore(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.t_fore,
            StepBackend::Mock { arm, .. } => arm.t_fore(),
            // A logp-only view never surfaces heads, mirroring the
            // compiled logp-only flavor's `t_fore = 0`.
            StepBackend::Catalog { cat, has_fore, .. } => {
                if *has_fore {
                    cat.t_fore
                } else {
                    0
                }
            }
        }
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        match self {
            StepBackend::Compiled(exe) => exe.run_into(x, out),
            StepBackend::Mock { arm, calls } => {
                arm.run_into(x, out)?;
                calls.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            StepBackend::Catalog { cat, batch, has_fore } => cat.run_full(*batch, *has_fore, x, out).map(|_| ()),
        }
    }
    fn run_plan(&self, x: &[i32], out: &mut StepOutput, plan: &PassPlan) -> Result<usize> {
        match self {
            // Shape-specialized: a lone compiled executable runs full
            // passes (the plan's skip permissions go unused, which is
            // allowed) and reports the full-shape device cost.
            StepBackend::Compiled(exe) => {
                exe.run_into(x, out)?;
                Ok(exe.batch * (exe.dim + exe.pixels * exe.t_fore))
            }
            StepBackend::Mock { arm, calls } => {
                let n = arm.run_plan(x, out, plan)?;
                calls.fetch_add(1, Ordering::Relaxed);
                Ok(n)
            }
            StepBackend::Catalog { cat, batch, has_fore } => cat.run_plan(*batch, *has_fore, x, out, plan),
        }
    }
    fn exploits_plan(&self) -> bool {
        !matches!(self, StepBackend::Compiled(_))
    }
}

/// JSON encoding of catalog telemetry (the `catalog` object of worker and
/// fleet metrics; key names are machine-checked against PROTOCOL.md by
/// the doc-parity lint).
pub fn catalog_value(stats: &CatalogStats) -> Value {
    let shapes: BTreeMap<String, Value> =
        stats.shapes.iter().map(|(label, hits)| (label.clone(), Value::num(*hits as f64))).collect();
    Value::obj(vec![
        ("variant_hits", Value::num(stats.variant_hits as f64)),
        ("full_shape_fallbacks", Value::num(stats.full_shape_fallbacks as f64)),
        ("positions_evaluated", Value::num(stats.positions_evaluated as f64)),
        ("shapes", Value::Obj(shapes)),
    ])
}

pub struct Engine {
    pub manifest: Manifest,
    pub info: ModelInfo,
    /// Keyed by (batch size, with-forecast-heads).
    exes: BTreeMap<(usize, bool), StepBackend>,
    /// The shared shape-variant catalog behind the `exes` views, when the
    /// model exports one (compiled models with variants on; mock models
    /// declaring `spans`).
    catalog: Option<Arc<VariantCatalog>>,
    decoder: Option<DecoderExe>,
}

impl Engine {
    /// Load the engine for `model` with variant catalogs enabled — see
    /// [`Engine::load_with`].
    pub fn load(manifest: &Manifest, model: &str) -> Result<Engine> {
        Self::load_with(manifest, model, true)
    }

    /// Load the engine for `model`. With `variants` on (the default), every
    /// exported `{batch, span, flavor}` step shape is collected into one
    /// shared [`VariantCatalog`] and each batch size is served through a
    /// catalog view, so planned passes run on the cheapest covering shape.
    /// With `variants` off — or when a model exports no span variants to
    /// speak of — batches load as standalone backends exactly as before
    /// (`--no-variants` is the kill switch if a span export misbehaves).
    pub fn load_with(manifest: &Manifest, model: &str, variants: bool) -> Result<Engine> {
        let info = manifest.model(model)?.clone();
        let mut exes = BTreeMap::new();
        let mut catalog = None;
        if let Some(mock) = &info.mock {
            let arm_at = |b: usize| MockArm::new(b, info.channels, info.pixels, info.categories, info.t_fore, mock.strength, mock.seed);
            let mut spans: Vec<usize> = mock.spans.iter().copied().filter(|&s| s < info.dim).collect();
            spans.sort_unstable();
            spans.dedup();
            if variants && !spans.is_empty() {
                let mut cat = VariantCatalog::new(&info.name, info.dim, info.categories, info.pixels, info.t_fore);
                for &b in &info.step_batch_sizes() {
                    // Full-shape anchor, logp-only flavor, and the span
                    // ladder in both flavors — the same grid the compiled
                    // exporter emits.
                    cat.push_backend(b, info.dim, true, Box::new(arm_at(b)))?;
                    cat.push_backend(b, info.dim, false, Box::new(arm_at(b)))?;
                    for &s in &spans {
                        cat.push_backend(b, s, true, Box::new(arm_at(b)))?;
                        cat.push_backend(b, s, false, Box::new(arm_at(b)))?;
                    }
                }
                catalog = Some(Arc::new(cat));
            } else {
                for &b in &info.step_batch_sizes() {
                    exes.insert((b, true), StepBackend::Mock { arm: arm_at(b), calls: AtomicU64::new(0) });
                }
            }
        } else if variants {
            let mut cat = VariantCatalog::new(&info.name, info.dim, info.categories, info.pixels, info.t_fore);
            for (role, b, s, fore) in info.step_variant_roles() {
                let file = info.file(&role)?;
                cat.push_compiled(StepExecutable::load_span_variant(manifest.path(file), &info, b, fore, s)?)?;
            }
            catalog = Some(Arc::new(cat));
        } else {
            for b in info.step_batch_sizes() {
                let file = info.file(&format!("step_b{b}"))?;
                exes.insert((b, true), StepBackend::Compiled(StepExecutable::load(manifest.path(file), &info, b)?));
                if let Ok(lp) = info.file(&format!("steplp_b{b}")) {
                    exes.insert((b, false), StepBackend::Compiled(StepExecutable::load_variant(manifest.path(lp), &info, b, false)?));
                }
            }
        }
        if let Some(cat) = &catalog {
            cat.validate()?;
            // One view pair per anchored batch size; both flavors route to
            // the same shared catalog, which picks the real device shape.
            for b in cat.anchored_batches() {
                exes.insert((b, true), StepBackend::Catalog { cat: cat.clone(), batch: b, has_fore: true });
                exes.insert((b, false), StepBackend::Catalog { cat: cat.clone(), batch: b, has_fore: false });
            }
        }
        if exes.is_empty() {
            bail!("model {model} exports no step executables");
        }
        let decoder = if info.kind == ModelKind::Latent {
            let ae_name = info.autoencoder.as_deref().ok_or_else(|| anyhow!("latent model without AE"))?;
            let ae = manifest.ae(ae_name)?;
            let path = manifest.path(&format!("ae_{ae_name}_dec_b32.hlo.txt"));
            Some(DecoderExe::load(path, ae, 32)?)
        } else {
            None
        };
        Ok(Engine { manifest: manifest.clone(), info, exes, catalog, decoder })
    }

    /// Telemetry snapshot of the shared variant catalog, if this engine
    /// serves one.
    pub fn catalog_stats(&self) -> Option<CatalogStats> {
        self.catalog.as_ref().map(|c| c.stats())
    }

    /// The full (logp + fore) step backend for an exact batch size.
    pub fn exe(&self, batch: usize) -> Result<&StepBackend> {
        self.exe_for(batch, true)
    }

    /// Pick the cheapest backend that satisfies `need_fore` (the
    /// logp-only variant when the method never reads forecast heads).
    pub fn exe_for(&self, batch: usize, need_fore: bool) -> Result<&StepBackend> {
        if !need_fore {
            if let Some(e) = self.exes.get(&(batch, false)) {
                return Ok(e);
            }
        }
        self.exes
            .get(&(batch, true))
            .ok_or_else(|| anyhow!("model {} has no b{batch} executable (have {:?})", self.info.name, self.exes.keys().collect::<Vec<_>>()))
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.exes.keys().filter(|(_, fore)| *fore).map(|(b, _)| *b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Every exported backend satisfying `need_fore`, ascending by batch
    /// size — the model family the down-shifting scheduler runs over.
    pub fn backends_for(&self, need_fore: bool) -> Vec<&StepBackend> {
        self.batch_sizes().into_iter().filter_map(|b| self.exe_for(b, need_fore).ok()).collect()
    }

    /// Continuous batching over an explicit job queue, using *every*
    /// exported batch size: the schedule starts on the smallest batch
    /// that fits the queue and down-shifts as it drains, so a tail of
    /// stragglers stops paying full-batch passes. Samples are bitwise
    /// independent of the shifting (noise is keyed by job id).
    pub fn sample_continuous(&self, method: Method, noises: Vec<JobNoise>) -> Result<ScheduleReport> {
        ensure!(method != Method::Baseline, "baseline serves through the sync path");
        let backends = self.backends_for(Self::needs_fore(method));
        scheduler::run_continuous_family(&backends, self.forecaster_for(method)?, noises)
    }

    /// As [`Engine::sample_continuous`], over a **live** queue: `feed` is
    /// polled between passes, so jobs can keep arriving while the
    /// schedule runs and the batch up-shifts to absorb them (the serving
    /// layer's elastic path). Results are delivered through
    /// [`JobFeed::complete`] the moment each job converges. Sizes with
    /// the occupancy-first default; see [`Engine::sample_elastic_policy`].
    pub fn sample_elastic(&self, method: Method, initial: Vec<LiveJob>, feed: &mut dyn JobFeed) -> Result<ScheduleReport> {
        self.sample_elastic_policy(method, initial, feed, &crate::coordinator::policy::OccupancyFirst)
    }

    /// As [`Engine::sample_elastic`], with an explicit batch-sizing
    /// policy (occupancy-first, latency-lean, or the SLO hybrid — see
    /// [`crate::coordinator::policy`]). The server builds the policy from
    /// `ServeConfig::policy`/`--policy`; sizing never changes samples.
    pub fn sample_elastic_policy(
        &self,
        method: Method,
        initial: Vec<LiveJob>,
        feed: &mut dyn JobFeed,
        sizing: &dyn crate::coordinator::policy::SizingPolicy,
    ) -> Result<ScheduleReport> {
        self.sample_elastic_primed(method, initial, feed, sizing, None)
    }

    /// As [`Engine::sample_elastic_policy`], seeding the schedule's
    /// convergence EWMAs from the server's cross-schedule history for
    /// this workload ([`crate::coordinator::policy::ConvergenceBook`]),
    /// so SLO sizing's cold-start projections use observed behavior
    /// instead of the worst-case `d` prior. Priming never changes
    /// samples.
    pub fn sample_elastic_primed(
        &self,
        method: Method,
        initial: Vec<LiveJob>,
        feed: &mut dyn JobFeed,
        sizing: &dyn crate::coordinator::policy::SizingPolicy,
        prior: Option<crate::coordinator::policy::ConvergencePrior>,
    ) -> Result<ScheduleReport> {
        ensure!(method != Method::Baseline, "baseline serves through the sync path");
        let backends = self.backends_for(Self::needs_fore(method));
        scheduler::run_elastic_family_primed(&backends, self.forecaster_for(method)?, initial, feed, sizing, prior)
    }

    /// Whether `method` reads the forecast-head outputs.
    pub fn needs_fore(method: Method) -> bool {
        matches!(method, Method::Forecast { .. })
    }

    fn forecaster_for(&self, method: Method) -> Result<Box<dyn Forecaster>> {
        Ok(match method {
            Method::Baseline => bail!("baseline has no forecaster"),
            Method::Zeros => Box::new(forecast::Zeros),
            Method::PredictLast => Box::new(forecast::PredictLast),
            Method::Fpi => Box::new(forecast::FpiReuse),
            Method::Forecast { t_use } => Box::new(forecast::Learned { t_use }),
            Method::NoReparam => Box::new(forecast::NoReparam),
        })
    }

    /// Sample a full batch at `batch_size` with the given method and seed
    /// (synchronous batched semantics: the paper's Tables 1/2 protocol).
    pub fn sample_batch(&self, method: Method, batch_size: usize, seed: u64) -> Result<BatchResult> {
        self.sample_batch_offset(method, batch_size, seed, 0)
    }

    /// As [`Engine::sample_batch`], with slot `s` drawing job noise keyed
    /// `(seed, job_offset + s)`. The serving sync path uses this to chunk a
    /// request larger than the batch executable into *distinct* jobs —
    /// reusing offset 0 for every chunk would repeat the first chunk's
    /// samples verbatim.
    pub fn sample_batch_offset(&self, method: Method, batch_size: usize, seed: u64, job_offset: u64) -> Result<BatchResult> {
        let exe = self.exe_for(batch_size, Self::needs_fore(method))?;
        if method == Method::Baseline {
            let noises: Vec<JobNoise> = (0..batch_size)
                .map(|s| JobNoise::new(seed, job_offset + s as u64, self.info.dim, self.info.categories))
                .collect();
            return ancestral_batch(exe, &noises);
        }
        let mut ps = PredictiveSampler::new(exe, self.forecaster_for(method)?);
        ps.run_sync_offset(seed, job_offset)
    }

    /// Test-set bits/dim through the compiled artifact (paper's bpd).
    pub fn eval_bpd(&self) -> Result<f64> {
        let test = self.manifest.load_test_batch(&self.info.name)?;
        let b = *self.batch_sizes().last().unwrap();
        let exe = self.exe(b)?;
        let n = b.min(test.len());
        let mut x = vec![0i32; b * self.info.dim];
        for (i, row) in test.iter().take(n).enumerate() {
            x[i * self.info.dim..(i + 1) * self.info.dim].copy_from_slice(row);
        }
        let mut out = StepOutput::default();
        exe.run_into(&x, &mut out)?;
        let bpds = bpd_of(&x, &out, n, self.info.dim, self.info.categories);
        Ok(bpds.iter().sum::<f64>() / n as f64)
    }

    /// Decode flat latents to images (latent models only). Input shorter
    /// than the decoder batch is padded and truncated transparently.
    pub fn decode(&self, z: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let dec = self.decoder.as_ref().ok_or_else(|| anyhow!("model {} is not latent", self.info.name))?;
        let s = dec.img_size;
        let mut out = Vec::with_capacity(z.len());
        for chunk in z.chunks(dec.batch) {
            let mut flat = vec![0i32; dec.batch * dec.latent_dim];
            for (i, row) in chunk.iter().enumerate() {
                flat[i * dec.latent_dim..(i + 1) * dec.latent_dim].copy_from_slice(row);
            }
            let imgs = dec.decode(&flat)?;
            for i in 0..chunk.len() {
                out.push(imgs[i * 3 * s * s..(i + 1) * 3 * s * s].to_vec());
            }
        }
        Ok(out)
    }

    pub fn img_size(&self) -> Option<usize> {
        self.decoder.as_ref().map(|d| d.img_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::runtime::artifact::{write_mock_manifest, MockModelSpec};

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Manifest::load(&dir).ok()
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    fn mock_engine_with(tag: &str, spans: &[usize], variants: bool) -> Engine {
        let dir = std::env::temp_dir().join(format!("predsamp-engine-{tag}-{}", std::process::id()));
        let mut spec = MockModelSpec::new("mock_m", 21);
        spec.spans = spans.to_vec();
        write_mock_manifest(&dir, &[spec]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let eng = Engine::load_with(&man, "mock_m", variants).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        eng
    }

    fn mock_engine(tag: &str) -> Engine {
        mock_engine_with(tag, &[], true)
    }

    #[test]
    fn mock_engine_samples_exactly_without_artifacts() {
        // The full Engine API over the mock backend: exactness holds and
        // FPI saves calls, with no compiled artifacts or PJRT anywhere.
        let eng = mock_engine("exact");
        assert_eq!(eng.batch_sizes(), vec![1, 4]);
        let base = eng.sample_batch(Method::Baseline, 4, 5).unwrap();
        let fpi = eng.sample_batch(Method::Fpi, 4, 5).unwrap();
        for s in 0..4 {
            assert_eq!(fpi.jobs[s].x, base.jobs[s].x, "slot {s}: FPI must equal ancestral");
        }
        assert_eq!(base.arm_calls, eng.info.dim);
        assert!(fpi.arm_calls <= eng.info.dim);
        let exe = eng.exe_for(4, false).unwrap();
        assert!(exe.calls() > 0, "mock backend must count passes");
    }

    #[test]
    fn mock_engine_offset_keys_distinct_jobs() {
        // Chunked serving correctness: offset batches must be (a) distinct
        // from the offset-0 batch and (b) identical to the same job ids
        // sampled at their natural slots.
        let eng = mock_engine("offset");
        let chunk0 = eng.sample_batch_offset(Method::Fpi, 4, 7, 0).unwrap();
        let chunk1 = eng.sample_batch_offset(Method::Fpi, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_ne!(chunk0.jobs[s].x, chunk1.jobs[s].x, "slot {s} repeated across chunks");
        }
        // Job id 4 sampled via offset chunk == job id 4 from a wider batch
        // at slot 4 would need b8; instead compare against offset 4 twice.
        let again = eng.sample_batch_offset(Method::Fpi, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_eq!(chunk1.jobs[s].x, again.jobs[s].x, "offset sampling must be deterministic");
        }
        // Baseline with the same offsets matches bitwise (exactness).
        let base1 = eng.sample_batch_offset(Method::Baseline, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_eq!(chunk1.jobs[s].x, base1.jobs[s].x, "slot {s}: offset chunk must stay exact");
        }
    }

    #[test]
    fn mock_engine_continuous_downshifts_and_stays_exact() {
        // The serving continuous path: scheduling over the [1, 4] backend
        // family must agree bitwise with the fixed-batch sync path, and a
        // single-job queue must run entirely on the b=1 backend.
        let eng = mock_engine("family");
        let d = eng.info.dim;
        let k = eng.info.categories;
        let sync = eng.sample_batch(Method::Fpi, 4, 9).unwrap();
        let noises: Vec<JobNoise> = (0..4).map(|id| JobNoise::new(9, id, d, k)).collect();
        let rep = eng.sample_continuous(Method::Fpi, noises).unwrap();
        for s in 0..4 {
            assert_eq!(rep.results[s].x, sync.jobs[s].x, "job {s}: continuous family diverged from sync");
        }
        let one = eng.sample_continuous(Method::Fpi, vec![JobNoise::new(9, 0, d, k)]).unwrap();
        assert_eq!(one.min_batch, 1, "single job must use the b=1 backend");
        assert_eq!(one.results[0].x, sync.jobs[0].x);
        assert!(eng.sample_continuous(Method::Baseline, vec![]).is_err());
    }

    #[test]
    fn mock_engine_elastic_feed_matches_continuous() {
        // The serving elastic path: jobs delivered mid-schedule through a
        // feed must sample bitwise identically to the same queue handed
        // over all at once (and results must flow out via the feed).
        use crate::coordinator::scheduler::TickBurstFeed;
        let eng = mock_engine("elastic");
        let (d, k) = (eng.info.dim, eng.info.categories);
        let noises: Vec<JobNoise> = (0..6).map(|id| JobNoise::new(11, id, d, k)).collect();
        let fixed = eng.sample_continuous(Method::Fpi, noises).unwrap();
        let initial = vec![LiveJob { tag: 0, noise: JobNoise::new(11, 0, d, k) }];
        // The burst lands at tick 1, i.e. after the schedule has already
        // run a pass on the b=1 backend.
        let burst: Vec<LiveJob> = (1..6).map(|id| LiveJob { tag: id, noise: JobNoise::new(11, id, d, k) }).collect();
        let mut feed = TickBurstFeed::new(6, vec![(1, burst)]);
        let rep = eng.sample_elastic(Method::Fpi, initial, &mut feed).unwrap();
        for (id, job) in fixed.results.iter().enumerate() {
            assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "job {id}: elastic feed changed the sample");
        }
        assert!(rep.upshifts >= 1, "a 1-job start growing to 6 must up-shift onto the b=4 backend");
    }

    #[test]
    fn catalog_engine_bitwise_matches_legacy_across_methods() {
        // The exactness gate for the variant catalog at the Engine level:
        // the same manifest served with variants on vs off must produce
        // bitwise-identical samples (and pass counts) for every method.
        let legacy = mock_engine_with("cat-leg", &[6, 12], false);
        let cat = mock_engine_with("cat-on", &[6, 12], true);
        assert!(legacy.catalog_stats().is_none(), "variants off must skip the catalog");
        let st0 = cat.catalog_stats().expect("variants on over exported spans builds a catalog");
        assert_eq!(st0.shapes.len(), 2 * 2 * 3, "2 batches x 2 flavors x (full + 2 spans)");
        for method in [
            Method::Baseline,
            Method::Zeros,
            Method::PredictLast,
            Method::Fpi,
            Method::Forecast { t_use: 1 },
            Method::NoReparam,
        ] {
            let a = legacy.sample_batch(method, 4, 13).unwrap();
            let b = cat.sample_batch(method, 4, 13).unwrap();
            for s in 0..4 {
                assert_eq!(b.jobs[s].x, a.jobs[s].x, "{method:?} slot {s}: catalog diverged from legacy");
            }
            assert_eq!(b.arm_calls, a.arm_calls, "{method:?}: shape selection must not change pass counts");
        }
        let st = cat.catalog_stats().unwrap();
        assert!(st.variant_hits > 0, "frontier-aware plans must hit sub-full shapes");
        assert!(st.positions_evaluated > 0);
        assert!(st.shapes.iter().any(|(_, h)| *h > 0));
    }

    #[test]
    fn catalog_engine_continuous_path_stays_exact() {
        // The serving continuous path through catalog views: bitwise equal
        // to the legacy backend family on the same queue.
        let legacy = mock_engine_with("cont-leg", &[6, 12], false);
        let cat = mock_engine_with("cont-on", &[6, 12], true);
        let (d, k) = (cat.info.dim, cat.info.categories);
        let mk = |seed: u64| (0..6).map(|id| JobNoise::new(seed, id, d, k)).collect::<Vec<_>>();
        let a = legacy.sample_continuous(Method::Fpi, mk(19)).unwrap();
        let b = cat.sample_continuous(Method::Fpi, mk(19)).unwrap();
        for (id, job) in a.results.iter().enumerate() {
            assert_eq!(b.results[id].x, job.x, "job {id}: catalog continuous path diverged");
        }
        assert_eq!(b.total_passes, a.total_passes, "shape selection must not change the schedule");
        // Legacy mock backends are plan-exact; the catalog pays shape
        // quantization on top but must stay far below the full-shape cost.
        let full_pass = 4 * (d + cat.info.pixels * cat.info.t_fore);
        assert!(b.positions_evaluated >= a.positions_evaluated);
        assert!(
            b.positions_evaluated < b.total_passes * full_pass,
            "catalog ({} rows) should beat full-shape passes ({} rows)",
            b.positions_evaluated,
            b.total_passes * full_pass
        );
    }

    #[test]
    fn engine_loads_and_samples_exactly() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let d = eng.info.dim;
        // Exactness through the real artifact: FPI == baseline, same seed.
        let base = eng.sample_batch(Method::Baseline, 1, 5).unwrap();
        let fpi = eng.sample_batch(Method::Fpi, 1, 5).unwrap();
        assert_eq!(fpi.jobs[0].x, base.jobs[0].x, "FPI must equal ancestral");
        assert_eq!(base.arm_calls, d);
        assert!(fpi.arm_calls < d, "FPI should save calls: {}", fpi.arm_calls);
        // Learned forecasting is exact too.
        let fc = eng.sample_batch(Method::Forecast { t_use: 5 }, 1, 5).unwrap();
        assert_eq!(fc.jobs[0].x, base.jobs[0].x, "forecast must equal ancestral");
    }

    #[test]
    fn engine_bpd_close_to_build() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let bpd = eng.eval_bpd().unwrap();
        let expect = eng.info.bpd;
        assert!((bpd - expect).abs() < 0.15, "bpd {bpd} vs {expect}");
    }

    #[test]
    fn latent_engine_decodes() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "latent_cifar").unwrap();
        let res = eng.sample_batch(Method::Fpi, 1, 0).unwrap();
        let imgs = eng.decode(&[res.jobs[0].x.clone()]).unwrap();
        let s = eng.img_size().unwrap();
        assert_eq!(imgs[0].len(), 3 * s * s);
        assert!(imgs[0].iter().all(|v| v.is_finite()));
    }
}
