//! Engine: one model's step backends + the sampling methods.
//!
//! An `Engine` owns a step backend for each exported batch size (and the
//! paired decoder for latent models), and exposes the paper's methods
//! uniformly. A backend is either a compiled PJRT executable or — for
//! manifest entries carrying a `"mock"` spec — the pure-rust [`MockArm`],
//! which lets the whole serving stack run without artifacts. PJRT handles
//! are thread-affine, so an `Engine` never leaves the thread that created
//! it; the server replicates engines per worker for the same reason.

use crate::coordinator::config::Method;
use crate::coordinator::scheduler::{self, JobFeed, LiveJob, ScheduleReport};
use crate::runtime::artifact::{Manifest, ModelInfo, ModelKind};
use crate::runtime::autoenc::DecoderExe;
use crate::runtime::step::{bpd_of, StepExecutable, StepOutput};
use crate::sampler::ancestral::ancestral_batch;
use crate::sampler::forecast::{self, Forecaster};
use crate::sampler::mock::MockArm;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::PredictiveSampler;
use crate::sampler::{BatchResult, PassPlan, StepModel};
use anyhow::{anyhow, bail, ensure, Result};
use std::cell::Cell;
use std::collections::BTreeMap;

/// One fixed-batch-size inference backend: a compiled PJRT step
/// executable, or the deterministic pure-rust mock ARM.
pub enum StepBackend {
    Compiled(StepExecutable),
    Mock { arm: MockArm, calls: Cell<u64> },
}

impl StepBackend {
    /// Step invocations since load (telemetry).
    pub fn calls(&self) -> u64 {
        match self {
            StepBackend::Compiled(exe) => exe.calls(),
            StepBackend::Mock { calls, .. } => calls.get(),
        }
    }
}

impl StepModel for StepBackend {
    fn batch(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.batch,
            StepBackend::Mock { arm, .. } => arm.batch(),
        }
    }
    fn dim(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.dim,
            StepBackend::Mock { arm, .. } => arm.dim(),
        }
    }
    fn categories(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.categories,
            StepBackend::Mock { arm, .. } => arm.categories(),
        }
    }
    fn pixels(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.pixels,
            StepBackend::Mock { arm, .. } => arm.pixels(),
        }
    }
    fn t_fore(&self) -> usize {
        match self {
            StepBackend::Compiled(exe) => exe.t_fore,
            StepBackend::Mock { arm, .. } => arm.t_fore(),
        }
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        match self {
            StepBackend::Compiled(exe) => exe.run_into(x, out),
            StepBackend::Mock { arm, calls } => {
                arm.run_into(x, out)?;
                calls.set(calls.get() + 1);
                Ok(())
            }
        }
    }
    fn run_plan(&self, x: &[i32], out: &mut StepOutput, plan: &PassPlan) -> Result<()> {
        match self {
            // Shape-specialized: the compiled executable runs full passes
            // (the plan's skip permissions go unused, which is allowed).
            StepBackend::Compiled(exe) => exe.run_into(x, out),
            StepBackend::Mock { arm, calls } => {
                arm.run_plan(x, out, plan)?;
                calls.set(calls.get() + 1);
                Ok(())
            }
        }
    }
    fn exploits_plan(&self) -> bool {
        matches!(self, StepBackend::Mock { .. })
    }
}

pub struct Engine {
    pub manifest: Manifest,
    pub info: ModelInfo,
    /// Keyed by (batch size, with-forecast-heads).
    exes: BTreeMap<(usize, bool), StepBackend>,
    decoder: Option<DecoderExe>,
}

impl Engine {
    /// Load the engine for `model`: the mock backend when the manifest
    /// declares one, otherwise compiling the step executables (full and,
    /// when exported, logp-only) for every batch size.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Engine> {
        let info = manifest.model(model)?.clone();
        let mut exes = BTreeMap::new();
        if let Some(mock) = &info.mock {
            for &b in &info.step_batch_sizes() {
                let arm = MockArm::new(b, info.channels, info.pixels, info.categories, info.t_fore, mock.strength, mock.seed);
                exes.insert((b, true), StepBackend::Mock { arm, calls: Cell::new(0) });
            }
        } else {
            for b in info.step_batch_sizes() {
                let file = info.file(&format!("step_b{b}"))?;
                exes.insert((b, true), StepBackend::Compiled(StepExecutable::load(manifest.path(file), &info, b)?));
                if let Ok(lp) = info.file(&format!("steplp_b{b}")) {
                    exes.insert((b, false), StepBackend::Compiled(StepExecutable::load_variant(manifest.path(lp), &info, b, false)?));
                }
            }
        }
        if exes.is_empty() {
            bail!("model {model} exports no step executables");
        }
        let decoder = if info.kind == ModelKind::Latent {
            let ae_name = info.autoencoder.as_deref().ok_or_else(|| anyhow!("latent model without AE"))?;
            let ae = manifest.ae(ae_name)?;
            let path = manifest.path(&format!("ae_{ae_name}_dec_b32.hlo.txt"));
            Some(DecoderExe::load(path, ae, 32)?)
        } else {
            None
        };
        Ok(Engine { manifest: manifest.clone(), info, exes, decoder })
    }

    /// The full (logp + fore) step backend for an exact batch size.
    pub fn exe(&self, batch: usize) -> Result<&StepBackend> {
        self.exe_for(batch, true)
    }

    /// Pick the cheapest backend that satisfies `need_fore` (the
    /// logp-only variant when the method never reads forecast heads).
    pub fn exe_for(&self, batch: usize, need_fore: bool) -> Result<&StepBackend> {
        if !need_fore {
            if let Some(e) = self.exes.get(&(batch, false)) {
                return Ok(e);
            }
        }
        self.exes
            .get(&(batch, true))
            .ok_or_else(|| anyhow!("model {} has no b{batch} executable (have {:?})", self.info.name, self.exes.keys().collect::<Vec<_>>()))
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.exes.keys().filter(|(_, fore)| *fore).map(|(b, _)| *b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Every exported backend satisfying `need_fore`, ascending by batch
    /// size — the model family the down-shifting scheduler runs over.
    pub fn backends_for(&self, need_fore: bool) -> Vec<&StepBackend> {
        self.batch_sizes().into_iter().filter_map(|b| self.exe_for(b, need_fore).ok()).collect()
    }

    /// Continuous batching over an explicit job queue, using *every*
    /// exported batch size: the schedule starts on the smallest batch
    /// that fits the queue and down-shifts as it drains, so a tail of
    /// stragglers stops paying full-batch passes. Samples are bitwise
    /// independent of the shifting (noise is keyed by job id).
    pub fn sample_continuous(&self, method: Method, noises: Vec<JobNoise>) -> Result<ScheduleReport> {
        ensure!(method != Method::Baseline, "baseline serves through the sync path");
        let backends = self.backends_for(Self::needs_fore(method));
        scheduler::run_continuous_family(&backends, self.forecaster_for(method)?, noises)
    }

    /// As [`Engine::sample_continuous`], over a **live** queue: `feed` is
    /// polled between passes, so jobs can keep arriving while the
    /// schedule runs and the batch up-shifts to absorb them (the serving
    /// layer's elastic path). Results are delivered through
    /// [`JobFeed::complete`] the moment each job converges. Sizes with
    /// the occupancy-first default; see [`Engine::sample_elastic_policy`].
    pub fn sample_elastic(&self, method: Method, initial: Vec<LiveJob>, feed: &mut dyn JobFeed) -> Result<ScheduleReport> {
        self.sample_elastic_policy(method, initial, feed, &crate::coordinator::policy::OccupancyFirst)
    }

    /// As [`Engine::sample_elastic`], with an explicit batch-sizing
    /// policy (occupancy-first, latency-lean, or the SLO hybrid — see
    /// [`crate::coordinator::policy`]). The server builds the policy from
    /// `ServeConfig::policy`/`--policy`; sizing never changes samples.
    pub fn sample_elastic_policy(
        &self,
        method: Method,
        initial: Vec<LiveJob>,
        feed: &mut dyn JobFeed,
        sizing: &dyn crate::coordinator::policy::SizingPolicy,
    ) -> Result<ScheduleReport> {
        self.sample_elastic_primed(method, initial, feed, sizing, None)
    }

    /// As [`Engine::sample_elastic_policy`], seeding the schedule's
    /// convergence EWMAs from the server's cross-schedule history for
    /// this workload ([`crate::coordinator::policy::ConvergenceBook`]),
    /// so SLO sizing's cold-start projections use observed behavior
    /// instead of the worst-case `d` prior. Priming never changes
    /// samples.
    pub fn sample_elastic_primed(
        &self,
        method: Method,
        initial: Vec<LiveJob>,
        feed: &mut dyn JobFeed,
        sizing: &dyn crate::coordinator::policy::SizingPolicy,
        prior: Option<crate::coordinator::policy::ConvergencePrior>,
    ) -> Result<ScheduleReport> {
        ensure!(method != Method::Baseline, "baseline serves through the sync path");
        let backends = self.backends_for(Self::needs_fore(method));
        scheduler::run_elastic_family_primed(&backends, self.forecaster_for(method)?, initial, feed, sizing, prior)
    }

    /// Whether `method` reads the forecast-head outputs.
    pub fn needs_fore(method: Method) -> bool {
        matches!(method, Method::Forecast { .. })
    }

    fn forecaster_for(&self, method: Method) -> Result<Box<dyn Forecaster>> {
        Ok(match method {
            Method::Baseline => bail!("baseline has no forecaster"),
            Method::Zeros => Box::new(forecast::Zeros),
            Method::PredictLast => Box::new(forecast::PredictLast),
            Method::Fpi => Box::new(forecast::FpiReuse),
            Method::Forecast { t_use } => Box::new(forecast::Learned { t_use }),
            Method::NoReparam => Box::new(forecast::NoReparam),
        })
    }

    /// Sample a full batch at `batch_size` with the given method and seed
    /// (synchronous batched semantics: the paper's Tables 1/2 protocol).
    pub fn sample_batch(&self, method: Method, batch_size: usize, seed: u64) -> Result<BatchResult> {
        self.sample_batch_offset(method, batch_size, seed, 0)
    }

    /// As [`Engine::sample_batch`], with slot `s` drawing job noise keyed
    /// `(seed, job_offset + s)`. The serving sync path uses this to chunk a
    /// request larger than the batch executable into *distinct* jobs —
    /// reusing offset 0 for every chunk would repeat the first chunk's
    /// samples verbatim.
    pub fn sample_batch_offset(&self, method: Method, batch_size: usize, seed: u64, job_offset: u64) -> Result<BatchResult> {
        let exe = self.exe_for(batch_size, Self::needs_fore(method))?;
        if method == Method::Baseline {
            let noises: Vec<JobNoise> = (0..batch_size)
                .map(|s| JobNoise::new(seed, job_offset + s as u64, self.info.dim, self.info.categories))
                .collect();
            return ancestral_batch(exe, &noises);
        }
        let mut ps = PredictiveSampler::new(exe, self.forecaster_for(method)?);
        ps.run_sync_offset(seed, job_offset)
    }

    /// Test-set bits/dim through the compiled artifact (paper's bpd).
    pub fn eval_bpd(&self) -> Result<f64> {
        let test = self.manifest.load_test_batch(&self.info.name)?;
        let b = *self.batch_sizes().last().unwrap();
        let exe = self.exe(b)?;
        let n = b.min(test.len());
        let mut x = vec![0i32; b * self.info.dim];
        for (i, row) in test.iter().take(n).enumerate() {
            x[i * self.info.dim..(i + 1) * self.info.dim].copy_from_slice(row);
        }
        let mut out = StepOutput::default();
        exe.run_into(&x, &mut out)?;
        let bpds = bpd_of(&x, &out, n, self.info.dim, self.info.categories);
        Ok(bpds.iter().sum::<f64>() / n as f64)
    }

    /// Decode flat latents to images (latent models only). Input shorter
    /// than the decoder batch is padded and truncated transparently.
    pub fn decode(&self, z: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let dec = self.decoder.as_ref().ok_or_else(|| anyhow!("model {} is not latent", self.info.name))?;
        let s = dec.img_size;
        let mut out = Vec::with_capacity(z.len());
        for chunk in z.chunks(dec.batch) {
            let mut flat = vec![0i32; dec.batch * dec.latent_dim];
            for (i, row) in chunk.iter().enumerate() {
                flat[i * dec.latent_dim..(i + 1) * dec.latent_dim].copy_from_slice(row);
            }
            let imgs = dec.decode(&flat)?;
            for i in 0..chunk.len() {
                out.push(imgs[i * 3 * s * s..(i + 1) * 3 * s * s].to_vec());
            }
        }
        Ok(out)
    }

    pub fn img_size(&self) -> Option<usize> {
        self.decoder.as_ref().map(|d| d.img_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::runtime::artifact::{write_mock_manifest, MockModelSpec};

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Manifest::load(&dir).ok()
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    fn mock_engine(tag: &str) -> Engine {
        let dir = std::env::temp_dir().join(format!("predsamp-engine-{tag}-{}", std::process::id()));
        write_mock_manifest(&dir, &[MockModelSpec::new("mock_m", 21)]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let eng = Engine::load(&man, "mock_m").unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        eng
    }

    #[test]
    fn mock_engine_samples_exactly_without_artifacts() {
        // The full Engine API over the mock backend: exactness holds and
        // FPI saves calls, with no compiled artifacts or PJRT anywhere.
        let eng = mock_engine("exact");
        assert_eq!(eng.batch_sizes(), vec![1, 4]);
        let base = eng.sample_batch(Method::Baseline, 4, 5).unwrap();
        let fpi = eng.sample_batch(Method::Fpi, 4, 5).unwrap();
        for s in 0..4 {
            assert_eq!(fpi.jobs[s].x, base.jobs[s].x, "slot {s}: FPI must equal ancestral");
        }
        assert_eq!(base.arm_calls, eng.info.dim);
        assert!(fpi.arm_calls <= eng.info.dim);
        let exe = eng.exe_for(4, false).unwrap();
        assert!(exe.calls() > 0, "mock backend must count passes");
    }

    #[test]
    fn mock_engine_offset_keys_distinct_jobs() {
        // Chunked serving correctness: offset batches must be (a) distinct
        // from the offset-0 batch and (b) identical to the same job ids
        // sampled at their natural slots.
        let eng = mock_engine("offset");
        let chunk0 = eng.sample_batch_offset(Method::Fpi, 4, 7, 0).unwrap();
        let chunk1 = eng.sample_batch_offset(Method::Fpi, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_ne!(chunk0.jobs[s].x, chunk1.jobs[s].x, "slot {s} repeated across chunks");
        }
        // Job id 4 sampled via offset chunk == job id 4 from a wider batch
        // at slot 4 would need b8; instead compare against offset 4 twice.
        let again = eng.sample_batch_offset(Method::Fpi, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_eq!(chunk1.jobs[s].x, again.jobs[s].x, "offset sampling must be deterministic");
        }
        // Baseline with the same offsets matches bitwise (exactness).
        let base1 = eng.sample_batch_offset(Method::Baseline, 4, 7, 4).unwrap();
        for s in 0..4 {
            assert_eq!(chunk1.jobs[s].x, base1.jobs[s].x, "slot {s}: offset chunk must stay exact");
        }
    }

    #[test]
    fn mock_engine_continuous_downshifts_and_stays_exact() {
        // The serving continuous path: scheduling over the [1, 4] backend
        // family must agree bitwise with the fixed-batch sync path, and a
        // single-job queue must run entirely on the b=1 backend.
        let eng = mock_engine("family");
        let d = eng.info.dim;
        let k = eng.info.categories;
        let sync = eng.sample_batch(Method::Fpi, 4, 9).unwrap();
        let noises: Vec<JobNoise> = (0..4).map(|id| JobNoise::new(9, id, d, k)).collect();
        let rep = eng.sample_continuous(Method::Fpi, noises).unwrap();
        for s in 0..4 {
            assert_eq!(rep.results[s].x, sync.jobs[s].x, "job {s}: continuous family diverged from sync");
        }
        let one = eng.sample_continuous(Method::Fpi, vec![JobNoise::new(9, 0, d, k)]).unwrap();
        assert_eq!(one.min_batch, 1, "single job must use the b=1 backend");
        assert_eq!(one.results[0].x, sync.jobs[0].x);
        assert!(eng.sample_continuous(Method::Baseline, vec![]).is_err());
    }

    #[test]
    fn mock_engine_elastic_feed_matches_continuous() {
        // The serving elastic path: jobs delivered mid-schedule through a
        // feed must sample bitwise identically to the same queue handed
        // over all at once (and results must flow out via the feed).
        use crate::coordinator::scheduler::TickBurstFeed;
        let eng = mock_engine("elastic");
        let (d, k) = (eng.info.dim, eng.info.categories);
        let noises: Vec<JobNoise> = (0..6).map(|id| JobNoise::new(11, id, d, k)).collect();
        let fixed = eng.sample_continuous(Method::Fpi, noises).unwrap();
        let initial = vec![LiveJob { tag: 0, noise: JobNoise::new(11, 0, d, k) }];
        // The burst lands at tick 1, i.e. after the schedule has already
        // run a pass on the b=1 backend.
        let burst: Vec<LiveJob> = (1..6).map(|id| LiveJob { tag: id, noise: JobNoise::new(11, id, d, k) }).collect();
        let mut feed = TickBurstFeed::new(6, vec![(1, burst)]);
        let rep = eng.sample_elastic(Method::Fpi, initial, &mut feed).unwrap();
        for (id, job) in fixed.results.iter().enumerate() {
            assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "job {id}: elastic feed changed the sample");
        }
        assert!(rep.upshifts >= 1, "a 1-job start growing to 6 must up-shift onto the b=4 backend");
    }

    #[test]
    fn engine_loads_and_samples_exactly() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let d = eng.info.dim;
        // Exactness through the real artifact: FPI == baseline, same seed.
        let base = eng.sample_batch(Method::Baseline, 1, 5).unwrap();
        let fpi = eng.sample_batch(Method::Fpi, 1, 5).unwrap();
        assert_eq!(fpi.jobs[0].x, base.jobs[0].x, "FPI must equal ancestral");
        assert_eq!(base.arm_calls, d);
        assert!(fpi.arm_calls < d, "FPI should save calls: {}", fpi.arm_calls);
        // Learned forecasting is exact too.
        let fc = eng.sample_batch(Method::Forecast { t_use: 5 }, 1, 5).unwrap();
        assert_eq!(fc.jobs[0].x, base.jobs[0].x, "forecast must equal ancestral");
    }

    #[test]
    fn engine_bpd_close_to_build() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let bpd = eng.eval_bpd().unwrap();
        let expect = eng.info.bpd;
        assert!((bpd - expect).abs() < 0.15, "bpd {bpd} vs {expect}");
    }

    #[test]
    fn latent_engine_decodes() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "latent_cifar").unwrap();
        let res = eng.sample_batch(Method::Fpi, 1, 0).unwrap();
        let imgs = eng.decode(&[res.jobs[0].x.clone()]).unwrap();
        let s = eng.img_size().unwrap();
        assert_eq!(imgs[0].len(), 3 * s * s);
        assert!(imgs[0].iter().all(|v| v.is_finite()));
    }
}
