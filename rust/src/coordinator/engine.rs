//! Engine: one model's compiled executables + the sampling methods.
//!
//! An `Engine` owns the step executables for each exported batch size (and
//! the paired decoder for latent models), and exposes the paper's methods
//! uniformly. PJRT handles are thread-affine, so an `Engine` never leaves
//! the thread that created it.

use crate::coordinator::config::Method;
use crate::runtime::artifact::{Manifest, ModelInfo, ModelKind};
use crate::runtime::autoenc::DecoderExe;
use crate::runtime::step::{bpd_of, StepExecutable, StepOutput};
use crate::sampler::ancestral::ancestral_batch;
use crate::sampler::forecast::{self, Forecaster};
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::PredictiveSampler;
use crate::sampler::BatchResult;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

pub struct Engine {
    pub manifest: Manifest,
    pub info: ModelInfo,
    /// Keyed by (batch size, with-forecast-heads).
    exes: BTreeMap<(usize, bool), StepExecutable>,
    decoder: Option<DecoderExe>,
}

impl Engine {
    /// Load the engine for `model`, compiling the step executables (full
    /// and, when exported, logp-only) for every batch size.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Engine> {
        let info = manifest.model(model)?.clone();
        let mut exes = BTreeMap::new();
        for b in info.step_batch_sizes() {
            let file = info.file(&format!("step_b{b}"))?;
            exes.insert((b, true), StepExecutable::load(manifest.path(file), &info, b)?);
            if let Ok(lp) = info.file(&format!("steplp_b{b}")) {
                exes.insert((b, false), StepExecutable::load_variant(manifest.path(lp), &info, b, false)?);
            }
        }
        if exes.is_empty() {
            bail!("model {model} exports no step executables");
        }
        let decoder = if info.kind == ModelKind::Latent {
            let ae_name = info.autoencoder.as_deref().ok_or_else(|| anyhow!("latent model without AE"))?;
            let ae = manifest.ae(ae_name)?;
            let path = manifest.path(&format!("ae_{ae_name}_dec_b32.hlo.txt"));
            Some(DecoderExe::load(path, ae, 32)?)
        } else {
            None
        };
        Ok(Engine { manifest: manifest.clone(), info, exes, decoder })
    }

    /// The full (logp + fore) step executable for an exact batch size.
    pub fn exe(&self, batch: usize) -> Result<&StepExecutable> {
        self.exe_for(batch, true)
    }

    /// Pick the cheapest executable that satisfies `need_fore` (the
    /// logp-only variant when the method never reads forecast heads).
    pub fn exe_for(&self, batch: usize, need_fore: bool) -> Result<&StepExecutable> {
        if !need_fore {
            if let Some(e) = self.exes.get(&(batch, false)) {
                return Ok(e);
            }
        }
        self.exes
            .get(&(batch, true))
            .ok_or_else(|| anyhow!("model {} has no b{batch} executable (have {:?})", self.info.name, self.exes.keys().collect::<Vec<_>>()))
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.exes.keys().filter(|(_, fore)| *fore).map(|(b, _)| *b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Whether `method` reads the forecast-head outputs.
    pub fn needs_fore(method: Method) -> bool {
        matches!(method, Method::Forecast { .. })
    }

    fn forecaster_for(&self, method: Method) -> Result<Box<dyn Forecaster>> {
        Ok(match method {
            Method::Baseline => bail!("baseline has no forecaster"),
            Method::Zeros => Box::new(forecast::Zeros),
            Method::PredictLast => Box::new(forecast::PredictLast),
            Method::Fpi => Box::new(forecast::FpiReuse),
            Method::Forecast { t_use } => Box::new(forecast::Learned { t_use }),
            Method::NoReparam => Box::new(forecast::NoReparam),
        })
    }

    /// Sample a full batch at `batch_size` with the given method and seed
    /// (synchronous batched semantics: the paper's Tables 1/2 protocol).
    pub fn sample_batch(&self, method: Method, batch_size: usize, seed: u64) -> Result<BatchResult> {
        let exe = self.exe_for(batch_size, Self::needs_fore(method))?;
        if method == Method::Baseline {
            let noises: Vec<JobNoise> = (0..batch_size)
                .map(|s| JobNoise::new(seed, s as u64, self.info.dim, self.info.categories))
                .collect();
            return ancestral_batch(exe, &noises);
        }
        let mut ps = PredictiveSampler::new(exe, self.forecaster_for(method)?);
        ps.run_sync(seed)
    }

    /// Test-set bits/dim through the compiled artifact (paper's bpd).
    pub fn eval_bpd(&self) -> Result<f64> {
        let test = self.manifest.load_test_batch(&self.info.name)?;
        let b = *self.batch_sizes().last().unwrap();
        let exe = self.exe(b)?;
        let n = b.min(test.len());
        let mut x = vec![0i32; b * self.info.dim];
        for (i, row) in test.iter().take(n).enumerate() {
            x[i * self.info.dim..(i + 1) * self.info.dim].copy_from_slice(row);
        }
        let mut out = StepOutput::default();
        exe.run_into(&x, &mut out)?;
        let bpds = bpd_of(&x, &out, n, self.info.dim, self.info.categories);
        Ok(bpds.iter().sum::<f64>() / n as f64)
    }

    /// Decode flat latents to images (latent models only). Input shorter
    /// than the decoder batch is padded and truncated transparently.
    pub fn decode(&self, z: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let dec = self.decoder.as_ref().ok_or_else(|| anyhow!("model {} is not latent", self.info.name))?;
        let s = dec.img_size;
        let mut out = Vec::with_capacity(z.len());
        for chunk in z.chunks(dec.batch) {
            let mut flat = vec![0i32; dec.batch * dec.latent_dim];
            for (i, row) in chunk.iter().enumerate() {
                flat[i * dec.latent_dim..(i + 1) * dec.latent_dim].copy_from_slice(row);
            }
            let imgs = dec.decode(&flat)?;
            for i in 0..chunk.len() {
                out.push(imgs[i * 3 * s * s..(i + 1) * 3 * s * s].to_vec());
            }
        }
        Ok(out)
    }

    pub fn img_size(&self) -> Option<usize> {
        self.decoder.as_ref().map(|d| d.img_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Manifest::load(&dir).ok()
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn engine_loads_and_samples_exactly() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let d = eng.info.dim;
        // Exactness through the real artifact: FPI == baseline, same seed.
        let base = eng.sample_batch(Method::Baseline, 1, 5).unwrap();
        let fpi = eng.sample_batch(Method::Fpi, 1, 5).unwrap();
        assert_eq!(fpi.jobs[0].x, base.jobs[0].x, "FPI must equal ancestral");
        assert_eq!(base.arm_calls, d);
        assert!(fpi.arm_calls < d, "FPI should save calls: {}", fpi.arm_calls);
        // Learned forecasting is exact too.
        let fc = eng.sample_batch(Method::Forecast { t_use: 5 }, 1, 5).unwrap();
        assert_eq!(fc.jobs[0].x, base.jobs[0].x, "forecast must equal ancestral");
    }

    #[test]
    fn engine_bpd_close_to_build() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "mnist_bin").unwrap();
        let bpd = eng.eval_bpd().unwrap();
        let expect = eng.info.bpd;
        assert!((bpd - expect).abs() < 0.15, "bpd {bpd} vs {expect}");
    }

    #[test]
    fn latent_engine_decodes() {
        let Some(man) = manifest() else { return };
        let eng = Engine::load(&man, "latent_cifar").unwrap();
        let res = eng.sample_batch(Method::Fpi, 1, 0).unwrap();
        let imgs = eng.decode(&[res.jobs[0].x.clone()]).unwrap();
        let s = eng.img_size().unwrap();
        assert_eq!(imgs[0].len(), 3 * s * s);
        assert!(imgs[0].iter().all(|v| v.is_finite()));
    }
}
