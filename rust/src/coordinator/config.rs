//! Serving configuration (CLI- and env-tunable). Every knob is
//! documented — with where it takes effect — in `docs/ARCHITECTURE.md`;
//! a CI grep keeps that page in sync with this struct.

use crate::coordinator::placement::PlacementKind;
use crate::coordinator::policy::{AdmissionKind, PolicyKind};
use crate::substrate::readiness::ReadinessKind;
use anyhow::{ensure, Result};
use std::time::Duration;

/// Sampling method selector (maps 1:1 to the paper's table rows).
/// `Hash`/`Eq` because `(model, method)` keys the server's batching groups;
/// `Ord` because those groups live in ordered maps (iteration order must be
/// deterministic wherever it can reach serialized output — see nondet-guard
/// in `docs/ANALYSIS.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Method {
    /// Naive ancestral sampling: d ARM calls (the paper's baseline).
    Baseline,
    /// Forecast zeros (Table-1 baseline).
    Zeros,
    /// Repeat last observed value (Table-1 baseline).
    PredictLast,
    /// ARM fixed-point iteration (paper §2.3).
    Fpi,
    /// FPI + learned forecasting modules with a T window (paper §2.4).
    Forecast { t_use: usize },
    /// Table-3 ablation: FPI without reparametrization.
    NoReparam,
}

impl Method {
    pub fn parse(name: &str, t_use: usize) -> Option<Method> {
        Some(match name {
            "baseline" | "ancestral" => Method::Baseline,
            "zeros" => Method::Zeros,
            "last" | "predict_last" => Method::PredictLast,
            "fpi" => Method::Fpi,
            "forecast" | "learned" => Method::Forecast { t_use: t_use.max(1) },
            "noreparam" | "fpi_noreparam" => Method::NoReparam,
            _ => return None,
        })
    }

    pub fn label(&self) -> String {
        match self {
            Method::Baseline => "baseline".into(),
            Method::Zeros => "zeros".into(),
            Method::PredictLast => "predict_last".into(),
            Method::Fpi => "fpi".into(),
            Method::Forecast { t_use } => format!("forecast(T={t_use})"),
            Method::NoReparam => "fpi_noreparam".into(),
        }
    }

    /// Wire form of the method: the `(method, t_use)` request-field pair
    /// that [`Method::parse`] maps back to this variant. The federation
    /// router serializes forwarded requests through this; `label()` is
    /// for humans (`forecast(T=5)`) and does not round-trip.
    pub fn wire_name(&self) -> (&'static str, usize) {
        match self {
            Method::Baseline => ("baseline", 1),
            Method::Zeros => ("zeros", 1),
            Method::PredictLast => ("predict_last", 1),
            Method::Fpi => ("fpi", 1),
            Method::Forecast { t_use } => ("forecast", *t_use),
            Method::NoReparam => ("fpi_noreparam", 1),
        }
    }
}

/// Server/engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Batching window: a worker executes its head group once this many
    /// jobs are waiting...
    pub max_batch: usize,
    /// ...or once the group's oldest request has been queued this long
    /// (windows are keyed to each request's *admission* time, so waiting
    /// behind other groups counts against the window).
    pub max_wait: Duration,
    /// Use continuous batching (slot refill) rather than synchronous
    /// batch-at-a-time execution.
    pub continuous: bool,
    /// Elastic batching: a group being executed absorbs its own
    /// mid-flight arrivals into the live schedule (up-shifting the batch
    /// as the queue deepens) instead of stashing them for the next
    /// window. Continuous mode only. Samples are bitwise identical
    /// either way (noise is keyed by `(seed, job index)`).
    pub elastic: bool,
    /// Cross-worker group stealing: a worker whose queue drains pulls a
    /// whole queued `(model, method)` group from the most-loaded worker.
    /// Groups move atomically, so sticky batching and PJRT
    /// thread-affinity are preserved — and samples, as ever, are bitwise
    /// identical either way.
    pub steal: bool,
    /// Connection-plane shards (`--conn-threads`): event-loop threads
    /// the edge is split across. Shard 0 owns the listener and
    /// round-robins accepted sockets; each shard owns its connections'
    /// buffers, token buckets, and in-flight maps outright (no shared
    /// state on the hot path). The default of 1 is exactly the
    /// single-loop topology; delivery semantics — and samples — are
    /// shard-invariant. (Replaces the retired `worker_threads` knob,
    /// which had been parsed-but-dead since the nonblocking edge landed.)
    pub conn_threads: usize,
    /// Readiness backend for the connection shards (`--readiness`):
    /// `auto` (default; epoll on Linux, scan elsewhere), `scan` (the
    /// portable every-socket-every-tick fallback), or `epoll` (Linux
    /// only; O(ready) per tick instead of O(open connections)).
    pub readiness: ReadinessKind,
    /// Engine worker shards. Each owns a full `Router` — PJRT handles are
    /// thread-affine, so engines are replicated per worker, lazily — and
    /// the dispatcher assigns each `(model, method)` batching group to the
    /// least-loaded worker. Job noise is keyed by `(seed, job index)`,
    /// never by worker, so samples are bitwise identical at any setting.
    pub engine_threads: usize,
    /// Batch-sizing policy for live (elastic) schedules (`--policy`):
    /// occupancy-first (full batches, the batch-1 ARM-call rate),
    /// latency-lean (every runnable job seated), or the SLO hybrid
    /// (occupancy until the projected queue delay exceeds [`Self::slo`]).
    /// Sizing never changes samples.
    pub policy: PolicyKind,
    /// Queue-delay target the SLO hybrid sizes against (`--slo-ms`).
    /// Ignored by the other policies.
    pub slo: Duration,
    /// Mid-flight admission policy for executing groups: age-based
    /// oldest-admission-first fairness (default), or the legacy fixed
    /// absorb budget (`--absorb-budget N`). Admission only defers work —
    /// samples are bitwise identical either way.
    pub admission: AdmissionKind,
    /// Model placement across engine workers (`--placement`, `--pin`,
    /// `--max-engines`): replicate-all (default), models pinned to
    /// explicit worker subsets, or an LRU-evicted per-worker engine cap.
    /// Placement only moves `(model, method)` groups between workers, so
    /// samples are bitwise identical under every policy.
    pub placement: PlacementKind,
    /// How long the connection plane waits for the engines to answer a
    /// request before failing it to the client (`--reply-timeout-ms`).
    /// The engine's eventual reply is logged and counted as orphaned,
    /// never silently dropped.
    pub reply_timeout: Duration,
    /// Maximum request line length in bytes (`--max-line-len`). Enforced
    /// *while* buffering: a connection that streams an endless line is
    /// rejected and closed the moment its read buffer crosses the limit,
    /// long before it could exhaust memory.
    pub max_line_len: usize,
    /// Per-connection outbound buffer cap in bytes (`--outbound-cap`).
    /// Read-side backpressure: the event loop stops *reading* a
    /// connection whose unflushed write buffer exceeds the cap, so a slow
    /// reader throttles itself without stalling other connections.
    pub outbound_cap: usize,
    /// Per-connection request rate limit in requests/second, token-bucket
    /// with a one-second burst; 0 disables the limit (`--rate-limit`).
    /// Over-limit requests get a protocol error and the connection stays
    /// open.
    pub rate_limit: u32,
    /// Maximum simultaneously open connections (`--max-conns`). Excess
    /// accepts receive a protocol error and are closed immediately.
    pub max_conns: usize,
    /// Honor the `"stream": true` request field: push one NDJSON event
    /// per completed job before the final reply (`--no-stream` clears).
    /// Delivery timing only — sample payloads stay bitwise identical.
    pub streaming: bool,
    /// Honor the `"frame": true` request field: sample payloads travel as
    /// a length-prefixed binary frame after the JSON header line instead
    /// of inline JSON arrays (`--no-frame` clears). Same bytes, cheaper
    /// wire format.
    pub framing: bool,
    /// Serve planned passes through the shape-variant catalog: engines
    /// collect every exported `{batch, span, flavor}` step shape and run
    /// each pass on the cheapest covering variant (`--no-variants` falls
    /// back to standalone full-shape executables — the kill switch if a
    /// span export misbehaves). Shape selection never changes samples.
    pub variants: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7199".into(),
            max_batch: 32,
            max_wait: Duration::from_millis(20),
            continuous: true,
            elastic: true,
            steal: true,
            conn_threads: 1,
            readiness: ReadinessKind::Auto,
            engine_threads: 2,
            policy: PolicyKind::Occupancy,
            slo: Duration::from_millis(50),
            admission: AdmissionKind::OldestFirst,
            placement: PlacementKind::ReplicateAll,
            reply_timeout: Duration::from_secs(600),
            max_line_len: 1 << 20,
            outbound_cap: 8 << 20,
            rate_limit: 0,
            max_conns: 1024,
            streaming: true,
            framing: true,
            variants: true,
        }
    }
}

impl ServeConfig {
    /// Sanity-check knob ranges before spinning up threads.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.addr.is_empty(), "serve config: empty addr");
        ensure!(self.max_batch >= 1, "serve config: max_batch must be >= 1");
        ensure!(
            (1..=64).contains(&self.conn_threads),
            "serve config: conn_threads must be in [1, 64] (connection-plane event-loop shards)"
        );
        ensure!(
            self.readiness.supported(),
            "serve config: readiness backend {:?} is not supported on this platform (use scan or auto)",
            self.readiness.label()
        );
        ensure!(
            (1..=256).contains(&self.engine_threads),
            "serve config: engine_threads must be in [1, 256] (each worker replicates engines)"
        );
        ensure!(self.max_wait <= Duration::from_secs(60), "serve config: max_wait above 60s will stall clients");
        ensure!(self.slo <= Duration::from_secs(60), "serve config: slo above 60s is not a latency target");
        if let AdmissionKind::Budget(b) = self.admission {
            ensure!(b >= 1, "serve config: absorb budget must be >= 1 (or use age-based admission)");
        }
        ensure!(
            self.reply_timeout >= Duration::from_millis(10) && self.reply_timeout <= Duration::from_secs(3600),
            "serve config: reply_timeout must be in [10ms, 1h]"
        );
        ensure!(
            (256..=256 << 20).contains(&self.max_line_len),
            "serve config: max_line_len must be in [256 B, 256 MiB] (requests are single JSON lines)"
        );
        ensure!(self.outbound_cap >= 4096, "serve config: outbound_cap below 4 KiB cannot hold a single response");
        ensure!(self.rate_limit <= 1_000_000, "serve config: rate_limit above 1M req/s is not a limit");
        ensure!(self.max_conns >= 1, "serve config: max_conns must be >= 1");
        // `streaming` / `framing` / `variants` are plain opt-in switches:
        // every bool combination is valid, so there is nothing to
        // range-check.
        // Placement knobs (pin lists, engine cap) are validated by
        // `placement::placement_for` at spawn — it is the single
        // authority, since it also sees the manifest's own pins.
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("baseline", 1), Some(Method::Baseline));
        assert_eq!(Method::parse("fpi", 1), Some(Method::Fpi));
        assert_eq!(Method::parse("forecast", 5), Some(Method::Forecast { t_use: 5 }));
        assert_eq!(Method::parse("forecast", 0), Some(Method::Forecast { t_use: 1 }));
        assert_eq!(Method::parse("noreparam", 1), Some(Method::NoReparam));
        assert_eq!(Method::parse("wat", 1), None);
    }

    #[test]
    fn labels_stable() {
        assert_eq!(Method::Forecast { t_use: 5 }.label(), "forecast(T=5)");
        assert_eq!(Method::Fpi.label(), "fpi");
    }

    #[test]
    fn wire_names_roundtrip_through_parse() {
        for m in [
            Method::Baseline,
            Method::Zeros,
            Method::PredictLast,
            Method::Fpi,
            Method::Forecast { t_use: 7 },
            Method::NoReparam,
        ] {
            let (name, t_use) = m.wire_name();
            assert_eq!(Method::parse(name, t_use), Some(m), "wire_name must invert parse for {name}");
        }
    }

    #[test]
    fn validate_catches_bad_knobs() {
        assert!(ServeConfig::default().validate().is_ok());
        assert!(ServeConfig { engine_threads: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { engine_threads: 1000, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { conn_threads: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { conn_threads: 65, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { conn_threads: 4, ..ServeConfig::default() }.validate().is_ok());
        assert!(ServeConfig { readiness: ReadinessKind::Scan, ..ServeConfig::default() }.validate().is_ok());
        assert_eq!(
            ServeConfig { readiness: ReadinessKind::Epoll, ..ServeConfig::default() }.validate().is_ok(),
            cfg!(target_os = "linux"),
            "epoll must validate exactly on linux"
        );
        assert!(ServeConfig { max_wait: Duration::from_secs(3600), ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { slo: Duration::from_secs(3600), ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { admission: AdmissionKind::Budget(0), ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { admission: AdmissionKind::Budget(8), policy: PolicyKind::Slo, ..ServeConfig::default() }.validate().is_ok());
    }

    #[test]
    fn validate_catches_bad_edge_knobs() {
        assert!(ServeConfig { reply_timeout: Duration::from_millis(1), ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { reply_timeout: Duration::from_secs(86400), ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { reply_timeout: Duration::from_millis(50), ..ServeConfig::default() }.validate().is_ok());
        assert!(ServeConfig { max_line_len: 16, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { max_line_len: 1 << 30, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { max_line_len: 4096, ..ServeConfig::default() }.validate().is_ok());
        assert!(ServeConfig { outbound_cap: 128, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { outbound_cap: 4096, ..ServeConfig::default() }.validate().is_ok());
        assert!(ServeConfig { rate_limit: 2_000_000, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { rate_limit: 0, ..ServeConfig::default() }.validate().is_ok(), "0 means unlimited");
        assert!(ServeConfig { max_conns: 0, ..ServeConfig::default() }.validate().is_err());
        // The delivery opt-ins are plain switches: any combination is valid.
        assert!(ServeConfig { streaming: false, framing: false, ..ServeConfig::default() }.validate().is_ok());
    }

    #[test]
    fn validate_leaves_placement_to_placement_for() {
        // Placement knobs are validated by `placement_for` at spawn (the
        // single authority — it also sees the manifest's pins); validate
        // must accept any kind rather than duplicate those rules.
        let pin = PlacementKind::Pinned(vec![("m".to_string(), vec![0, 1])]);
        assert!(ServeConfig { placement: PlacementKind::CapacityCapped(1), ..ServeConfig::default() }.validate().is_ok());
        assert!(ServeConfig { placement: pin, ..ServeConfig::default() }.validate().is_ok());
    }
}
