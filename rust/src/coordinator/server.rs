//! TCP serving: line-delimited JSON over a thread pool, with a single
//! engine thread owning all PJRT state.
//!
//! Topology:
//!
//! ```text
//! clients ──TCP──▶ connection workers (ThreadPool)
//!                      │ (Request, reply Sender) over mpsc
//!                      ▼
//!                engine thread: Router + Metrics + dynamic batching
//! ```
//!
//! Compatible `sample` requests arriving within the batching window are
//! merged into one continuous-batching schedule (the per-job noise keyed
//! by (seed, index-within-request) keeps results independent of merging).

use crate::coordinator::config::{Method, ServeConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler;
use crate::runtime::artifact::Manifest;
use crate::sampler::noise::JobNoise;
use crate::substrate::json::Value;
use crate::substrate::threadpool::ThreadPool;
use crate::substrate::timer::Timer;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

type Reply = mpsc::Sender<String>;

enum Msg {
    Req(Request, Reply),
    Shutdown,
}

/// Handle to a running server (for tests and the serving demo).
pub struct ServerHandle {
    pub addr: SocketAddr,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    engine_join: Option<std::thread::JoinHandle<()>>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.engine_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Bind `cfg.addr` (use port 0 for ephemeral) and serve in background
/// threads. The returned handle reports the bound address.
pub fn spawn(manifest_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Msg>();

    // Engine thread: owns Router (PJRT state) + Metrics.
    let cfg2 = cfg.clone();
    let engine_join = std::thread::Builder::new()
        .name("predsamp-engine".into())
        .spawn(move || {
            let manifest = match Manifest::load(&manifest_dir) {
                Ok(m) => m,
                Err(e) => {
                    log::error!("manifest load failed: {e:#}");
                    return;
                }
            };
            engine_loop(Router::new(manifest), cfg2, rx);
        })?;

    // Acceptor + connection workers.
    let pool = ThreadPool::new(cfg.worker_threads);
    let stop2 = Arc::clone(&stop);
    let tx2 = tx.clone();
    let accept_join = std::thread::Builder::new()
        .name("predsamp-accept".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx3 = tx2.clone();
                        let stop3 = Arc::clone(&stop2);
                        pool.execute(move || handle_conn(stream, tx3, stop3));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        log::warn!("accept error: {e}");
                        break;
                    }
                }
            }
            drop(pool); // join workers
        })?;

    Ok(ServerHandle { addr, tx, stop, engine_join: Some(engine_join), accept_join: Some(accept_join) })
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Msg>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    // Read with a timeout so connection workers can observe shutdown even
    // while a client holds the socket open (otherwise ServerHandle::stop
    // would deadlock joining the pool).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        let mut partial = String::new();
        let n = loop {
            match reader.read_line(&mut partial) {
                Ok(n) => break n,
                Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    // partial keeps whatever was read; retry for the rest
                    if partial.ends_with('\n') {
                        break partial.len();
                    }
                }
                Err(_) => return,
            }
        };
        if n == 0 && partial.is_empty() {
            break; // EOF
        }
        line.push_str(&partial);
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(Msg::Req(req, rtx)).is_err() {
                    break;
                }
                match rrx.recv_timeout(Duration::from_secs(600)) {
                    Ok(r) => r,
                    Err(_) => protocol::err("engine timeout"),
                }
            }
            Err(e) => protocol::err(&e),
        };
        if writer.write_all(response.as_bytes()).and_then(|_| writer.write_all(b"\n")).is_err() {
            break;
        }
    }
    log::debug!("connection closed: {peer:?}");
}

/// A sample request admitted to the batching window.
struct PendingSample {
    model: String,
    method: Method,
    n: usize,
    seed: u64,
    return_samples: bool,
    decode: bool,
    reply: Reply,
}

fn engine_loop(mut router: Router, cfg: ServeConfig, rx: mpsc::Receiver<Msg>) {
    let mut metrics = Metrics::new();
    let mut stash: Vec<PendingSample> = Vec::new();
    loop {
        let msg = if stash.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            None
        };
        match msg {
            Some(Msg::Shutdown) => break,
            Some(Msg::Req(req, reply)) => {
                metrics.record_request();
                match req {
                    Request::Sample { model, method, n, seed, return_samples, decode } => {
                        stash.push(PendingSample { model, method, n, seed, return_samples, decode, reply });
                    }
                    other => {
                        let resp = handle_simple(&mut router, &metrics, &other);
                        let _ = reply.send(resp);
                    }
                }
            }
            None => {}
        }
        if stash.is_empty() {
            continue;
        }
        // Batching window: gather more requests compatible with the head.
        let window_end = Instant::now() + cfg.max_wait;
        let head_key = (stash[0].model.clone(), stash[0].method);
        let mut group_jobs: usize = stash.iter().filter(|p| (p.model.clone(), p.method) == head_key).map(|p| p.n).sum();
        while group_jobs < cfg.max_batch {
            let now = Instant::now();
            if now >= window_end {
                break;
            }
            match rx.recv_timeout(window_end - now) {
                Ok(Msg::Req(req, reply)) => {
                    metrics.record_request();
                    match req {
                        Request::Sample { model, method, n, seed, return_samples, decode } => {
                            if (model.clone(), method) == head_key {
                                group_jobs += n;
                            }
                            stash.push(PendingSample { model, method, n, seed, return_samples, decode, reply });
                        }
                        other => {
                            let resp = handle_simple(&mut router, &metrics, &other);
                            let _ = reply.send(resp);
                        }
                    }
                }
                Ok(Msg::Shutdown) => return,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        // Execute the head group; keep the rest stashed for the next turn.
        let (group, rest): (Vec<_>, Vec<_>) = stash.drain(..).partition(|p| (p.model.clone(), p.method) == head_key);
        stash = rest;
        execute_group(&mut router, &cfg, &mut metrics, group);
    }
}

fn handle_simple(router: &mut Router, metrics: &Metrics, req: &Request) -> String {
    match req {
        Request::Ping => protocol::ok(vec![("pong", Value::Bool(true))]),
        Request::Metrics => protocol::ok(vec![("metrics", metrics.snapshot())]),
        Request::Info => {
            let models: Vec<Value> = router
                .manifest()
                .models
                .values()
                .map(|m| {
                    Value::obj(vec![
                        ("name", Value::str(m.name.clone())),
                        ("dim", Value::num(m.dim as f64)),
                        ("categories", Value::num(m.categories as f64)),
                        ("kind", Value::str(format!("{:?}", m.kind))),
                        ("bpd", Value::num(m.bpd)),
                    ])
                })
                .collect();
            protocol::ok(vec![("models", Value::Arr(models))])
        }
        Request::Eval { model } => match router.engine(model).and_then(|e| e.eval_bpd()) {
            Ok(bpd) => protocol::ok(vec![("model", Value::str(model.clone())), ("bpd", Value::num(bpd))]),
            Err(e) => protocol::err(&format!("{e:#}")),
        },
        Request::Sample { .. } => unreachable!("sample handled by batching path"),
    }
}

fn execute_group(router: &mut Router, cfg: &ServeConfig, metrics: &mut Metrics, group: Vec<PendingSample>) {
    if group.is_empty() {
        return;
    }
    let model = group[0].model.clone();
    let method = group[0].method;
    let total_jobs: usize = group.iter().map(|p| p.n).sum();
    let timer = Timer::start();

    let mut run = || -> Result<(Vec<crate::sampler::JobResult>, usize)> {
        let engine = router.engine(&model)?;
        let info = &engine.info;
        if method == Method::Baseline || !cfg.continuous {
            // Synchronous path: per request, pick the smallest exe >= n.
            let mut all = Vec::with_capacity(total_jobs);
            let mut calls = 0usize;
            for p in &group {
                let bs = engine
                    .batch_sizes()
                    .into_iter()
                    .find(|&b| b >= p.n)
                    .unwrap_or_else(|| *engine.batch_sizes().last().unwrap());
                let mut done = 0;
                while done < p.n {
                    let res = engine.sample_batch(method, bs, p.seed)?;
                    calls += res.arm_calls;
                    let take = (p.n - done).min(bs);
                    all.extend(res.jobs.into_iter().take(take));
                    done += take;
                }
            }
            Ok((all, calls))
        } else {
            // Continuous batching over the merged job queue.
            let bs = *engine.batch_sizes().last().unwrap();
            let exe = engine.exe_for(bs, crate::coordinator::engine::Engine::needs_fore(method))?;
            let mut noises = Vec::with_capacity(total_jobs);
            for p in &group {
                for j in 0..p.n {
                    noises.push(JobNoise::new(p.seed, j as u64, info.dim, info.categories));
                }
            }
            let fc = crate::sampler::forecast::by_name(
                match method {
                    Method::Zeros => "zeros",
                    Method::PredictLast => "last",
                    Method::Fpi => "fpi",
                    Method::Forecast { .. } => "learned",
                    Method::NoReparam => "noreparam",
                    Method::Baseline => unreachable!(),
                },
                if let Method::Forecast { t_use } = method { t_use } else { 1 },
            )
            .expect("known method");
            let rep = scheduler::run_continuous_noises(exe, fc, noises)?;
            Ok((rep.results, rep.total_passes))
        }
    };

    match run() {
        Ok((results, calls)) => {
            let wall = timer.secs();
            let dim = results.first().map(|r| r.x.len()).unwrap_or(1);
            metrics.record_batch(total_jobs, calls, dim, wall);
            let mut offset = 0usize;
            for p in group {
                let mine = &results[offset..offset + p.n];
                offset += p.n;
                let mut fields = vec![
                    ("model", Value::str(model.clone())),
                    ("method", Value::str(method.label())),
                    ("arm_calls", Value::num(calls as f64)),
                    ("calls_pct", Value::num(100.0 * calls as f64 / dim as f64)),
                    ("wall_secs", Value::num(wall)),
                    ("n", Value::num(p.n as f64)),
                ];
                if p.return_samples {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    fields.push(("samples", protocol::samples_value(&xs)));
                }
                if p.decode {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    match router.engine(&model).and_then(|e| e.decode(&xs)) {
                        Ok(imgs) => {
                            let arr = Value::Arr(
                                imgs.iter()
                                    .map(|im| Value::Arr(im.iter().map(|&f| Value::num(f as f64)).collect()))
                                    .collect(),
                            );
                            fields.push(("images", arr));
                        }
                        Err(e) => {
                            let _ = p.reply.send(protocol::err(&format!("decode: {e:#}")));
                            continue;
                        }
                    }
                }
                let _ = p.reply.send(protocol::ok(fields));
            }
        }
        Err(e) => {
            metrics.record_error();
            for p in group {
                let _ = p.reply.send(protocol::err(&format!("{e:#}")));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Minimal blocking client for examples, benches and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, wait for the response.
    pub fn call(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(crate::substrate::json::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }
}
