//! TCP serving: line-delimited JSON over a thread pool, dispatched to a
//! sharded pool of engine workers with elastic batching and work stealing.
//!
//! Topology:
//!
//! ```text
//! clients ──TCP──▶ connection workers (ThreadPool)
//!                      │ (Request, reply Sender) over mpsc
//!                      ▼
//!                dispatcher: answers ping/info/metrics, routes each
//!                (model, method) batching group to the least-loaded
//!                engine worker (ties: fewest loaded engines, then
//!                round-robin; sticky while the group has jobs in flight)
//!                      │ shared work pool (per-worker queues + routing
//!                      │ table under one lock)
//!        ┌─────────────┼─────────────┐
//!        ▼             ▼             ▼
//!   engine worker 0  worker 1 …  worker N-1   (cfg.engine_threads)
//!   each: Router + Metrics + admission-keyed batching window
//!        │                           ▲
//!        └── executing group absorbs │ idle workers steal whole queued
//!            its own live arrivals   │ groups from the most-loaded one
//! ```
//!
//! PJRT handles are thread-affine, so every worker owns a full `Router`
//! and engines are replicated per worker (lazily, on first use). Sharding
//! removes the head-of-line blocking a single engine thread imposed on
//! incompatible `(model, method)` groups; two mechanisms keep the fleet
//! work-conserving on top of it:
//!
//! * **Live-queue elasticity** — a group being executed keeps absorbing
//!   its own mid-flight arrivals: the worker's schedule polls the shared
//!   queue between ARM passes ([`crate::coordinator::engine::Engine::sample_elastic`]),
//!   up-shifts onto a larger exported batch when the queue deepens, and
//!   answers each request the moment its last job converges — instead of
//!   stashing arrivals for the next batching window. How the schedule
//!   *sizes* those batches and *which* arrivals it absorbs are pluggable
//!   policies ([`crate::coordinator::policy`]): `cfg.policy`/`cfg.slo`
//!   select occupancy-first, latency-lean, or SLO-hybrid sizing, and
//!   `cfg.admission` gates absorption (age-based oldest-first fairness
//!   by default, so a hot group cannot starve queued neighbours).
//! * **Group stealing** — a worker whose queue drains pulls a whole
//!   queued `(model, method)` group from the most-loaded worker. Groups
//!   move atomically (every queued request at once, order preserved,
//!   route retargeted under the pool lock), so sticky batching and PJRT
//!   thread-affinity survive the migration.
//!
//! Batching windows are sized off each request's *admission* time, not
//! the window's opening: a request queued behind k other groups executes
//! as soon as a worker reaches it, instead of re-paying `cfg.max_wait`
//! per preceding group. Exactness is untouched by any of it: per-job
//! noise is keyed by `(seed, job index within the request)` — never by
//! worker, slot, batch size, or arrival time — so samples are bitwise
//! identical at any `engine_threads`/`elastic`/`steal` setting (see
//! `tests/server_test.rs`).

use crate::coordinator::config::{Method, ServeConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{self, AdmissionCtx, AdmissionPolicy};
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{self, JobFeed, LiveJob, LiveStats};
use crate::runtime::artifact::Manifest;
use crate::sampler::noise::JobNoise;
use crate::sampler::JobResult;
use crate::substrate::json::Value;
use crate::substrate::threadpool::ThreadPool;
use crate::substrate::timer::Timer;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Reply = mpsc::Sender<String>;
type GroupKey = (String, Method);

/// Load units an `eval` contributes to a worker's queue depth. eval_bpd
/// runs a full test-set pass, so it must weigh like a batch of jobs or
/// least-loaded routing would pile groups behind it.
const EVAL_LOAD: usize = 8;

enum Msg {
    Req(Request, Reply),
    Shutdown,
}

/// Shared state of one `(model, method)` batching group. Held by the
/// routing table and by every queued request of the group, so a steal can
/// retarget the route atomically under the pool lock.
struct GroupSlot {
    /// Worker currently owning the group.
    worker: AtomicUsize,
    /// Outstanding jobs; the routing entry dies when this drains to zero.
    pending: AtomicUsize,
}

/// A sample request admitted to the serving plane.
struct PendingSample {
    model: String,
    method: Method,
    n: usize,
    seed: u64,
    return_samples: bool,
    decode: bool,
    reply: Reply,
    /// When the dispatcher admitted the request. Batching windows close
    /// at `admitted + max_wait`, so time spent queued behind other groups
    /// counts against the window instead of restarting it.
    admitted: Instant,
    group: Arc<GroupSlot>,
}

/// Work queued to one engine worker.
enum Work {
    Sample(PendingSample),
    Eval {
        model: String,
        reply: Reply,
        /// Dispatcher admission time — age-based admission must see a
        /// queued eval too, or a hot absorbing group could starve it.
        admitted: Instant,
    },
}

/// Everything routing-related under one lock: per-worker FIFO queues, the
/// group routing table, and what each worker is executing right now —
/// so queueing, routing, and whole-group steals are mutually atomic.
struct PoolState {
    queues: Vec<VecDeque<Work>>,
    /// Per-worker executing group: its live schedule absorbs its own
    /// arrivals, so thieves must never take it.
    executing: Vec<Option<GroupKey>>,
    /// (model, method) → group slot; sticky while `pending > 0`.
    routes: HashMap<GroupKey, Arc<GroupSlot>>,
    /// Workers whose thread has exited (panic included): the dispatcher
    /// routes around them so requests never queue where nobody drains.
    dead: Vec<bool>,
}

/// The shared work pool engine workers and the dispatcher operate on.
struct Pool {
    state: Mutex<PoolState>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Queue depth per worker (jobs routed, not yet answered).
    loads: Vec<Arc<AtomicUsize>>,
}

/// Dispatcher-side handle to one engine worker.
struct WorkerHandle {
    /// Jobs routed to this worker and not yet completed (queue depth).
    load: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    engines_loaded: Arc<AtomicUsize>,
    join: std::thread::JoinHandle<()>,
}

/// Handle to a running server (for tests and the serving demo).
pub struct ServerHandle {
    pub addr: SocketAddr,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    dispatch_join: Option<std::thread::JoinHandle<()>>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.dispatch_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Bind `cfg.addr` (use port 0 for ephemeral) and serve in background
/// threads. The returned handle reports the bound address. Fails fast if
/// the config is invalid or the manifest is unreadable.
pub fn spawn(manifest_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let manifest = Manifest::load(&manifest_dir).context("loading manifest for serving")?;
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Msg>();

    // The shared work pool, then one engine worker thread per shard: each
    // owns a Router (PJRT state) + Metrics.
    let loads: Vec<Arc<AtomicUsize>> = (0..cfg.engine_threads).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let pool = Arc::new(Pool {
        state: Mutex::new(PoolState {
            queues: (0..cfg.engine_threads).map(|_| VecDeque::new()).collect(),
            executing: vec![None; cfg.engine_threads],
            routes: HashMap::new(),
            dead: vec![false; cfg.engine_threads],
        }),
        cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
        loads: loads.clone(),
    });
    let mut workers = Vec::with_capacity(cfg.engine_threads);
    for w in 0..cfg.engine_threads {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let engines_loaded = Arc::new(AtomicUsize::new(0));
        let man = manifest.clone();
        let cfg2 = cfg.clone();
        let (pool2, load2, metrics2, loaded2) = (Arc::clone(&pool), Arc::clone(&loads[w]), Arc::clone(&metrics), Arc::clone(&engines_loaded));
        let join = std::thread::Builder::new()
            .name(format!("predsamp-engine-{w}"))
            .spawn(move || worker_loop(Router::new(man), cfg2, w, pool2, load2, metrics2, loaded2))?;
        workers.push(WorkerHandle { load: Arc::clone(&loads[w]), metrics, engines_loaded, join });
    }

    // Dispatcher: owns the request channel and the group routing table.
    let pool2 = Arc::clone(&pool);
    let dispatch_join = std::thread::Builder::new()
        .name("predsamp-dispatch".into())
        .spawn(move || dispatch_loop(manifest, workers, pool2, rx))?;

    // Acceptor + connection workers.
    let conn_pool = ThreadPool::new(cfg.worker_threads);
    let stop2 = Arc::clone(&stop);
    let tx2 = tx.clone();
    let accept_join = std::thread::Builder::new()
        .name("predsamp-accept".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx3 = tx2.clone();
                        let stop3 = Arc::clone(&stop2);
                        conn_pool.execute(move || handle_conn(stream, tx3, stop3));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        log::warn!("accept error: {e}");
                        break;
                    }
                }
            }
            drop(conn_pool); // join workers
        })?;

    Ok(ServerHandle { addr, tx, stop, dispatch_join: Some(dispatch_join), accept_join: Some(accept_join) })
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Msg>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    // Read with a timeout so connection workers can observe shutdown even
    // while a client holds the socket open (otherwise ServerHandle::stop
    // would deadlock joining the pool).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        let n = loop {
            match reader.read_line(&mut line) {
                Ok(n) => break n,
                Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    // line keeps whatever was read; retry for the rest
                    if line.ends_with('\n') {
                        break line.len();
                    }
                }
                Err(_) => return,
            }
        };
        if n == 0 || !line.ends_with('\n') {
            // EOF. A final partial line is *not* a request: drop it rather
            // than parsing (a truncated frame must not be executed).
            if !line.trim().is_empty() {
                log::debug!("dropping {} bytes of unterminated trailing input from {peer:?}", line.len());
            }
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(Msg::Req(req, rtx)).is_err() {
                    break;
                }
                match rrx.recv_timeout(Duration::from_secs(600)) {
                    Ok(r) => r,
                    Err(_) => protocol::err("engine timeout"),
                }
            }
            Err(e) => protocol::err(&e),
        };
        if writer.write_all(response.as_bytes()).and_then(|_| writer.write_all(b"\n")).is_err() {
            break;
        }
    }
    log::debug!("connection closed: {peer:?}");
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

/// Least-loaded live worker, ties broken by the fewest lazily-loaded
/// engines (an idle fleet spreads lazy engine loads instead of
/// serializing them on worker 0), then round-robin among exact ties.
/// `None` when every worker thread has died.
fn pick_worker(workers: &[WorkerHandle], rr: &mut usize, dead: &[bool]) -> Option<usize> {
    let costs: Vec<(usize, (usize, usize))> = workers
        .iter()
        .enumerate()
        .filter(|&(i, _)| !dead[i])
        .map(|(i, w)| (i, (w.load.load(Ordering::SeqCst), w.engines_loaded.load(Ordering::SeqCst))))
        .collect();
    let best = costs.iter().map(|&(_, c)| c).min()?;
    let ties: Vec<usize> = costs.iter().filter(|&&(_, c)| c == best).map(|&(i, _)| i).collect();
    let pick = ties[*rr % ties.len()];
    *rr += 1;
    Some(pick)
}

fn dispatch_loop(manifest: Manifest, workers: Vec<WorkerHandle>, pool: Arc<Pool>, rx: mpsc::Receiver<Msg>) {
    let started = Instant::now();
    let mut disp = Metrics::new();
    let mut rr = 0usize; // round-robin cursor for routing ties
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        match msg {
            Msg::Shutdown => break,
            Msg::Req(req, reply) => {
                disp.record_request();
                match req {
                    Request::Ping => {
                        let _ = reply.send(protocol::ok(vec![("pong", Value::Bool(true))]));
                    }
                    Request::Info => {
                        let _ = reply.send(info_response(&manifest, &workers));
                    }
                    Request::Metrics => {
                        let _ = reply.send(metrics_response(&disp, &workers, started.elapsed().as_secs_f64()));
                    }
                    Request::Eval { model } => {
                        let mut st = pool.state.lock().expect("pool lock");
                        let Some(w) = pick_worker(&workers, &mut rr, &st.dead) else {
                            drop(st);
                            disp.record_error();
                            let _ = reply.send(protocol::err("engine workers unavailable"));
                            continue;
                        };
                        workers[w].load.fetch_add(EVAL_LOAD, Ordering::SeqCst);
                        st.queues[w].push_back(Work::Eval { model, reply, admitted: Instant::now() });
                        drop(st);
                        pool.cv.notify_all();
                    }
                    Request::Sample { model, method, n, seed, return_samples, decode } => {
                        // Route under the pool lock: a sticky group follows
                        // its (possibly stolen) worker, a fresh group goes
                        // to the least-loaded one, and no steal can
                        // interleave between the route read and the push.
                        let key = (model.clone(), method);
                        let mut st = pool.state.lock().expect("pool lock");
                        let sticky = match st.routes.get(&key) {
                            Some(g) if g.pending.load(Ordering::SeqCst) > 0 => Some(Arc::clone(g)),
                            _ => None,
                        };
                        let group = match sticky {
                            Some(g) => g,
                            None => match pick_worker(&workers, &mut rr, &st.dead) {
                                Some(w) => {
                                    let g = Arc::new(GroupSlot { worker: AtomicUsize::new(w), pending: AtomicUsize::new(0) });
                                    st.routes.insert(key, Arc::clone(&g));
                                    g
                                }
                                None => {
                                    drop(st);
                                    disp.record_error();
                                    let _ = reply.send(protocol::err("engine workers unavailable"));
                                    continue;
                                }
                            },
                        };
                        let mut widx = group.worker.load(Ordering::SeqCst);
                        if st.dead[widx] {
                            // The sticky worker died: re-home the group.
                            match pick_worker(&workers, &mut rr, &st.dead) {
                                Some(w) => {
                                    group.worker.store(w, Ordering::SeqCst);
                                    widx = w;
                                }
                                None => {
                                    drop(st);
                                    disp.record_error();
                                    let _ = reply.send(protocol::err("engine workers unavailable"));
                                    continue;
                                }
                            }
                        }
                        group.pending.fetch_add(n, Ordering::SeqCst);
                        workers[widx].load.fetch_add(n, Ordering::SeqCst);
                        let ps = PendingSample { model, method, n, seed, return_samples, decode, reply, admitted: Instant::now(), group };
                        st.queues[widx].push_back(Work::Sample(ps));
                        if st.routes.len() > 64 {
                            st.routes.retain(|_, g| g.pending.load(Ordering::SeqCst) > 0);
                        }
                        drop(st);
                        pool.cv.notify_all();
                    }
                }
            }
        }
    }
    pool.shutdown.store(true, Ordering::SeqCst);
    pool.cv.notify_all();
    for w in workers {
        let _ = w.join.join();
    }
}

fn info_response(manifest: &Manifest, workers: &[WorkerHandle]) -> String {
    let models: Vec<Value> = manifest
        .models
        .values()
        .map(|m| {
            Value::obj(vec![
                ("name", Value::str(m.name.clone())),
                ("dim", Value::num(m.dim as f64)),
                ("categories", Value::num(m.categories as f64)),
                ("kind", Value::str(format!("{:?}", m.kind))),
                ("bpd", Value::num(m.bpd)),
                ("mock", Value::Bool(m.mock.is_some())),
            ])
        })
        .collect();
    let warr: Vec<Value> = workers
        .iter()
        .enumerate()
        .map(|(i, w)| {
            Value::obj(vec![
                ("id", Value::num(i as f64)),
                ("queue_depth", Value::num(w.load.load(Ordering::SeqCst) as f64)),
                ("engines_loaded", Value::num(w.engines_loaded.load(Ordering::SeqCst) as f64)),
            ])
        })
        .collect();
    protocol::ok(vec![
        ("models", Value::Arr(models)),
        ("engine_workers", Value::num(workers.len() as f64)),
        ("workers", Value::Arr(warr)),
    ])
}

fn metrics_response(disp: &Metrics, workers: &[WorkerHandle], uptime_s: f64) -> String {
    let mut total = Metrics::new();
    total.merge(disp);
    let mut warr = Vec::with_capacity(workers.len());
    for (i, w) in workers.iter().enumerate() {
        let m = w.metrics.lock().unwrap();
        total.merge(&m);
        warr.push(m.worker_value(i, w.load.load(Ordering::SeqCst), w.engines_loaded.load(Ordering::SeqCst)));
    }
    let Value::Obj(mut obj) = total.snapshot() else {
        unreachable!("snapshot is an object")
    };
    obj.insert("engine_workers".into(), Value::num(workers.len() as f64));
    obj.insert("uptime_s".into(), Value::num(uptime_s));
    obj.insert("workers".into(), Value::Arr(warr));
    protocol::ok(vec![("metrics", Value::Obj(obj))])
}

// ---------------------------------------------------------------------------
// Engine workers
// ---------------------------------------------------------------------------

fn handle_eval(router: &mut Router, model: &str, reply: &Reply, metrics: &Mutex<Metrics>, load: &AtomicUsize) {
    let resp = match router.engine(model).and_then(|e| e.eval_bpd()) {
        Ok(bpd) => protocol::ok(vec![("model", Value::str(model)), ("bpd", Value::num(bpd))]),
        Err(e) => {
            metrics.lock().unwrap().record_error();
            protocol::err(&format!("{e:#}"))
        }
    };
    let _ = reply.send(resp);
    load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
}

/// Fail one request (shutdown / unknown model / engine error) and release
/// its load and group accounting.
fn fail_request(p: PendingSample, load: &AtomicUsize, why: &str) {
    let _ = p.reply.send(protocol::err(why));
    p.group.pending.fetch_sub(p.n, Ordering::SeqCst);
    load.fetch_sub(p.n, Ordering::SeqCst);
}

/// Fail every queued work item (shutdown) and release its accounting.
fn abort_queue(queue: VecDeque<Work>, load: &AtomicUsize, why: &str) {
    for w in queue {
        match w {
            Work::Sample(p) => fail_request(p, load, why),
            Work::Eval { reply, .. } => {
                let _ = reply.send(protocol::err(why));
                load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
            }
        }
    }
}

/// Move every queued request of `key` from `queue` into `group`,
/// preserving arrival order.
fn take_group_arrivals(queue: &mut VecDeque<Work>, key: &GroupKey, group: &mut Vec<PendingSample>) {
    let mut i = 0;
    while i < queue.len() {
        let hit = matches!(&queue[i], Work::Sample(p) if p.model == key.0 && p.method == key.1);
        if hit {
            let Some(Work::Sample(p)) = queue.remove(i) else { unreachable!("just matched") };
            group.push(p);
        } else {
            i += 1;
        }
    }
}

/// Steal work from a loaded worker into `thief`'s queue. Victims are
/// tried heaviest-queue first (evals weigh [`EVAL_LOAD`]); from each, the
/// oldest whole queued `(model, method)` group moves atomically — every
/// queued request of the key at once, arrival order preserved, and the
/// route retargeted — all under the pool lock, so sticky batching and
/// PJRT thread-affinity survive the migration. Groups currently executing
/// are never stolen (their owner's live schedule is absorbing arrivals);
/// a victim with nothing but its executing group still yields any queued
/// eval (evals are not sticky — every worker owns a full `Router`).
/// Returns whether anything moved.
fn steal_group(st: &mut PoolState, thief: usize, loads: &[Arc<AtomicUsize>]) -> bool {
    let mut victims: Vec<(usize, usize)> = st
        .queues
        .iter()
        .enumerate()
        .filter(|&(w, _)| w != thief)
        .map(|(w, q)| {
            let weight: usize = q
                .iter()
                .map(|it| match it {
                    Work::Sample(p) => p.n,
                    Work::Eval { .. } => EVAL_LOAD,
                })
                .sum();
            (w, weight)
        })
        .filter(|&(_, weight)| weight > 0)
        .collect();
    victims.sort_by(|a, b| b.1.cmp(&a.1));
    for (v, _) in victims {
        let executing = st.executing[v].clone();
        let key = st.queues[v].iter().find_map(|it| match it {
            Work::Sample(p) => {
                let k = (p.model.clone(), p.method);
                if executing.as_ref() == Some(&k) {
                    None
                } else {
                    Some(k)
                }
            }
            Work::Eval { .. } => None,
        });
        if let Some(key) = key {
            let mut moved: Vec<PendingSample> = Vec::new();
            take_group_arrivals(&mut st.queues[v], &key, &mut moved);
            if !moved.is_empty() {
                let jobs: usize = moved.iter().map(|p| p.n).sum();
                moved[0].group.worker.store(thief, Ordering::SeqCst);
                loads[v].fetch_sub(jobs, Ordering::SeqCst);
                loads[thief].fetch_add(jobs, Ordering::SeqCst);
                for p in moved {
                    st.queues[thief].push_back(Work::Sample(p));
                }
                return true;
            }
        }
        if let Some(pos) = st.queues[v].iter().position(|it| matches!(it, Work::Eval { .. })) {
            let eval = st.queues[v].remove(pos).expect("just found");
            loads[v].fetch_sub(EVAL_LOAD, Ordering::SeqCst);
            loads[thief].fetch_add(EVAL_LOAD, Ordering::SeqCst);
            st.queues[thief].push_back(eval);
            return true;
        }
    }
    false
}

/// Runs on worker-thread exit — panic included: marks the worker dead so
/// the dispatcher routes around it, and fails whatever is queued on it
/// (a request must never sit on a queue nobody will drain).
struct WorkerGuard {
    pool: Arc<Pool>,
    widx: usize,
    load: Arc<AtomicUsize>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let q = {
            let mut st = self.pool.state.lock().unwrap_or_else(|e| e.into_inner());
            st.dead[self.widx] = true;
            std::mem::take(&mut st.queues[self.widx])
        };
        abort_queue(q, &self.load, "engine worker unavailable");
        self.pool.cv.notify_all();
    }
}

fn worker_loop(
    mut router: Router,
    cfg: ServeConfig,
    widx: usize,
    pool: Arc<Pool>,
    load: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    engines_loaded: Arc<AtomicUsize>,
) {
    let _guard = WorkerGuard { pool: Arc::clone(&pool), widx, load: Arc::clone(&load) };
    loop {
        // Claim the oldest work item on our queue, stealing a whole queued
        // group from the most-loaded worker when ours is empty.
        let mut stole = false;
        let mut st = pool.state.lock().expect("pool lock");
        let head = loop {
            if pool.shutdown.load(Ordering::SeqCst) {
                let q = std::mem::take(&mut st.queues[widx]);
                drop(st);
                abort_queue(q, &load, "server shutting down");
                return;
            }
            if let Some(w) = st.queues[widx].pop_front() {
                break w;
            }
            if cfg.steal && steal_group(&mut st, widx, &pool.loads) {
                stole = true;
                continue;
            }
            st = pool.cv.wait_timeout(st, Duration::from_millis(100)).expect("pool lock poisoned").0;
        };
        match head {
            Work::Eval { model, reply, .. } => {
                drop(st);
                if stole {
                    metrics.lock().unwrap().record_steal();
                }
                handle_eval(&mut router, &model, &reply, &metrics, &load);
                engines_loaded.store(router.loaded(), Ordering::SeqCst);
            }
            Work::Sample(head) => {
                // Mark the group executing before the window opens, still
                // under the claim's lock: thieves skip it from here on,
                // and (on the elastic path) the live schedule owns its
                // arrivals through to the end of execution.
                let key = (head.model.clone(), head.method);
                st.executing[widx] = Some(key.clone());
                // Batching window, sized off the *oldest admission* of the
                // head group: a request that already waited its window
                // while queued behind other groups executes immediately
                // instead of re-paying max_wait per preceding group.
                let deadline = head.admitted + cfg.max_wait;
                let mut group = vec![head];
                loop {
                    take_group_arrivals(&mut st.queues[widx], &key, &mut group);
                    // Evals interleave into the window (otherwise, on a
                    // single-worker server with no thief to rescue them,
                    // they'd wait out the whole group execution too).
                    while let Some(pos) = st.queues[widx].iter().position(|it| matches!(it, Work::Eval { .. })) {
                        let Some(Work::Eval { model, reply, .. }) = st.queues[widx].remove(pos) else { unreachable!("just matched") };
                        drop(st);
                        handle_eval(&mut router, &model, &reply, &metrics, &load);
                        engines_loaded.store(router.loaded(), Ordering::SeqCst);
                        st = pool.state.lock().expect("pool lock");
                    }
                    if pool.shutdown.load(Ordering::SeqCst) {
                        let q = std::mem::take(&mut st.queues[widx]);
                        st.executing[widx] = None;
                        drop(st);
                        for p in group {
                            fail_request(p, &load, "server shutting down");
                        }
                        abort_queue(q, &load, "server shutting down");
                        return;
                    }
                    let group_jobs: usize = group.iter().map(|p| p.n).sum();
                    let now = Instant::now();
                    if group_jobs >= cfg.max_batch || now >= deadline {
                        break;
                    }
                    st = pool.cv.wait_timeout(st, deadline - now).expect("pool lock poisoned").0;
                }
                drop(st);
                {
                    // The window just closed: sample each request's queue
                    // age (admission → execution) into the age histogram.
                    let mut m = metrics.lock().unwrap();
                    if stole {
                        m.record_steal();
                    }
                    for p in &group {
                        m.record_admission_age(p.admitted.elapsed());
                    }
                }
                let continuous = cfg.continuous && key.1 != Method::Baseline;
                if continuous && cfg.elastic {
                    execute_elastic_group(&mut router, &metrics, group, &load, &pool, widx, &cfg);
                } else {
                    execute_group(&mut router, &metrics, group, &load, continuous);
                }
                pool.state.lock().expect("pool lock").executing[widx] = None;
                engines_loaded.store(router.loaded(), Ordering::SeqCst);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Group execution
// ---------------------------------------------------------------------------

/// Execute a closed group (synchronous chunking, or continuous batching
/// with elasticity disabled): run the whole merged queue, then answer
/// every request with the group-level stats.
fn execute_group(router: &mut Router, metrics: &Mutex<Metrics>, group: Vec<PendingSample>, load: &AtomicUsize, continuous: bool) {
    if group.is_empty() {
        return;
    }
    let model = group[0].model.clone();
    let method = group[0].method;
    let total_jobs: usize = group.iter().map(|p| p.n).sum();
    let timer = Timer::start();

    // Returns (per-job results in request order, total batched ARM calls,
    // ARM calls per job under the batched cost model — passes × B / jobs,
    // matching ScheduleReport::calls_per_job).
    let mut run = || -> Result<(Vec<JobResult>, usize, f64)> {
        let engine = router.engine(&model)?;
        let info = &engine.info;
        if !continuous {
            // Synchronous path: per request, pick the smallest exe >= n and
            // run it in chunks. Chunk c covers job ids [done, done + bs):
            // the offset keys fresh noise per chunk — without it every
            // chunk would repeat jobs 0..bs and duplicate samples.
            let mut all = Vec::with_capacity(total_jobs);
            let mut calls = 0usize;
            let mut weighted_calls = 0f64;
            for p in &group {
                let bs = engine
                    .batch_sizes()
                    .into_iter()
                    .find(|&b| b >= p.n)
                    .unwrap_or_else(|| *engine.batch_sizes().last().unwrap());
                let mut done = 0;
                while done < p.n {
                    let res = engine.sample_batch_offset(method, bs, p.seed, done as u64)?;
                    calls += res.arm_calls;
                    weighted_calls += (res.arm_calls * bs) as f64;
                    let take = (p.n - done).min(bs);
                    all.extend(res.jobs.into_iter().take(take));
                    done += take;
                }
            }
            Ok((all, calls, weighted_calls / total_jobs as f64))
        } else {
            // Continuous batching over the merged job queue, scheduled
            // across every exported batch size: the engine starts on the
            // smallest batch that fits and down-shifts as the queue
            // drains, so a straggler tail stops paying full-batch passes.
            let mut noises = Vec::with_capacity(total_jobs);
            for p in &group {
                for j in 0..p.n {
                    noises.push(JobNoise::new(p.seed, j as u64, info.dim, info.categories));
                }
            }
            let rep = engine.sample_continuous(method, noises)?;
            Ok((rep.results, rep.total_passes, rep.calls_per_job))
        }
    };

    match run() {
        Ok((results, calls, calls_per_job)) => {
            let wall = timer.secs();
            let dim = results.first().map(|r| r.x.len()).unwrap_or(1);
            let calls_pct = scheduler::calls_pct_of(calls_per_job, dim);
            {
                let mut m = metrics.lock().unwrap();
                m.record_batch(total_jobs, calls, calls_pct, wall);
                // The closed continuous path schedules under the
                // latency-lean (fit) rule; the chunked path is the
                // synchronous baseline.
                m.record_policy(if continuous { "latency" } else { "sync" });
            }
            let mut offset = 0usize;
            for p in group {
                let mine = &results[offset..offset + p.n];
                offset += p.n;
                let mut fields = sample_fields(&model, method, calls, calls_per_job, calls_pct, wall, p.n);
                let mut decode_err: Option<String> = None;
                if p.return_samples {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    fields.push(("samples", protocol::samples_value(&xs)));
                }
                if p.decode {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    match router.engine(&model).and_then(|e| e.decode(&xs)) {
                        Ok(imgs) => fields.push(("images", images_value(&imgs))),
                        Err(e) => decode_err = Some(format!("decode: {e:#}")),
                    }
                }
                let resp = match decode_err {
                    Some(msg) => protocol::err(&msg),
                    None => protocol::ok(fields),
                };
                let _ = p.reply.send(resp);
                p.group.pending.fetch_sub(p.n, Ordering::SeqCst);
                load.fetch_sub(p.n, Ordering::SeqCst);
            }
        }
        Err(e) => {
            metrics.lock().unwrap().record_error();
            let msg = format!("{e:#}");
            for p in group {
                fail_request(p, load, &msg);
            }
        }
    }
}

fn sample_fields(
    model: &str,
    method: Method,
    arm_calls: usize,
    calls_per_job: f64,
    calls_pct: f64,
    wall: f64,
    n: usize,
) -> Vec<(&'static str, Value)> {
    vec![
        ("model", Value::str(model)),
        ("method", Value::str(method.label())),
        ("arm_calls", Value::num(arm_calls as f64)),
        ("calls_per_job", Value::num(calls_per_job)),
        ("calls_pct", Value::num(calls_pct)),
        ("wall_secs", Value::num(wall)),
        ("n", Value::num(n as f64)),
    ]
}

fn images_value(imgs: &[Vec<f32>]) -> Value {
    Value::Arr(
        imgs.iter()
            .map(|im| Value::Arr(im.iter().map(|&f| Value::num(f as f64)).collect()))
            .collect(),
    )
}

/// One request inside a live schedule.
struct FeedReq {
    p: PendingSample,
    results: Vec<Option<JobResult>>,
    remaining: usize,
    replied: bool,
}

/// Bridges a live schedule to the serving plane: polls the worker's
/// shared queue between ARM passes for mid-flight arrivals of the
/// executing group, and answers each request the moment its last job
/// converges (requests needing the decoder wait for the schedule to end,
/// when the router is borrowable again).
struct ServeFeed<'a> {
    pool: &'a Pool,
    widx: usize,
    key: GroupKey,
    dim: usize,
    categories: usize,
    load: &'a AtomicUsize,
    /// Decides whether an arrival of this group joins the live schedule
    /// or stays queued for the next window (fairness: a hot group must
    /// not starve other groups queued on this worker; whatever it leaves
    /// queued forms a normal next window — or gets stolen). Denial only
    /// defers — samples are identical either way.
    admission: Box<dyn AdmissionPolicy>,
    /// Jobs absorbed mid-flight so far (the initial window not counted).
    absorbed_jobs: usize,
    metrics: &'a Mutex<Metrics>,
    /// Sizing-policy label for the per-policy metric counters.
    policy_label: &'static str,
    /// Completed jobs between mid-schedule metric flushes. Age-based
    /// admission puts no bound on a schedule's lifetime (a hot group on
    /// an idle server absorbs forever), so batch/latency/policy metrics
    /// are flushed as windows every `flush_every` completions instead of
    /// only when the schedule ends — otherwise the `metrics` op would
    /// report an eternally-busy server as idle.
    flush_every: usize,
    /// Jobs / slot-passes / passes already flushed to metrics.
    flushed_jobs: usize,
    flushed_slot_passes: usize,
    flushed_passes: usize,
    /// Wall-clock of the current metrics window.
    window_timer: Timer,
    /// Absorption stops once this many requests have joined the schedule
    /// — a hygiene bound, not a fairness knob: every request leaves a
    /// small routing stub in `reqs` for its tags, so an unboundedly
    /// long-lived schedule would leak. When the cap is hit the schedule
    /// drains and ends, replies flush, and the queued backlog opens a
    /// fresh window immediately (windows are keyed to admission time,
    /// so ending costs no extra `max_wait`).
    absorb_cap: usize,
    /// Requests with jobs in the schedule; tags pack (request index,
    /// job index within the request).
    reqs: Vec<FeedReq>,
    /// Completed decode=true requests, replied after the schedule ends.
    deferred: Vec<usize>,
    /// Jobs completed across the whole schedule (group metrics).
    completed_jobs: usize,
    last_stats: Option<LiveStats>,
}

impl<'a> ServeFeed<'a> {
    /// Flush the metrics window ending at `stats`: one `record_batch`
    /// (+ per-policy count) covering everything completed since the last
    /// flush. No-op when the window is empty.
    fn flush_window(&mut self, stats: &LiveStats) {
        let jobs = self.completed_jobs - self.flushed_jobs;
        if jobs == 0 {
            return;
        }
        let slot_passes = stats.slot_passes - self.flushed_slot_passes;
        let passes = stats.passes - self.flushed_passes;
        let calls_per_job = slot_passes as f64 / jobs as f64;
        let wall = self.window_timer.secs();
        {
            let mut m = self.metrics.lock().expect("metrics lock");
            m.record_batch(jobs, passes, scheduler::calls_pct_of(calls_per_job, self.dim), wall);
            m.record_policy(self.policy_label);
        }
        self.flushed_jobs = self.completed_jobs;
        self.flushed_slot_passes = stats.slot_passes;
        self.flushed_passes = stats.passes;
        self.window_timer = Timer::start();
    }

    /// Flush whatever the last completion left unflushed (schedule end).
    fn flush_final(&mut self) {
        if let Some(stats) = self.last_stats {
            self.flush_window(&stats);
        }
    }

    /// Register a request with the schedule, returning its jobs. Noise is
    /// keyed `(seed, job index within the request)` — identical to every
    /// other serving path, which is what makes mid-flight admission exact.
    fn admit_request(&mut self, p: PendingSample) -> Vec<LiveJob> {
        let ri = self.reqs.len() as u64;
        let jobs = (0..p.n)
            .map(|j| LiveJob { tag: ri << 32 | j as u64, noise: JobNoise::new(p.seed, j as u64, self.dim, self.categories) })
            .collect();
        self.reqs.push(FeedReq { remaining: p.n, results: (0..p.n).map(|_| None).collect(), replied: false, p });
        jobs
    }

    /// Answer completed request `ri` with the schedule's stats as of now.
    /// `router` present selects the decode path (only possible once the
    /// schedule ended and the router is borrowable again).
    fn reply_request(&mut self, ri: usize, stats: &LiveStats, router: Option<&mut Router>) {
        let req = &mut self.reqs[ri];
        // Per-request cost: each job owns its slot for exactly its pass
        // count, so slot-passes per job = mean iterations — exact under
        // occupancy sizing (every pass runs a full batch), and never
        // inflated by capacity other jobs are still consuming the way a
        // running schedule-wide ratio would be.
        let iters: usize = req.results.iter().map(|r| r.as_ref().expect("request complete").iterations).sum();
        let calls_per_job = iters as f64 / req.p.n.max(1) as f64;
        let calls_pct = scheduler::calls_pct_of(calls_per_job, self.dim);
        // Wall time is this request's serving latency (queue + schedule),
        // not the whole schedule's age — a request absorbed mid-flight
        // must not inherit the time before it arrived.
        let wall = req.p.admitted.elapsed().as_secs_f64();
        let mut fields = sample_fields(&self.key.0, self.key.1, stats.passes, calls_per_job, calls_pct, wall, req.p.n);
        let xs: Vec<Vec<i32>> = if req.p.return_samples || router.is_some() {
            req.results.iter().map(|r| r.as_ref().expect("request complete").x.clone()).collect()
        } else {
            Vec::new()
        };
        if req.p.return_samples {
            fields.push(("samples", protocol::samples_value(&xs)));
        }
        let resp = match router {
            Some(router) => match router.engine(&self.key.0).and_then(|e| e.decode(&xs)) {
                Ok(imgs) => {
                    fields.push(("images", images_value(&imgs)));
                    protocol::ok(fields)
                }
                Err(e) => protocol::err(&format!("decode: {e:#}")),
            },
            None => protocol::ok(fields),
        };
        let _ = req.p.reply.send(resp);
        req.replied = true;
        // Drop the sample payloads now: a live schedule can absorb for a
        // long time, and only the small routing stub must outlive the
        // reply (tags index `reqs` for the schedule's whole lifetime).
        req.results = Vec::new();
        req.p.group.pending.fetch_sub(req.p.n, Ordering::SeqCst);
        self.load.fetch_sub(req.p.n, Ordering::SeqCst);
    }

    /// Schedule finished cleanly: answer deferred decode requests, then
    /// fail anything that somehow never completed (accounting safety net).
    fn finish(&mut self, router: &mut Router) {
        let stats = self.last_stats.unwrap_or(LiveStats { passes: 0, slot_passes: 0, completed: 0, upshifts: 0, downshifts: 0 });
        for ri in std::mem::take(&mut self.deferred) {
            self.reply_request(ri, &stats, Some(&mut *router));
        }
        self.fail_rest("schedule ended with jobs outstanding");
    }

    /// Fail every request that has not been answered yet.
    fn fail_rest(&mut self, why: &str) {
        for req in self.reqs.iter_mut().filter(|r| !r.replied) {
            let _ = req.p.reply.send(protocol::err(why));
            req.replied = true;
            req.p.group.pending.fetch_sub(req.p.n, Ordering::SeqCst);
            self.load.fetch_sub(req.p.n, Ordering::SeqCst);
        }
    }
}

impl JobFeed for ServeFeed<'_> {
    fn poll(&mut self) -> Vec<LiveJob> {
        // Stop absorbing — letting the schedule drain and end — once (a)
        // a completed decode request is waiting on the router (deferred
        // replies can only be sent after the schedule ends, when the
        // router is borrowable again), or (b) the request table hit its
        // hygiene cap. Queued arrivals just form the next window.
        if !self.deferred.is_empty() || self.reqs.len() >= self.absorb_cap {
            return Vec::new();
        }
        let mut fresh: Vec<PendingSample> = Vec::new();
        let mut denied = false;
        {
            let mut st = self.pool.state.lock().expect("pool lock");
            // The oldest admission among work of *other* groups queued on
            // this worker — whatever absorption would starve. Evals count
            // too: without them, an endlessly-absorbing group could hold
            // a queued eval past any bound (no budget caps the schedule
            // any more).
            let oldest_other = st.queues[self.widx]
                .iter()
                .filter_map(|it| match it {
                    Work::Sample(p) if !(p.model == self.key.0 && p.method == self.key.1) => Some(p.admitted),
                    Work::Sample(_) => None,
                    Work::Eval { admitted, .. } => Some(*admitted),
                })
                .min();
            let oldest_other_age = oldest_other.map(|t| t.elapsed());
            // Take this group's arrivals, oldest first, while the
            // admission policy accepts them. The first denial stops the
            // sweep — later arrivals are younger still — and leaves the
            // denied requests queued in place for the next window (or a
            // thief), preserving arrival order.
            let q = &mut st.queues[self.widx];
            let mut i = 0;
            while i < q.len() {
                let decision = match &q[i] {
                    Work::Sample(p) if p.model == self.key.0 && p.method == self.key.1 => {
                        let ctx = AdmissionCtx { jobs: p.n, absorbed: self.absorbed_jobs, age: p.admitted.elapsed(), oldest_other_age };
                        Some(self.admission.admit(&ctx))
                    }
                    _ => None,
                };
                match decision {
                    Some(true) => {
                        let Some(Work::Sample(p)) = q.remove(i) else { unreachable!("just matched") };
                        self.absorbed_jobs += p.n;
                        fresh.push(p);
                        if self.reqs.len() + fresh.len() >= self.absorb_cap {
                            break;
                        }
                    }
                    Some(false) => {
                        denied = true;
                        break;
                    }
                    None => i += 1,
                }
            }
        }
        if !fresh.is_empty() || denied {
            let mut m = self.metrics.lock().expect("metrics lock");
            for p in &fresh {
                m.record_absorbed(p.n);
                m.record_admission_age(p.admitted.elapsed());
            }
            if denied {
                m.record_absorb_denial();
            }
        }
        let mut jobs = Vec::new();
        for p in fresh {
            jobs.extend(self.admit_request(p));
        }
        jobs
    }

    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats) {
        self.completed_jobs += 1;
        self.last_stats = Some(*stats);
        let (ri, j) = ((tag >> 32) as usize, (tag & 0xffff_ffff) as usize);
        let req = &mut self.reqs[ri];
        req.results[j] = Some(result);
        req.remaining -= 1;
        if req.remaining == 0 {
            if req.p.decode {
                self.deferred.push(ri);
            } else {
                self.reply_request(ri, stats, None);
            }
        }
        if self.completed_jobs - self.flushed_jobs >= self.flush_every {
            self.flush_window(stats);
        }
    }
}

/// Execute a group as a **live** schedule: the initial window plus every
/// mid-flight arrival the feed absorbs (gated by the configured
/// [`AdmissionPolicy`]), sized per pass by the configured
/// [`policy::SizingPolicy`], with per-request replies as they complete.
fn execute_elastic_group(
    router: &mut Router,
    metrics: &Mutex<Metrics>,
    group: Vec<PendingSample>,
    load: &AtomicUsize,
    pool: &Pool,
    widx: usize,
    cfg: &ServeConfig,
) {
    if group.is_empty() {
        return;
    }
    let key = (group[0].model.clone(), group[0].method);
    let shape = router.engine(&key.0).map(|e| (e.info.dim, e.info.categories));
    let (dim, categories) = match shape {
        Ok(s) => s,
        Err(e) => {
            metrics.lock().unwrap().record_error();
            let msg = format!("{e:#}");
            for p in group {
                fail_request(p, load, &msg);
            }
            return;
        }
    };
    let method = key.1;
    let sizing = policy::sizing_for(cfg.policy, cfg.slo);
    let mut feed = ServeFeed {
        pool,
        widx,
        key: key.clone(),
        dim,
        categories,
        load,
        admission: policy::admission_for(cfg.admission, cfg.max_wait),
        absorbed_jobs: 0,
        metrics,
        policy_label: sizing.name(),
        flush_every: cfg.max_batch.max(1) * 8,
        flushed_jobs: 0,
        flushed_slot_passes: 0,
        flushed_passes: 0,
        window_timer: Timer::start(),
        absorb_cap: cfg.max_batch.max(1) * 64,
        reqs: Vec::new(),
        deferred: Vec::new(),
        completed_jobs: 0,
        last_stats: None,
    };
    let mut initial = Vec::new();
    for p in group {
        initial.extend(feed.admit_request(p));
    }
    let rep = router.engine(&key.0).and_then(|e| e.sample_elastic_policy(method, initial, &mut feed, sizing.as_ref()));
    match rep {
        Ok(_) => {
            feed.flush_final();
            feed.finish(router);
        }
        Err(e) => {
            metrics.lock().unwrap().record_error();
            feed.fail_rest(&format!("{e:#}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Minimal blocking client for examples, benches and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, wait for the response.
    pub fn call(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            // A clean EOF is not a malformed response: say what happened.
            anyhow::bail!("connection closed by server");
        }
        Ok(crate::substrate::json::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(model: &str, method: Method, n: usize, widx: usize, routes: &mut HashMap<GroupKey, Arc<GroupSlot>>) -> Work {
        let group = Arc::clone(
            routes
                .entry((model.to_string(), method))
                .or_insert_with(|| Arc::new(GroupSlot { worker: AtomicUsize::new(widx), pending: AtomicUsize::new(0) })),
        );
        group.pending.fetch_add(n, Ordering::SeqCst);
        let (reply, rx) = mpsc::channel();
        drop(rx); // replies are discarded in these unit tests
        let (model, admitted) = (model.to_string(), Instant::now());
        Work::Sample(PendingSample { model, method, n, seed: 0, return_samples: false, decode: false, reply, admitted, group })
    }

    fn queued_keys(q: &VecDeque<Work>) -> Vec<(String, Method)> {
        q.iter()
            .filter_map(|w| match w {
                Work::Sample(p) => Some((p.model.clone(), p.method)),
                Work::Eval { .. } => None,
            })
            .collect()
    }

    fn pool_state(workers: usize) -> PoolState {
        PoolState {
            queues: (0..workers).map(|_| VecDeque::new()).collect(),
            executing: vec![None; workers],
            routes: HashMap::new(),
            dead: vec![false; workers],
        }
    }

    #[test]
    fn steal_moves_whole_group_atomically_and_retargets_route() {
        // Victim (worker 0) queues two groups interleaved; the thief
        // (worker 1) must take the oldest non-executing group *whole*,
        // preserve arrival order, retarget its route, and move the load.
        let mut routes = HashMap::new();
        let mut st = pool_state(2);
        st.queues[0].push_back(sample("a", Method::Fpi, 2, 0, &mut routes));
        st.queues[0].push_back(sample("b", Method::Fpi, 3, 0, &mut routes));
        st.queues[0].push_back(sample("a", Method::Fpi, 1, 0, &mut routes));
        let loads = vec![Arc::new(AtomicUsize::new(6)), Arc::new(AtomicUsize::new(0))];
        assert!(steal_group(&mut st, 1, &loads));
        // Group "a" (the oldest) moved whole: both its requests, in order.
        assert_eq!(queued_keys(&st.queues[1]), vec![("a".to_string(), Method::Fpi), ("a".to_string(), Method::Fpi)]);
        assert_eq!(queued_keys(&st.queues[0]), vec![("b".to_string(), Method::Fpi)]);
        assert_eq!(routes[&("a".to_string(), Method::Fpi)].worker.load(Ordering::SeqCst), 1, "route must follow the stolen group");
        assert_eq!(routes[&("b".to_string(), Method::Fpi)].worker.load(Ordering::SeqCst), 0, "unstolen route must not move");
        assert_eq!(loads[0].load(Ordering::SeqCst), 3);
        assert_eq!(loads[1].load(Ordering::SeqCst), 3);
    }

    #[test]
    fn steal_skips_executing_groups() {
        // The only queued group on the victim is the one it is executing
        // (mid-flight arrivals owned by its live schedule): no steal. A
        // second, non-executing group is fair game.
        let mut routes = HashMap::new();
        let mut st = pool_state(2);
        st.queues[0].push_back(sample("a", Method::Fpi, 2, 0, &mut routes));
        st.executing[0] = Some(("a".to_string(), Method::Fpi));
        let loads = vec![Arc::new(AtomicUsize::new(2)), Arc::new(AtomicUsize::new(0))];
        assert!(!steal_group(&mut st, 1, &loads), "executing group must not be stolen");
        assert_eq!(st.queues[0].len(), 1);
        st.queues[0].push_back(sample("b", Method::Zeros, 1, 0, &mut routes));
        assert!(steal_group(&mut st, 1, &loads), "queued group behind an executing one is stealable");
        assert_eq!(queued_keys(&st.queues[1]), vec![("b".to_string(), Method::Zeros)]);
        assert_eq!(queued_keys(&st.queues[0]), vec![("a".to_string(), Method::Fpi)]);
    }

    #[test]
    fn steal_prefers_most_loaded_victim_and_needs_queued_work() {
        let mut routes = HashMap::new();
        let mut st = pool_state(3);
        let loads = vec![Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(1)), Arc::new(AtomicUsize::new(9))];
        assert!(!steal_group(&mut st, 0, &loads), "nothing queued, nothing to steal");
        st.queues[1].push_back(sample("a", Method::Fpi, 1, 1, &mut routes));
        st.queues[2].push_back(sample("b", Method::Fpi, 9, 2, &mut routes));
        assert!(steal_group(&mut st, 0, &loads));
        assert_eq!(queued_keys(&st.queues[0]), vec![("b".to_string(), Method::Fpi)], "steal must come from the most-loaded queue");
    }

    #[test]
    fn steal_falls_through_to_lighter_victims_and_evals() {
        // The heaviest victim's only queued group is executing; the thief
        // must fall through to the lighter victim's free group rather
        // than give up (work conservation). Once only an eval remains
        // queued anywhere, that moves too — evals are not sticky.
        let mut routes = HashMap::new();
        let mut st = pool_state(3);
        st.queues[1].push_back(sample("hot", Method::Fpi, 9, 1, &mut routes));
        st.executing[1] = Some(("hot".to_string(), Method::Fpi));
        st.queues[2].push_back(sample("cold", Method::Fpi, 1, 2, &mut routes));
        let loads = vec![Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(9)), Arc::new(AtomicUsize::new(1))];
        assert!(steal_group(&mut st, 0, &loads), "a lighter victim with a free group must still be robbed");
        assert_eq!(queued_keys(&st.queues[0]), vec![("cold".to_string(), Method::Fpi)]);
        assert_eq!(st.queues[2].len(), 0);
        // Only the executing group's arrivals and an eval remain: the
        // eval is the one stealable item.
        let (reply, rx) = mpsc::channel();
        drop(rx);
        st.queues[1].push_back(Work::Eval { model: "hot".into(), reply, admitted: Instant::now() });
        assert!(steal_group(&mut st, 2, &loads), "a queued eval behind an executing group is stealable");
        assert!(matches!(st.queues[2].front(), Some(Work::Eval { .. })), "the eval must have moved to the thief");
        assert_eq!(st.queues[1].len(), 1, "the executing group's queued request must stay");
    }
}
