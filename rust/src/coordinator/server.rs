//! TCP serving: line-delimited JSON over a thread pool, dispatched to a
//! sharded pool of engine workers.
//!
//! Topology:
//!
//! ```text
//! clients ──TCP──▶ connection workers (ThreadPool)
//!                      │ (Request, reply Sender) over mpsc
//!                      ▼
//!                dispatcher: answers ping/info/metrics, routes each
//!                (model, method) batching group to the least-loaded
//!                engine worker (sticky while the group has jobs in
//!                flight, so one group's requests batch together)
//!                      │
//!        ┌─────────────┼─────────────┐
//!        ▼             ▼             ▼
//!   engine worker 0  worker 1 …  worker N-1   (cfg.engine_threads)
//!   each: Router + Metrics + dynamic batching window
//! ```
//!
//! PJRT handles are thread-affine, so every worker owns a full `Router`
//! and engines are replicated per worker (lazily, on first use). Sharding
//! removes the head-of-line blocking a single engine thread imposed on
//! incompatible `(model, method)` groups. Continuous batches run through
//! [`crate::coordinator::engine::Engine::sample_continuous`], which
//! schedules over every exported batch size and down-shifts as the queue
//! drains. Exactness is untouched by any of it: per-job noise is keyed by
//! `(seed, job index within the request)` — never by worker, slot, or
//! batch size — so samples are bitwise identical at any `engine_threads`
//! setting (see `tests/server_test.rs`).

use crate::coordinator::config::{Method, ServeConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler;
use crate::runtime::artifact::Manifest;
use crate::sampler::noise::JobNoise;
use crate::substrate::json::Value;
use crate::substrate::threadpool::ThreadPool;
use crate::substrate::timer::Timer;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

type Reply = mpsc::Sender<String>;

/// Load units an `eval` contributes to a worker's queue depth. eval_bpd
/// runs a full test-set pass, so it must weigh like a batch of jobs or
/// least-loaded routing would pile groups behind it.
const EVAL_LOAD: usize = 8;

enum Msg {
    Req(Request, Reply),
    Shutdown,
}

/// Work routed to one engine worker by the dispatcher.
enum WorkerMsg {
    Sample(PendingSample),
    Eval { model: String, reply: Reply },
    Shutdown,
}

/// A sample request admitted to a worker's batching window.
struct PendingSample {
    model: String,
    method: Method,
    n: usize,
    seed: u64,
    return_samples: bool,
    decode: bool,
    reply: Reply,
    /// Outstanding jobs of this request's (model, method) group — shared
    /// with the dispatcher's routing table: the group stays pinned to its
    /// worker until this drains to zero.
    group_pending: Arc<AtomicUsize>,
}

/// Dispatcher-side handle to one engine worker.
struct WorkerHandle {
    tx: mpsc::Sender<WorkerMsg>,
    /// Jobs routed to this worker and not yet completed (queue depth).
    load: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    engines_loaded: Arc<AtomicUsize>,
    join: std::thread::JoinHandle<()>,
}

/// Handle to a running server (for tests and the serving demo).
pub struct ServerHandle {
    pub addr: SocketAddr,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    dispatch_join: Option<std::thread::JoinHandle<()>>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.dispatch_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Bind `cfg.addr` (use port 0 for ephemeral) and serve in background
/// threads. The returned handle reports the bound address. Fails fast if
/// the config is invalid or the manifest is unreadable.
pub fn spawn(manifest_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let manifest = Manifest::load(&manifest_dir).context("loading manifest for serving")?;
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Msg>();

    // Engine workers: each owns a Router (PJRT state) + Metrics.
    let mut workers = Vec::with_capacity(cfg.engine_threads);
    for w in 0..cfg.engine_threads {
        let (wtx, wrx) = mpsc::channel::<WorkerMsg>();
        let load = Arc::new(AtomicUsize::new(0));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let engines_loaded = Arc::new(AtomicUsize::new(0));
        let man = manifest.clone();
        let cfg2 = cfg.clone();
        let (load2, metrics2, loaded2) = (Arc::clone(&load), Arc::clone(&metrics), Arc::clone(&engines_loaded));
        let join = std::thread::Builder::new()
            .name(format!("predsamp-engine-{w}"))
            .spawn(move || worker_loop(Router::new(man), cfg2, wrx, load2, metrics2, loaded2))?;
        workers.push(WorkerHandle { tx: wtx, load, metrics, engines_loaded, join });
    }

    // Dispatcher: owns the request channel and the group routing table.
    let dispatch_join = std::thread::Builder::new()
        .name("predsamp-dispatch".into())
        .spawn(move || dispatch_loop(manifest, workers, rx))?;

    // Acceptor + connection workers.
    let pool = ThreadPool::new(cfg.worker_threads);
    let stop2 = Arc::clone(&stop);
    let tx2 = tx.clone();
    let accept_join = std::thread::Builder::new()
        .name("predsamp-accept".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx3 = tx2.clone();
                        let stop3 = Arc::clone(&stop2);
                        pool.execute(move || handle_conn(stream, tx3, stop3));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        log::warn!("accept error: {e}");
                        break;
                    }
                }
            }
            drop(pool); // join workers
        })?;

    Ok(ServerHandle { addr, tx, stop, dispatch_join: Some(dispatch_join), accept_join: Some(accept_join) })
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Msg>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    // Read with a timeout so connection workers can observe shutdown even
    // while a client holds the socket open (otherwise ServerHandle::stop
    // would deadlock joining the pool).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        let n = loop {
            match reader.read_line(&mut line) {
                Ok(n) => break n,
                Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    // line keeps whatever was read; retry for the rest
                    if line.ends_with('\n') {
                        break line.len();
                    }
                }
                Err(_) => return,
            }
        };
        if n == 0 || !line.ends_with('\n') {
            // EOF. A final partial line is *not* a request: drop it rather
            // than parsing (a truncated frame must not be executed).
            if !line.trim().is_empty() {
                log::debug!("dropping {} bytes of unterminated trailing input from {peer:?}", line.len());
            }
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(Msg::Req(req, rtx)).is_err() {
                    break;
                }
                match rrx.recv_timeout(Duration::from_secs(600)) {
                    Ok(r) => r,
                    Err(_) => protocol::err("engine timeout"),
                }
            }
            Err(e) => protocol::err(&e),
        };
        if writer.write_all(response.as_bytes()).and_then(|_| writer.write_all(b"\n")).is_err() {
            break;
        }
    }
    log::debug!("connection closed: {peer:?}");
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn least_loaded(workers: &[WorkerHandle]) -> usize {
    workers
        .iter()
        .enumerate()
        .min_by_key(|(_, w)| w.load.load(Ordering::SeqCst))
        .map(|(i, _)| i)
        .expect("at least one engine worker")
}

fn dispatch_loop(manifest: Manifest, workers: Vec<WorkerHandle>, rx: mpsc::Receiver<Msg>) {
    let started = Instant::now();
    let mut disp = Metrics::new();
    // (model, method) → (worker, outstanding jobs). Sticky while jobs are
    // in flight so one group's requests land in one batching window.
    let mut groups: HashMap<(String, Method), (usize, Arc<AtomicUsize>)> = HashMap::new();
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        match msg {
            Msg::Shutdown => break,
            Msg::Req(req, reply) => {
                disp.record_request();
                match req {
                    Request::Ping => {
                        let _ = reply.send(protocol::ok(vec![("pong", Value::Bool(true))]));
                    }
                    Request::Info => {
                        let _ = reply.send(info_response(&manifest, &workers));
                    }
                    Request::Metrics => {
                        let _ = reply.send(metrics_response(&disp, &workers, started.elapsed().as_secs_f64()));
                    }
                    Request::Eval { model } => {
                        let w = least_loaded(&workers);
                        workers[w].load.fetch_add(EVAL_LOAD, Ordering::SeqCst);
                        if let Err(mpsc::SendError(WorkerMsg::Eval { reply, .. })) = workers[w].tx.send(WorkerMsg::Eval { model, reply }) {
                            workers[w].load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
                            disp.record_error();
                            let _ = reply.send(protocol::err("engine worker unavailable"));
                        }
                    }
                    Request::Sample { model, method, n, seed, return_samples, decode } => {
                        let key = (model.clone(), method);
                        let (widx, pending) = match groups.get(&key) {
                            Some((w, p)) if p.load(Ordering::SeqCst) > 0 => (*w, Arc::clone(p)),
                            _ => {
                                let w = least_loaded(&workers);
                                let p = Arc::new(AtomicUsize::new(0));
                                groups.insert(key, (w, Arc::clone(&p)));
                                (w, p)
                            }
                        };
                        pending.fetch_add(n, Ordering::SeqCst);
                        workers[widx].load.fetch_add(n, Ordering::SeqCst);
                        let ps = PendingSample { model, method, n, seed, return_samples, decode, reply, group_pending: pending };
                        if let Err(mpsc::SendError(WorkerMsg::Sample(ps))) = workers[widx].tx.send(WorkerMsg::Sample(ps)) {
                            ps.group_pending.fetch_sub(ps.n, Ordering::SeqCst);
                            workers[widx].load.fetch_sub(ps.n, Ordering::SeqCst);
                            disp.record_error();
                            let _ = ps.reply.send(protocol::err("engine worker unavailable"));
                        }
                        if groups.len() > 64 {
                            groups.retain(|_, (_, p)| p.load(Ordering::SeqCst) > 0);
                        }
                    }
                }
            }
        }
    }
    for w in &workers {
        let _ = w.tx.send(WorkerMsg::Shutdown);
    }
    for w in workers {
        let _ = w.join.join();
    }
}

fn info_response(manifest: &Manifest, workers: &[WorkerHandle]) -> String {
    let models: Vec<Value> = manifest
        .models
        .values()
        .map(|m| {
            Value::obj(vec![
                ("name", Value::str(m.name.clone())),
                ("dim", Value::num(m.dim as f64)),
                ("categories", Value::num(m.categories as f64)),
                ("kind", Value::str(format!("{:?}", m.kind))),
                ("bpd", Value::num(m.bpd)),
                ("mock", Value::Bool(m.mock.is_some())),
            ])
        })
        .collect();
    let warr: Vec<Value> = workers
        .iter()
        .enumerate()
        .map(|(i, w)| {
            Value::obj(vec![
                ("id", Value::num(i as f64)),
                ("queue_depth", Value::num(w.load.load(Ordering::SeqCst) as f64)),
                ("engines_loaded", Value::num(w.engines_loaded.load(Ordering::SeqCst) as f64)),
            ])
        })
        .collect();
    protocol::ok(vec![
        ("models", Value::Arr(models)),
        ("engine_workers", Value::num(workers.len() as f64)),
        ("workers", Value::Arr(warr)),
    ])
}

fn metrics_response(disp: &Metrics, workers: &[WorkerHandle], uptime_s: f64) -> String {
    let mut total = Metrics::new();
    total.merge(disp);
    let mut warr = Vec::with_capacity(workers.len());
    for (i, w) in workers.iter().enumerate() {
        let m = w.metrics.lock().unwrap();
        total.merge(&m);
        warr.push(m.worker_value(i, w.load.load(Ordering::SeqCst), w.engines_loaded.load(Ordering::SeqCst)));
    }
    let Value::Obj(mut obj) = total.snapshot() else {
        unreachable!("snapshot is an object")
    };
    obj.insert("engine_workers".into(), Value::num(workers.len() as f64));
    obj.insert("uptime_s".into(), Value::num(uptime_s));
    obj.insert("workers".into(), Value::Arr(warr));
    protocol::ok(vec![("metrics", Value::Obj(obj))])
}

// ---------------------------------------------------------------------------
// Engine workers
// ---------------------------------------------------------------------------

fn handle_eval(router: &mut Router, model: &str, reply: &Reply, metrics: &Mutex<Metrics>, load: &AtomicUsize) {
    let resp = match router.engine(model).and_then(|e| e.eval_bpd()) {
        Ok(bpd) => protocol::ok(vec![("model", Value::str(model)), ("bpd", Value::num(bpd))]),
        Err(e) => {
            metrics.lock().unwrap().record_error();
            protocol::err(&format!("{e:#}"))
        }
    };
    let _ = reply.send(resp);
    load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
}

/// Fail every stashed request (shutdown / dispatcher gone) and release its
/// load accounting.
fn abort_pending(stash: Vec<PendingSample>, load: &AtomicUsize, why: &str) {
    for p in stash {
        let _ = p.reply.send(protocol::err(why));
        p.group_pending.fetch_sub(p.n, Ordering::SeqCst);
        load.fetch_sub(p.n, Ordering::SeqCst);
    }
}

fn worker_loop(
    mut router: Router,
    cfg: ServeConfig,
    rx: mpsc::Receiver<WorkerMsg>,
    load: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    engines_loaded: Arc<AtomicUsize>,
) {
    let mut stash: Vec<PendingSample> = Vec::new();
    loop {
        let msg = if stash.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            None
        };
        match msg {
            Some(WorkerMsg::Shutdown) => break,
            Some(WorkerMsg::Eval { model, reply }) => {
                handle_eval(&mut router, &model, &reply, &metrics, &load);
                engines_loaded.store(router.loaded(), Ordering::SeqCst);
            }
            Some(WorkerMsg::Sample(p)) => stash.push(p),
            None => {}
        }
        if stash.is_empty() {
            continue;
        }
        // Batching window: gather more requests compatible with the head.
        let window_end = Instant::now() + cfg.max_wait;
        let head_key = (stash[0].model.clone(), stash[0].method);
        let mut group_jobs: usize = stash.iter().filter(|p| p.model == head_key.0 && p.method == head_key.1).map(|p| p.n).sum();
        while group_jobs < cfg.max_batch {
            let now = Instant::now();
            if now >= window_end {
                break;
            }
            match rx.recv_timeout(window_end - now) {
                Ok(WorkerMsg::Sample(p)) => {
                    if p.model == head_key.0 && p.method == head_key.1 {
                        group_jobs += p.n;
                    }
                    stash.push(p);
                }
                Ok(WorkerMsg::Eval { model, reply }) => {
                    handle_eval(&mut router, &model, &reply, &metrics, &load);
                    engines_loaded.store(router.loaded(), Ordering::SeqCst);
                }
                Ok(WorkerMsg::Shutdown) => {
                    abort_pending(stash, &load, "server shutting down");
                    return;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    abort_pending(stash, &load, "server shutting down");
                    return;
                }
            }
        }
        // Execute the head group; keep the rest stashed for the next turn.
        let (group, rest): (Vec<_>, Vec<_>) = stash.drain(..).partition(|p| p.model == head_key.0 && p.method == head_key.1);
        stash = rest;
        execute_group(&mut router, &cfg, &metrics, group, &load);
        engines_loaded.store(router.loaded(), Ordering::SeqCst);
    }
    abort_pending(stash, &load, "server shutting down");
}

fn execute_group(router: &mut Router, cfg: &ServeConfig, metrics: &Mutex<Metrics>, group: Vec<PendingSample>, load: &AtomicUsize) {
    if group.is_empty() {
        return;
    }
    let model = group[0].model.clone();
    let method = group[0].method;
    let total_jobs: usize = group.iter().map(|p| p.n).sum();
    let timer = Timer::start();

    // Returns (per-job results in request order, total batched ARM calls,
    // ARM calls per job under the batched cost model — passes × B / jobs,
    // matching ScheduleReport::calls_per_job).
    let mut run = || -> Result<(Vec<crate::sampler::JobResult>, usize, f64)> {
        let engine = router.engine(&model)?;
        let info = &engine.info;
        if method == Method::Baseline || !cfg.continuous {
            // Synchronous path: per request, pick the smallest exe >= n and
            // run it in chunks. Chunk c covers job ids [done, done + bs):
            // the offset keys fresh noise per chunk — without it every
            // chunk would repeat jobs 0..bs and duplicate samples.
            let mut all = Vec::with_capacity(total_jobs);
            let mut calls = 0usize;
            let mut weighted_calls = 0f64;
            for p in &group {
                let bs = engine
                    .batch_sizes()
                    .into_iter()
                    .find(|&b| b >= p.n)
                    .unwrap_or_else(|| *engine.batch_sizes().last().unwrap());
                let mut done = 0;
                while done < p.n {
                    let res = engine.sample_batch_offset(method, bs, p.seed, done as u64)?;
                    calls += res.arm_calls;
                    weighted_calls += (res.arm_calls * bs) as f64;
                    let take = (p.n - done).min(bs);
                    all.extend(res.jobs.into_iter().take(take));
                    done += take;
                }
            }
            Ok((all, calls, weighted_calls / total_jobs as f64))
        } else {
            // Continuous batching over the merged job queue, scheduled
            // across every exported batch size: the engine starts on the
            // smallest batch that fits and down-shifts as the queue
            // drains, so a straggler tail stops paying full-batch passes.
            let mut noises = Vec::with_capacity(total_jobs);
            for p in &group {
                for j in 0..p.n {
                    noises.push(JobNoise::new(p.seed, j as u64, info.dim, info.categories));
                }
            }
            let rep = engine.sample_continuous(method, noises)?;
            Ok((rep.results, rep.total_passes, rep.calls_per_job))
        }
    };

    match run() {
        Ok((results, calls, calls_per_job)) => {
            let wall = timer.secs();
            let dim = results.first().map(|r| r.x.len()).unwrap_or(1);
            let calls_pct = scheduler::calls_pct_of(calls_per_job, dim);
            metrics.lock().unwrap().record_batch(total_jobs, calls, calls_pct, wall);
            let mut offset = 0usize;
            for p in group {
                let mine = &results[offset..offset + p.n];
                offset += p.n;
                let mut fields = vec![
                    ("model", Value::str(model.clone())),
                    ("method", Value::str(method.label())),
                    ("arm_calls", Value::num(calls as f64)),
                    ("calls_per_job", Value::num(calls_per_job)),
                    ("calls_pct", Value::num(calls_pct)),
                    ("wall_secs", Value::num(wall)),
                    ("n", Value::num(p.n as f64)),
                ];
                let mut decode_err: Option<String> = None;
                if p.return_samples {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    fields.push(("samples", protocol::samples_value(&xs)));
                }
                if p.decode {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    match router.engine(&model).and_then(|e| e.decode(&xs)) {
                        Ok(imgs) => {
                            let arr = Value::Arr(
                                imgs.iter()
                                    .map(|im| Value::Arr(im.iter().map(|&f| Value::num(f as f64)).collect()))
                                    .collect(),
                            );
                            fields.push(("images", arr));
                        }
                        Err(e) => decode_err = Some(format!("decode: {e:#}")),
                    }
                }
                let resp = match decode_err {
                    Some(msg) => protocol::err(&msg),
                    None => protocol::ok(fields),
                };
                let _ = p.reply.send(resp);
                p.group_pending.fetch_sub(p.n, Ordering::SeqCst);
                load.fetch_sub(p.n, Ordering::SeqCst);
            }
        }
        Err(e) => {
            metrics.lock().unwrap().record_error();
            let msg = format!("{e:#}");
            for p in group {
                let _ = p.reply.send(protocol::err(&msg));
                p.group_pending.fetch_sub(p.n, Ordering::SeqCst);
                load.fetch_sub(p.n, Ordering::SeqCst);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Minimal blocking client for examples, benches and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, wait for the response.
    pub fn call(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(crate::substrate::json::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }
}
