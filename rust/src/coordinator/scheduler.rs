//! Continuous batching — the scheduling system the paper defers to future
//! work (§4.1: "We leave the implementation of a scheduling system to
//! future work, which would allow sampling at an average rate equal to the
//! batch size 1 setting").
//!
//! In synchronous batching the slowest image pins the whole batch: every
//! other slot idles (recomputes already-final values) until the straggler
//! converges. Here a converged slot is immediately refilled with the next
//! queued job, so the batch's occupancy — and per-job ARM-call cost —
//! approaches the batch-size-1 rate. Per-job noise is keyed by job id
//! (not slot), so results are bitwise identical to any other placement —
//! the refill tests rely on that invariant.
//!
//! Given a *family* of step models (one per exported batch size), the
//! schedule is **elastic** in both directions. Down-shift: once the queue
//! is dry and fewer jobs remain in flight than the current batch, the
//! survivors are migrated — state and all, via
//! [`PredictiveSampler::extract_slot`] — onto the smallest exported batch
//! that still fits, so a draining tail pays for b=1 passes instead of b=B
//! ones. Up-shift: jobs can keep *arriving* while the schedule runs (a
//! [`JobFeed`] is polled between passes), and when the live queue deepens
//! past the current batch the in-flight slots migrate onto the next
//! larger exported batch and the queued jobs are admitted into the freed
//! capacity. Placement irrelevance (noise keyed by job id) is what makes
//! both migrations provably exact.
//!
//! *Which* export a schedule runs on is not decided here: every resize
//! consults a pluggable [`SizingPolicy`](crate::coordinator::policy::SizingPolicy)
//! (occupancy-first, latency-lean, or the SLO-driven hybrid — see
//! [`crate::coordinator::policy`]). The closed-queue entry points pin the
//! latency-lean policy; [`run_elastic_family`] defaults to occupancy-first
//! and [`run_elastic_family_policy`] takes the policy explicitly. Sizing
//! only moves work around, so samples are bitwise identical under every
//! policy (`policy-exactness` in `tests/sampler_props.rs`).
#![deny(missing_docs)]

use crate::coordinator::policy::{self, ConvergencePrior, LatencyLean, OccupancyFirst, SizingCtx, SizingPolicy};
use crate::sampler::forecast::Forecaster;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::{PredictiveSampler, SlotState};
use crate::sampler::{JobResult, StepModel};
use crate::substrate::timer::Timer;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// Smoothing factor for the schedule's per-pass wall-time and
/// passes-per-job estimates (the SLO policy's projection inputs).
const EWMA_ALPHA: f64 = 0.2;

/// Outcome of scheduling `n_jobs` through a fixed-size batch engine.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// Per-job results in job-id order.
    pub results: Vec<JobResult>,
    /// Total ARM passes executed.
    pub total_passes: usize,
    /// Mean active slots per pass (≤ batch size).
    pub occupancy: f64,
    /// Wall-clock seconds the schedule ran for.
    pub wall_secs: f64,
    /// ARM calls per job (slot-passes / n — the batched cost model —
    /// for comparison against the paper's batch-1 rate).
    pub calls_per_job: f64,
    /// Output rows the backends were asked for (log-prob positions +
    /// forecast-head rows), summed over passes — the hot-path bench's
    /// useful-work metric.
    pub positions_evaluated: usize,
    /// Times the schedule migrated to a smaller exported batch size.
    pub downshifts: usize,
    /// Times the schedule migrated to a larger exported batch size (a
    /// live queue deepened past the current batch mid-schedule).
    pub upshifts: usize,
    /// Smallest batch size the schedule executed on.
    pub min_batch: usize,
    /// Label of the sizing policy the schedule ran under (see
    /// [`crate::coordinator::policy::SizingPolicy::name`]; `"sync"` for
    /// the synchronous baseline).
    pub policy: &'static str,
}

/// A job admitted to a live schedule: its noise block plus an opaque tag
/// the feed uses to route the completed result (the serving layer packs a
/// request id and per-request job index into it).
pub struct LiveJob {
    /// Caller-owned routing tag, echoed back through [`JobFeed::complete`].
    pub tag: u64,
    /// The job's reparametrization noise block (keys its identity).
    pub noise: JobNoise,
}

/// Mid-schedule counters handed to [`JobFeed::complete`] — enough for the
/// serving layer to answer a request the moment its last job finishes
/// instead of waiting for the whole schedule to end.
#[derive(Clone, Copy, Debug)]
pub struct LiveStats {
    /// ARM passes executed so far.
    pub passes: usize,
    /// Slot-passes (Σ batch over passes) accumulated so far.
    pub slot_passes: usize,
    /// Jobs completed so far (including the one being delivered).
    pub completed: usize,
    /// Up-shifts (migrations to a larger exported batch) so far.
    pub upshifts: usize,
    /// Down-shifts (migrations to a smaller exported batch) so far.
    pub downshifts: usize,
}

/// Live job source for an elastic schedule. The scheduler polls it
/// between passes, so jobs can be appended while the schedule runs; the
/// schedule ends when the feed is dry and every admitted job converged.
pub trait JobFeed {
    /// Non-blocking poll for newly arrived jobs.
    fn poll(&mut self) -> Vec<LiveJob>;
    /// A job converged; called in completion order, mid-schedule.
    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats);
}

/// The closed feed: nothing arrives; results are collected by tag (which
/// [`run_continuous_family_mode`] assigns as the job's queue index).
struct CollectFeed {
    results: Vec<Option<JobResult>>,
}

impl JobFeed for CollectFeed {
    fn poll(&mut self) -> Vec<LiveJob> {
        Vec::new()
    }
    fn complete(&mut self, tag: u64, result: JobResult, _stats: &LiveStats) {
        self.results[tag as usize] = Some(result);
    }
}

/// Deterministic replay feed: releases each burst once the schedule has
/// polled `tick` times (the scheduler polls once per pass, so ticks are
/// pass counts) and collects results by tag, which must index `0..n`.
/// Bursts must be sorted by tick. This is how tests and benches drive
/// reproducible live-arrival scenarios without threads or clocks.
pub struct TickBurstFeed {
    bursts: VecDeque<(usize, Vec<LiveJob>)>,
    polls: usize,
    /// Completed results, indexed by tag.
    pub results: Vec<Option<JobResult>>,
    /// Stats snapshot delivered with each completion, in order.
    pub completions: Vec<LiveStats>,
    /// Pass count at which each tag's job converged — with the burst tick
    /// it arrived at, a deterministic per-job latency in ARM passes (the
    /// policy bench's latency metric).
    pub completed_pass: Vec<Option<usize>>,
}

impl TickBurstFeed {
    /// A feed over jobs tagged `0..n_jobs`, releasing `bursts` (sorted by
    /// tick) as the schedule polls.
    pub fn new(n_jobs: usize, bursts: Vec<(usize, Vec<LiveJob>)>) -> TickBurstFeed {
        debug_assert!(bursts.windows(2).all(|w| w[0].0 <= w[1].0), "bursts must be sorted by tick");
        TickBurstFeed {
            bursts: bursts.into(),
            polls: 0,
            results: (0..n_jobs).map(|_| None).collect(),
            completions: Vec::new(),
            completed_pass: (0..n_jobs).map(|_| None).collect(),
        }
    }
}

impl JobFeed for TickBurstFeed {
    fn poll(&mut self) -> Vec<LiveJob> {
        let t = self.polls;
        self.polls += 1;
        let mut out = Vec::new();
        while self.bursts.front().is_some_and(|(at, _)| *at <= t) {
            out.extend(self.bursts.pop_front().expect("non-empty").1);
        }
        out
    }
    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats) {
        self.results[tag as usize] = Some(result);
        self.completions.push(*stats);
        self.completed_pass[tag as usize] = Some(stats.passes);
    }
}

/// Per-job ARM calls as a percentage of the baseline's `d` calls — the
/// one normalization both the scheduler reports and the serving layer's
/// per-group responses use.
pub fn calls_pct_of(calls_per_job: f64, dim: usize) -> f64 {
    100.0 * calls_per_job / dim as f64
}

impl ScheduleReport {
    /// See [`calls_pct_of`].
    pub fn calls_pct(&self, dim: usize) -> f64 {
        calls_pct_of(self.calls_per_job, dim)
    }
}

/// Continuous batching: keep every slot busy by refilling converged slots
/// from the queue. Jobs `0..n_jobs` get noise keyed `(seed, job_id)`.
pub fn run_continuous<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    n_jobs: usize,
    seed: u64,
) -> Result<ScheduleReport> {
    let d = model.dim();
    let k = model.categories();
    let noises = (0..n_jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    run_continuous_noises(model, forecaster, noises)
}

/// Continuous batching over an explicit job queue (each job brings its own
/// noise block — used by the server to merge requests with different
/// seeds into one schedule).
pub fn run_continuous_noises<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family(&[model], forecaster, noises)
}

/// Continuous batching with **batch down-shifting** over a family of step
/// models for the same weights at different exported batch sizes. Starts
/// on the smallest batch that fits the queue and migrates surviving jobs
/// to smaller batches as the queue drains. Single-element families reduce
/// to plain continuous batching.
pub fn run_continuous_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family_mode(models, forecaster, noises, true)
}

/// As [`run_continuous_family`]; `use_plan = false` forces full-shape
/// passes (the pre-plan hot path, kept for `benches/sampler_hotpath.rs`).
pub fn run_continuous_family_mode<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
    use_plan: bool,
) -> Result<ScheduleReport> {
    let initial: Vec<LiveJob> = noises.into_iter().enumerate().map(|(id, noise)| LiveJob { tag: id as u64, noise }).collect();
    let mut feed = CollectFeed { results: (0..initial.len()).map(|_| None).collect() };
    let mut rep = schedule_family(models, forecaster, initial, &mut feed, use_plan, &LatencyLean, None)?;
    rep.results = feed.results.into_iter().map(|r| r.expect("all jobs complete")).collect();
    Ok(rep)
}

/// Elastic continuous batching over a **live** queue: `initial` jobs plus
/// whatever `feed` delivers while the schedule runs. Results are handed
/// to [`JobFeed::complete`] as they converge (the returned report's
/// `results` is empty). The schedule up-shifts when the live queue
/// outgrows the current batch and down-shifts as it drains; both
/// directions migrate in-flight slots state-and-all, so every sample is
/// bitwise identical to the same job scheduled any other way.
///
/// Unlike the closed-queue scheduler (which sizes for latency: the
/// smallest exported batch that fits *everything*, even half-empty), the
/// live scheduler defaults to sizing for **occupancy**: the largest
/// exported batch the runnable jobs can completely fill, **parking** any
/// excess in-flight slots (state and all) to resume ahead of fresh
/// admissions. Every pass therefore runs a full batch, which is exactly
/// the paper's §4.1 target of batched sampling at the batch-size-1
/// ARM-call rate. Use [`run_elastic_family_policy`] to size under a
/// different policy.
pub fn run_elastic_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
) -> Result<ScheduleReport> {
    schedule_family(models, forecaster, initial, feed, true, &OccupancyFirst, None)
}

/// As [`run_elastic_family`], sizing every resize decision with an
/// explicit [`SizingPolicy`] (the serving layer builds one from
/// `ServeConfig::policy` / `--policy`). Sizing moves work around but
/// never changes samples: every policy is property-tested bitwise
/// identical to the batch-1 references (`policy-exactness`).
pub fn run_elastic_family_policy<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
    sizing: &dyn SizingPolicy,
) -> Result<ScheduleReport> {
    schedule_family(models, forecaster, initial, feed, true, sizing, None)
}

/// As [`run_elastic_family_policy`], seeding the schedule's per-pass
/// wall-time and passes-per-job EWMAs from a [`ConvergencePrior`] — the
/// server's cross-schedule history for this `(model, method)` workload
/// ([`crate::coordinator::policy::ConvergenceBook`]). A seeded schedule's
/// [`crate::coordinator::policy::SloHybrid`] projections start from
/// observed behavior instead of the worst-case `d` prior, so cold-start
/// up-shift decisions stop being maximally conservative; the EWMAs then
/// blend in the schedule's own observations as usual. Seeding biases
/// sizing only — samples stay bitwise identical under any prior.
pub fn run_elastic_family_primed<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
    sizing: &dyn SizingPolicy,
    prior: Option<ConvergencePrior>,
) -> Result<ScheduleReport> {
    schedule_family(models, forecaster, initial, feed, true, sizing, prior)
}

/// The one scheduling loop under every batching mode. `sizing` decides
/// which exported batch each pass runs on: the closed-queue entry points
/// pass [`LatencyLean`] (smallest export ≥ runnable jobs; never parks),
/// the live entry points pass the caller's policy (the occupancy-first
/// default parks excess in-flight slots to keep batches full). `prior`
/// seeds the wall-time / passes-per-job EWMAs (see
/// [`run_elastic_family_primed`]); `None` starts them cold.
#[allow(clippy::too_many_arguments)]
fn schedule_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
    use_plan: bool,
    sizing: &dyn SizingPolicy,
    prior: Option<ConvergencePrior>,
) -> Result<ScheduleReport> {
    ensure!(!models.is_empty(), "empty model family");
    // Batch sizes ascending. The family must be one model at different
    // exported batch sizes: migrating a job across different shapes would
    // corrupt its noise indexing, and across different weights would
    // silently break exactness. Shape agreement is checkable here
    // (t_fore may legitimately differ — logp-only variants export 0);
    // weight identity is the caller's contract.
    let mut order: Vec<usize> = (0..models.len()).collect();
    order.sort_by_key(|&i| models[i].batch());
    let shapes_agree = models
        .iter()
        .all(|m| m.dim() == models[0].dim() && m.categories() == models[0].categories() && m.pixels() == models[0].pixels());
    ensure!(shapes_agree, "model family mixes shapes");
    // A fore-reading policy migrates forecast-head blocks between family
    // members, so their head shapes must agree too — fail fast here
    // rather than panicking mid-schedule at the first downshift.
    let fores_agree = models.iter().all(|m| m.t_fore() == models[0].t_fore());
    ensure!(fores_agree || !forecaster.reads_fore(), "fore-reading policy over a family with mixed t_fore");
    // Exported batch sizes, ascending, parallel to `order`. The sizing
    // policy picks from these; a value outside the family (a buggy custom
    // policy) degrades to the fit rule rather than panicking.
    let exports: Vec<usize> = order.iter().map(|&i| models[i].batch()).collect();
    let dim = models[0].dim();
    let index_of = |batch: usize| -> usize {
        let pos = exports
            .iter()
            .position(|&e| e == batch)
            .unwrap_or_else(|| exports.iter().position(|&e| e == policy::fit_size(&exports, batch)).expect("fit_size returns an export"));
        order[pos]
    };

    let timer = Timer::start();
    // Queued fresh jobs, each with the pass count at its arrival (the
    // policies' wait gauge).
    let mut queue: VecDeque<(LiveJob, usize)> = initial.into_iter().map(|j| (j, 0)).collect();
    // Mid-flight jobs lifted out when the batch shrinks below the
    // in-flight count (occupancy sizing only); resumed, oldest first,
    // ahead of fresh admissions. Each carries the pass it parked at.
    let mut parked: VecDeque<(u64, SlotState, usize)> = VecDeque::new();
    let mut passes = 0usize;
    // Rolling estimates the SLO policy projects from: wall-seconds per
    // ARM pass, and passes a job needs to converge. A caller-provided
    // prior (server-level cross-schedule history) seeds them; the
    // schedule's own observations blend in through the same EWMA.
    let mut pass_secs: Option<f64> = prior.map(|p| p.pass_secs);
    let mut passes_per_job: Option<f64> = prior.map(|p| p.passes_per_job);
    let ctx0 = SizingCtx {
        in_flight: 0,
        parked: 0,
        queued: queue.len(),
        passes: 0,
        oldest_wait_passes: 0,
        dim,
        pass_secs,
        passes_per_job,
    };
    let mut cur = index_of(sizing.choose(&exports, &ctx0));
    let mut ps = PredictiveSampler::new(models[cur], forecaster);
    ps.set_plan_mode(use_plan);
    let mut slot_job: Vec<Option<u64>> = vec![None; models[cur].batch()];
    let mut completed = 0usize;
    let mut active_accum = 0usize;
    let mut capacity_accum = 0usize;
    let mut positions = 0usize;
    let mut downshifts = 0usize;
    let mut upshifts = 0usize;
    let mut min_batch = models[cur].batch();

    loop {
        // Merge live arrivals before deciding whether anything is left.
        for job in feed.poll() {
            queue.push_back((job, passes));
        }
        let in_flight = slot_job.iter().filter(|j| j.is_some()).count();
        let runnable = in_flight + parked.len() + queue.len();
        if runnable == 0 {
            break;
        }
        // Elastic resize, policy-driven. Larger than the current batch
        // (the live queue deepened) => up-shift; smaller (the queue
        // drained) => down-shift. Both carry each job's full mid-flight
        // state — migrated or parked — so no pass repeats and no sample
        // changes.
        let waiting_since = match (parked.front().map(|p| p.2), queue.front().map(|q| q.1)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let ctx = SizingCtx {
            in_flight,
            parked: parked.len(),
            queued: queue.len(),
            passes,
            oldest_wait_passes: waiting_since.map(|at| passes - at).unwrap_or(0),
            dim,
            pass_secs,
            passes_per_job,
        };
        let target = index_of(sizing.choose(&exports, &ctx));
        if models[target].batch() != models[cur].batch() {
            if models[target].batch() > models[cur].batch() {
                upshifts += 1;
            } else {
                downshifts += 1;
            }
            positions += ps.positions_evaluated;
            let mut moved = Vec::with_capacity(in_flight);
            for (s, sj) in slot_job.iter_mut().enumerate() {
                if let Some(job) = sj.take() {
                    moved.push((job, ps.extract_slot(s).expect("in-flight slot")));
                }
            }
            let fc = ps.into_forecaster();
            cur = target;
            min_batch = min_batch.min(models[cur].batch());
            ps = PredictiveSampler::new(models[cur], fc);
            ps.set_plan_mode(use_plan);
            slot_job = vec![None; models[cur].batch()];
            let batch = models[cur].batch();
            for (s, (job, st)) in moved.drain(..batch.min(moved.len())).enumerate() {
                ps.install_slot(s, st);
                slot_job[s] = Some(job);
            }
            // A shrink below the in-flight count parks the rest (FIFO by
            // park time behind anything already parked).
            parked.extend(moved.into_iter().map(|(job, st)| (job, st, passes)));
        }
        // Fill every free slot: parked jobs resume first, then fresh
        // admissions from the queue.
        for (s, sj) in slot_job.iter_mut().enumerate() {
            if sj.is_none() {
                if let Some((job, st, _)) = parked.pop_front() {
                    ps.install_slot(s, st);
                    *sj = Some(job);
                } else if let Some((job, _)) = queue.pop_front() {
                    let got = ps.admit(job.noise).expect("free slot");
                    debug_assert_eq!(got, s);
                    *sj = Some(job.tag);
                }
            }
        }
        active_accum += slot_job.iter().filter(|j| j.is_some()).count();
        capacity_accum += models[cur].batch();
        let pass_timer = Timer::start();
        ps.step()?;
        let spent = pass_timer.secs();
        pass_secs = Some(match pass_secs {
            None => spent,
            Some(p) => p + EWMA_ALPHA * (spent - p),
        });
        passes += 1;
        for (s, sj) in slot_job.iter_mut().enumerate() {
            if sj.is_some() && ps.slot_done(s) {
                let tag = sj.take().unwrap();
                completed += 1;
                let result = ps.take_result(s).expect("done slot");
                let iters = result.iterations as f64;
                passes_per_job = Some(match passes_per_job {
                    None => iters,
                    Some(p) => p + EWMA_ALPHA * (iters - p),
                });
                let stats = LiveStats { passes, slot_passes: capacity_accum, completed, upshifts, downshifts };
                feed.complete(tag, result, &stats);
            }
        }
    }
    positions += ps.positions_evaluated;

    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / capacity_accum.max(1) as f64,
        wall_secs: timer.secs(),
        calls_per_job: capacity_accum as f64 / completed.max(1) as f64,
        results: Vec::new(),
        positions_evaluated: positions,
        downshifts,
        upshifts,
        min_batch,
        policy: sizing.name(),
    })
}

/// Synchronous batching baseline: process jobs in batch-size chunks; each
/// chunk runs until its slowest job converges (the paper's Table-1/2
/// semantics, extended to a queue of jobs). One sampler — and its `[B*d]`
/// input and step-output buffers — is built once and reset between chunks
/// instead of reallocated per chunk.
pub fn run_sync_chunks<M: StepModel>(model: &M, forecaster: Box<dyn Forecaster>, n_jobs: usize, seed: u64) -> Result<ScheduleReport> {
    let b = model.batch();
    let d = model.dim();
    let k = model.categories();
    let timer = Timer::start();
    let mut ps = PredictiveSampler::new(model, forecaster);
    let mut results: Vec<JobResult> = Vec::with_capacity(n_jobs);
    let mut passes = 0usize;
    let mut active_accum = 0usize;
    let mut start = 0usize;
    while start < n_jobs {
        let chunk = (n_jobs - start).min(b);
        for s in 0..chunk {
            ps.reset_slot(s, JobNoise::new(seed, (start + s) as u64, d, k));
        }
        for s in chunk..b {
            ps.clear_slot(s);
        }
        while (0..chunk).any(|s| !ps.slot_done(s)) {
            active_accum += (0..chunk).filter(|&s| !ps.slot_done(s)).count();
            ps.step()?;
            passes += 1;
        }
        for s in 0..chunk {
            results.push(ps.take_result(s).expect("chunk job done"));
        }
        start += chunk;
    }
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / (passes.max(1) * b) as f64,
        wall_secs: timer.secs(),
        calls_per_job: passes as f64 * b as f64 / n_jobs as f64,
        results,
        positions_evaluated: ps.positions_evaluated,
        downshifts: 0,
        upshifts: 0,
        min_batch: b,
        policy: "sync",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::forecast::FpiReuse;
    use crate::sampler::mock::MockArm;
    use crate::sampler::noise::JobNoise;
    use crate::sampler::predictive::PredictiveSampler;

    fn reference_samples(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let m1 = MockArm::new(1, 3, 6, 4, 2, 2.5, 21);
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), 4));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }

    #[test]
    fn continuous_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_continuous(&m, Box::new(FpiReuse), 11, 3).unwrap();
        assert_eq!(rep.results.len(), 11);
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i} sample changed under scheduling");
        }
        assert!(rep.occupancy > 0.0 && rep.occupancy <= 1.0);
    }

    #[test]
    fn sync_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_sync_chunks(&m, Box::new(FpiReuse), 11, 3).unwrap();
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    #[test]
    fn continuous_at_least_as_efficient() {
        // With heterogeneous convergence, slot refill can only reduce the
        // number of passes needed for a queue of jobs.
        let m = MockArm::new(4, 3, 8, 5, 2, 3.0, 33);
        let cont = run_continuous(&m, Box::new(FpiReuse), 16, 9).unwrap();
        let sync = run_sync_chunks(&m, Box::new(FpiReuse), 16, 9).unwrap();
        assert!(
            cont.total_passes <= sync.total_passes,
            "continuous {} > sync {}",
            cont.total_passes,
            sync.total_passes
        );
        assert!(cont.occupancy >= sync.occupancy - 1e-9);
    }

    #[test]
    fn occupancy_and_calls_per_job_stay_bounded() {
        // Property: as jobs drain, occupancy stays in [1/B, 1] (every pass
        // has at least one active slot, at most B) and calls_per_job stays
        // in [1, B*d] (every job needs >= 1 pass; no job survives more
        // than d passes). The identity occupancy * passes * B = total
        // job-iterations ties the two together.
        use crate::substrate::proptest_lite::check;
        check("scheduler-bounds", 16, |g| {
            let b = g.usize_in(1, 7);
            let m = MockArm::new(b, g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6), 1, g.f64_in(0.0, 4.0) as f32, g.rng.next_u64());
            let n = g.usize_in(1, 20);
            let rep = run_continuous(&m, Box::new(FpiReuse), n, g.rng.next_u64()).map_err(|e| e.to_string())?;
            let (bf, d) = (b as f64, m.dim() as f64);
            crate::prop_assert!(
                rep.occupancy >= 1.0 / bf - 1e-9 && rep.occupancy <= 1.0 + 1e-9,
                "occupancy {} outside [1/{b}, 1] (n={n})",
                rep.occupancy
            );
            crate::prop_assert!(rep.calls_per_job >= 1.0 - 1e-9, "calls_per_job {} < 1", rep.calls_per_job);
            crate::prop_assert!(rep.calls_per_job <= bf * d + 1e-9, "calls_per_job {} > B*d = {}", rep.calls_per_job, bf * d);
            let iterations = rep.occupancy * rep.total_passes as f64 * bf;
            crate::prop_assert!(iterations >= n as f64 - 1e-6, "total iterations {iterations} < n={n}");
            let pct = rep.calls_pct(m.dim());
            crate::prop_assert!((pct - 100.0 * rep.calls_per_job / d).abs() < 1e-9, "calls_pct helper disagrees");
            Ok(())
        });
    }

    #[test]
    fn queue_drain_downshifts_to_smaller_batches_bitwise() {
        // THE down-shifting acceptance gate: a queue draining through a
        // [b=1, b=2, b=4] family must migrate the surviving jobs onto
        // smaller executables — reaching b=1 for the straggler — while
        // every per-job sample stays bitwise identical to the fixed-batch
        // (and batch-1) references. Several seeds are scheduled so the
        // drain tail is exercised in different shapes; a straggler tail
        // that reaches batch 1 must occur.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m2 = MockArm { batch: 2, ..m4.clone() };
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let mut saw_b1 = false;
        for seed in 0..8u64 {
            let n = 9;
            let noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, m4.dim(), 4)).collect();
            let rep = run_continuous_family(&family, Box::new(FpiReuse), noises).unwrap();
            let fixed = run_continuous(&m4, Box::new(FpiReuse), n, seed).unwrap();
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, fixed.results[i].x, "seed {seed} job {i}: down-shifting changed the sample");
            }
            let refs = reference_samples(n, seed);
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, refs[i], "seed {seed} job {i}: family schedule diverged from batch-1 reference");
            }
            // Down-shifting can only shed slot-passes.
            assert!(
                rep.calls_per_job <= fixed.calls_per_job + 1e-9,
                "seed {seed}: down-shifted calls/job {} > fixed {}",
                rep.calls_per_job,
                fixed.calls_per_job
            );
            assert!(rep.min_batch < 4 || rep.downshifts == 0, "min_batch must track migrations");
            saw_b1 |= rep.min_batch == 1;
        }
        assert!(saw_b1, "no schedule drained to the b=1 executable — straggler tails must down-shift");
    }

    fn live_jobs(ids: std::ops::Range<usize>, seed: u64, d: usize, k: usize) -> Vec<LiveJob> {
        ids.map(|id| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) }).collect()
    }

    #[test]
    fn live_arrivals_upshift_and_stay_bitwise() {
        // THE up-shifting acceptance gate: a schedule that starts with one
        // job on the b=1 executable and sees the queue deepen mid-flight
        // must migrate onto larger exported batches — and every sample
        // must stay bitwise identical to the batch-1 reference and to the
        // same jobs scheduled all-at-once.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m2 = MockArm { batch: 2, ..m4.clone() };
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let (d, k) = (m4.dim(), 4);
        let mut saw_upshift = false;
        for seed in 0..6u64 {
            let n = 9;
            let initial = live_jobs(0..1, seed, d, k);
            let bursts = vec![(1, live_jobs(1..4, seed, d, k)), (3, live_jobs(4..n, seed, d, k))];
            let mut feed = TickBurstFeed::new(n, bursts);
            let rep = run_elastic_family(&family, Box::new(FpiReuse), initial, &mut feed).unwrap();
            let refs = reference_samples(n, seed);
            for (id, r) in feed.results.iter().enumerate() {
                let r = r.as_ref().expect("job completed");
                assert_eq!(r.x, refs[id], "seed {seed} job {id}: up-shifting changed the sample");
            }
            let all_noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
            let all_at_once = run_continuous_family(&family, Box::new(FpiReuse), all_noises).unwrap();
            for (id, job) in all_at_once.results.iter().enumerate() {
                assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "seed {seed} job {id}: live arrival order changed the sample");
            }
            assert_eq!(feed.completions.len(), n, "every completion must be delivered through the feed");
            assert!(feed.completions.windows(2).all(|w| w[0].completed < w[1].completed), "completion stats must be monotone");
            saw_upshift |= rep.upshifts > 0;
            // A grown-then-drained queue must also shed batch again.
            assert!(rep.upshifts == 0 || rep.min_batch <= 2 || rep.downshifts > 0, "seed {seed}: grown schedule never downshifted");
        }
        assert!(saw_upshift, "queue deepening never up-shifted the batch");
    }

    #[test]
    fn elastic_closed_queue_stays_exact_and_sheds_waste() {
        // A dry feed degenerates the elastic scheduler to a closed queue:
        // samples must stay bitwise identical to the latency-sized
        // continuous schedule, the batch never grows (nothing arrives),
        // and occupancy sizing (fill the largest export, park the rest)
        // must spend no more slot-passes per job than fit sizing does.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let (d, k) = (m4.dim(), 4);
        let n = 7;
        let mut feed = TickBurstFeed::new(n, Vec::new());
        let rep = run_elastic_family(&family, Box::new(FpiReuse), live_jobs(0..n, 5, d, k), &mut feed).unwrap();
        let fixed = run_continuous_family(&family, Box::new(FpiReuse), (0..n).map(|id| JobNoise::new(5, id as u64, d, k)).collect()).unwrap();
        assert_eq!(rep.upshifts, 0, "nothing arrived, nothing to grow for");
        assert!(
            rep.calls_per_job <= fixed.calls_per_job + 1e-9,
            "occupancy sizing must not waste slot-passes: elastic {} vs fit {}",
            rep.calls_per_job,
            fixed.calls_per_job
        );
        assert!(rep.occupancy > fixed.occupancy - 1e-9, "parking exists to keep batches full");
        for (id, job) in fixed.results.iter().enumerate() {
            assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "job {id}: parking or sizing changed the sample");
        }
    }

    #[test]
    fn starts_on_smallest_batch_that_fits() {
        // A 2-job queue on a [1, 4] family must run on b=4 only while it
        // needs to — and a 1-job queue must start (and stay) on b=1.
        let m4 = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let one = run_continuous_family(&family, Box::new(FpiReuse), vec![JobNoise::new(1, 0, m4.dim(), 3)]).unwrap();
        assert_eq!(one.min_batch, 1);
        assert_eq!(one.downshifts, 0, "initial sizing is not a migration");
        assert_eq!(one.occupancy, 1.0, "b=1 schedule must be fully occupied");
        let refs = reference_samples_small(2, 1, &m4);
        let two = run_continuous_family(&family, Box::new(FpiReuse), (0..2).map(|id| JobNoise::new(1, id, m4.dim(), 3)).collect()).unwrap();
        for (i, job) in two.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i}");
        }
    }

    #[test]
    fn handles_fewer_jobs_than_slots() {
        let m = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let rep = run_continuous(&m, Box::new(FpiReuse), 2, 1).unwrap();
        assert_eq!(rep.results.len(), 2);
        let refs = reference_samples_small(2, 1, &m);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    fn reference_samples_small(n: usize, seed: u64, m4: &MockArm) -> Vec<Vec<i32>> {
        let m1 = MockArm { batch: 1, ..m4.clone() };
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), m1.k));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }

    #[test]
    fn sizing_policy_extremes_reproduce_fill_and_fit_trajectories() {
        // The policy refactor must be a pure extraction: an SLO hybrid
        // with an infinite target is occupancy-first pass for pass, and
        // one with a zero target is latency-lean pass for pass — same
        // pass counts, same calls/job, same shifts, same samples.
        use crate::coordinator::policy::{LatencyLean, OccupancyFirst, SizingPolicy, SloHybrid, SloTarget};
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let (d, k) = (m4.dim(), 4);
        let n = 9;
        let run = |sizing: &dyn SizingPolicy| -> (ScheduleReport, Vec<Vec<i32>>) {
            let initial = live_jobs(0..3, 7, d, k);
            let bursts = vec![(2, live_jobs(3..n, 7, d, k))];
            let mut feed = TickBurstFeed::new(n, bursts);
            let rep = run_elastic_family_policy(&family, Box::new(FpiReuse), initial, &mut feed, sizing).unwrap();
            (rep, feed.results.into_iter().map(|r| r.expect("job completed").x).collect())
        };
        let (occ, occ_x) = run(&OccupancyFirst);
        let (fit, fit_x) = run(&LatencyLean);
        let (loose, loose_x) = run(&SloHybrid { target: SloTarget::Passes(1e12) });
        let (tight, tight_x) = run(&SloHybrid { target: SloTarget::Passes(0.0) });
        assert_eq!(occ_x, fit_x, "sizing policy must never change a sample");
        assert_eq!(occ_x, loose_x);
        assert_eq!(occ_x, tight_x);
        assert_eq!(occ.policy, "occupancy");
        assert_eq!(fit.policy, "latency");
        assert_eq!(loose.policy, "slo");
        for (a, b, what) in [(&loose, &occ, "loose-SLO vs occupancy"), (&tight, &fit, "tight-SLO vs latency")] {
            assert_eq!(a.total_passes, b.total_passes, "{what}: pass count");
            assert_eq!(a.upshifts, b.upshifts, "{what}: upshifts");
            assert_eq!(a.downshifts, b.downshifts, "{what}: downshifts");
            assert_eq!(a.min_batch, b.min_batch, "{what}: min_batch");
            assert!((a.calls_per_job - b.calls_per_job).abs() < 1e-9, "{what}: calls/job {} vs {}", a.calls_per_job, b.calls_per_job);
        }
        // The extremes genuinely differ on this trickle (occupancy parks
        // for full batches, fit seats everyone) — otherwise the test
        // proves nothing.
        assert!(occ.occupancy > fit.occupancy - 1e-9, "occupancy sizing exists to keep batches full");
        assert!(occ.calls_per_job <= fit.calls_per_job + 1e-9, "occupancy sizing must not spend more slot-passes than fit");
    }

    #[test]
    fn convergence_prior_seeds_schedule_ewmas_and_keeps_samples() {
        // The server-level estimator's contract at the scheduler layer: a
        // primed schedule must hand the sizing policy the prior's
        // passes-per-job / pass-seconds from the very first decision
        // (instead of None → the worst-case `d` fallback), and priming
        // must never change a sample.
        use std::cell::RefCell;
        #[derive(Default)]
        struct Probe {
            seen: RefCell<Vec<(Option<f64>, Option<f64>)>>,
        }
        impl SizingPolicy for Probe {
            fn name(&self) -> &'static str {
                "probe"
            }
            fn choose(&self, exports: &[usize], ctx: &SizingCtx) -> usize {
                self.seen.borrow_mut().push((ctx.passes_per_job, ctx.pass_secs));
                policy::fit_size(exports, ctx.need())
            }
        }
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let (d, k) = (m4.dim(), 4);
        let run = |prior: Option<ConvergencePrior>| -> (Vec<(Option<f64>, Option<f64>)>, Vec<Vec<i32>>) {
            let probe = Probe::default();
            // A burst after the first pass guarantees the schedule runs
            // multiple passes, so the EWMAs demonstrably move off the seed.
            let mut feed = TickBurstFeed::new(6, vec![(1, live_jobs(3..6, 13, d, k))]);
            run_elastic_family_primed(&family, Box::new(FpiReuse), live_jobs(0..3, 13, d, k), &mut feed, &probe, prior).unwrap();
            (probe.seen.into_inner(), feed.results.into_iter().map(|r| r.expect("job completed").x).collect())
        };
        let (cold_ctxs, cold_x) = run(None);
        assert_eq!(cold_ctxs[0], (None, None), "an unprimed schedule starts with cold EWMAs");
        let prior = ConvergencePrior { passes_per_job: 3.5, pass_secs: 0.25 };
        let (primed_ctxs, primed_x) = run(Some(prior));
        assert_eq!(primed_ctxs[0], (Some(3.5), Some(0.25)), "the prior must reach the policy's first decision");
        // The schedule's own observations take over: once a pass has run
        // (and a job completed), the EWMAs move off the exact seed.
        let last = *primed_ctxs.last().unwrap();
        assert!(last.1.is_some() && last.1 != Some(0.25), "pass-time observations must blend into the seeded EWMA");
        assert_eq!(primed_x, cold_x, "priming must never change a sample");
    }

    #[test]
    fn custom_sizing_policy_out_of_family_degrades_to_fit() {
        // A policy returning a batch size the family does not export must
        // degrade to the fit rule (round up), not panic.
        use crate::coordinator::policy::{SizingCtx, SizingPolicy};
        struct Wild;
        impl SizingPolicy for Wild {
            fn name(&self) -> &'static str {
                "wild"
            }
            fn choose(&self, _exports: &[usize], ctx: &SizingCtx) -> usize {
                ctx.need() * 3 + 1 // never an export
            }
        }
        let m4 = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let (d, k) = (m4.dim(), 3);
        let mut feed = TickBurstFeed::new(2, Vec::new());
        let rep = run_elastic_family_policy(&family, Box::new(FpiReuse), live_jobs(0..2, 1, d, k), &mut feed, &Wild).unwrap();
        assert_eq!(rep.policy, "wild");
        let refs = reference_samples_small(2, 1, &m4);
        for (id, r) in feed.results.iter().enumerate() {
            assert_eq!(r.as_ref().expect("job completed").x, refs[id], "job {id}");
        }
    }
}
