//! Continuous batching — the scheduling system the paper defers to future
//! work (§4.1: "We leave the implementation of a scheduling system to
//! future work, which would allow sampling at an average rate equal to the
//! batch size 1 setting").
//!
//! In synchronous batching the slowest image pins the whole batch: every
//! other slot idles (recomputes already-final values) until the straggler
//! converges. Here a converged slot is immediately refilled with the next
//! queued job, so the batch's occupancy — and per-job ARM-call cost —
//! approaches the batch-size-1 rate. Per-job noise is keyed by job id
//! (not slot), so results are bitwise identical to any other placement —
//! the refill tests rely on that invariant.
//!
//! Given a *family* of step models (one per exported batch size), the
//! scheduler also **down-shifts**: once the queue is dry and fewer jobs
//! remain in flight than the current batch, the survivors are migrated —
//! state and all, via [`PredictiveSampler::extract_slot`] — onto the
//! smallest exported batch that still fits, so a draining tail pays for
//! b=1 passes instead of b=B ones. Placement irrelevance (noise keyed by
//! job id) is what makes the migration provably exact.

use crate::sampler::forecast::Forecaster;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::PredictiveSampler;
use crate::sampler::{JobResult, StepModel};
use crate::substrate::timer::Timer;
use anyhow::{ensure, Result};

/// Outcome of scheduling `n_jobs` through a fixed-size batch engine.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// Per-job results in job-id order.
    pub results: Vec<JobResult>,
    /// Total ARM passes executed.
    pub total_passes: usize,
    /// Mean active slots per pass (≤ batch size).
    pub occupancy: f64,
    pub wall_secs: f64,
    /// ARM calls per job (slot-passes / n — the batched cost model —
    /// for comparison against the paper's batch-1 rate).
    pub calls_per_job: f64,
    /// Output rows the backends were asked for (log-prob positions +
    /// forecast-head rows), summed over passes — the hot-path bench's
    /// useful-work metric.
    pub positions_evaluated: usize,
    /// Times the schedule migrated to a smaller exported batch size.
    pub downshifts: usize,
    /// Smallest batch size the schedule executed on.
    pub min_batch: usize,
}

/// Per-job ARM calls as a percentage of the baseline's `d` calls — the
/// one normalization both the scheduler reports and the serving layer's
/// per-group responses use.
pub fn calls_pct_of(calls_per_job: f64, dim: usize) -> f64 {
    100.0 * calls_per_job / dim as f64
}

impl ScheduleReport {
    /// See [`calls_pct_of`].
    pub fn calls_pct(&self, dim: usize) -> f64 {
        calls_pct_of(self.calls_per_job, dim)
    }
}

/// Continuous batching: keep every slot busy by refilling converged slots
/// from the queue. Jobs `0..n_jobs` get noise keyed `(seed, job_id)`.
pub fn run_continuous<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    n_jobs: usize,
    seed: u64,
) -> Result<ScheduleReport> {
    let d = model.dim();
    let k = model.categories();
    let noises = (0..n_jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    run_continuous_noises(model, forecaster, noises)
}

/// Continuous batching over an explicit job queue (each job brings its own
/// noise block — used by the server to merge requests with different
/// seeds into one schedule).
pub fn run_continuous_noises<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family(&[model], forecaster, noises)
}

/// Continuous batching with **batch down-shifting** over a family of step
/// models for the same weights at different exported batch sizes. Starts
/// on the smallest batch that fits the queue and migrates surviving jobs
/// to smaller batches as the queue drains. Single-element families reduce
/// to plain continuous batching.
pub fn run_continuous_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family_mode(models, forecaster, noises, true)
}

/// As [`run_continuous_family`]; `use_plan = false` forces full-shape
/// passes (the pre-plan hot path, kept for `benches/sampler_hotpath.rs`).
pub fn run_continuous_family_mode<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
    use_plan: bool,
) -> Result<ScheduleReport> {
    ensure!(!models.is_empty(), "empty model family");
    // Batch sizes ascending. The family must be one model at different
    // exported batch sizes: migrating a job across different shapes would
    // corrupt its noise indexing, and across different weights would
    // silently break exactness. Shape agreement is checkable here
    // (t_fore may legitimately differ — logp-only variants export 0);
    // weight identity is the caller's contract.
    let mut order: Vec<usize> = (0..models.len()).collect();
    order.sort_by_key(|&i| models[i].batch());
    let shapes_agree = models
        .iter()
        .all(|m| m.dim() == models[0].dim() && m.categories() == models[0].categories() && m.pixels() == models[0].pixels());
    ensure!(shapes_agree, "model family mixes shapes");
    // A fore-reading policy migrates forecast-head blocks between family
    // members, so their head shapes must agree too — fail fast here
    // rather than panicking mid-schedule at the first downshift.
    let fores_agree = models.iter().all(|m| m.t_fore() == models[0].t_fore());
    ensure!(fores_agree || !forecaster.reads_fore(), "fore-reading policy over a family with mixed t_fore");
    // Smallest exported batch that fits `need` jobs (largest otherwise).
    let pick = |need: usize| -> usize { order.iter().copied().find(|&i| models[i].batch() >= need).unwrap_or(*order.last().unwrap()) };

    let n_jobs = noises.len();
    let timer = Timer::start();
    let mut results: Vec<Option<JobResult>> = (0..n_jobs).map(|_| None).collect();
    let mut queue = noises.into_iter().enumerate().collect::<std::collections::VecDeque<_>>();
    let mut cur = pick(n_jobs.max(1));
    let mut ps = PredictiveSampler::new(models[cur], forecaster);
    ps.set_plan_mode(use_plan);
    let mut slot_job: Vec<Option<usize>> = vec![None; models[cur].batch()];
    let mut completed = 0usize;
    let mut active_accum = 0usize;
    let mut capacity_accum = 0usize;
    let mut passes = 0usize;
    let mut positions = 0usize;
    let mut downshifts = 0usize;
    let mut min_batch = models[cur].batch();

    // Prime the slots.
    for (s, sj) in slot_job.iter_mut().enumerate() {
        if let Some((id, noise)) = queue.pop_front() {
            ps.reset_slot(s, noise);
            *sj = Some(id);
        }
    }

    while completed < n_jobs {
        let in_flight = slot_job.iter().filter(|j| j.is_some()).count();
        // Down-shift: queue dry and a smaller exported batch fits the
        // survivors. Carries each job's full mid-flight state, so the
        // migration costs no extra passes and changes no samples.
        if queue.is_empty() && in_flight > 0 {
            let target = pick(in_flight);
            if models[target].batch() < models[cur].batch() {
                downshifts += 1;
                positions += ps.positions_evaluated;
                let mut moved = Vec::with_capacity(in_flight);
                for (s, sj) in slot_job.iter_mut().enumerate() {
                    if let Some(job) = sj.take() {
                        moved.push((job, ps.extract_slot(s).expect("in-flight slot")));
                    }
                }
                let fc = ps.into_forecaster();
                cur = target;
                min_batch = min_batch.min(models[cur].batch());
                ps = PredictiveSampler::new(models[cur], fc);
                ps.set_plan_mode(use_plan);
                slot_job = vec![None; models[cur].batch()];
                for (s, (job, st)) in moved.into_iter().enumerate() {
                    ps.install_slot(s, st);
                    slot_job[s] = Some(job);
                }
            }
        }
        active_accum += in_flight;
        capacity_accum += models[cur].batch();
        ps.step()?;
        passes += 1;
        for (s, sj) in slot_job.iter_mut().enumerate() {
            if sj.is_some() && ps.slot_done(s) {
                let job = sj.take().unwrap();
                results[job] = Some(ps.take_result(s).expect("done slot"));
                completed += 1;
                if let Some((id, noise)) = queue.pop_front() {
                    ps.reset_slot(s, noise);
                    *sj = Some(id);
                }
            }
        }
    }
    positions += ps.positions_evaluated;

    let results: Vec<JobResult> = results.into_iter().map(|r| r.expect("all jobs complete")).collect();
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / capacity_accum.max(1) as f64,
        wall_secs: timer.secs(),
        calls_per_job: capacity_accum as f64 / n_jobs as f64,
        results,
        positions_evaluated: positions,
        downshifts,
        min_batch,
    })
}

/// Synchronous batching baseline: process jobs in batch-size chunks; each
/// chunk runs until its slowest job converges (the paper's Table-1/2
/// semantics, extended to a queue of jobs). One sampler — and its `[B*d]`
/// input and step-output buffers — is built once and reset between chunks
/// instead of reallocated per chunk.
pub fn run_sync_chunks<M: StepModel>(model: &M, forecaster: Box<dyn Forecaster>, n_jobs: usize, seed: u64) -> Result<ScheduleReport> {
    let b = model.batch();
    let d = model.dim();
    let k = model.categories();
    let timer = Timer::start();
    let mut ps = PredictiveSampler::new(model, forecaster);
    let mut results: Vec<JobResult> = Vec::with_capacity(n_jobs);
    let mut passes = 0usize;
    let mut active_accum = 0usize;
    let mut start = 0usize;
    while start < n_jobs {
        let chunk = (n_jobs - start).min(b);
        for s in 0..chunk {
            ps.reset_slot(s, JobNoise::new(seed, (start + s) as u64, d, k));
        }
        for s in chunk..b {
            ps.clear_slot(s);
        }
        while (0..chunk).any(|s| !ps.slot_done(s)) {
            active_accum += (0..chunk).filter(|&s| !ps.slot_done(s)).count();
            ps.step()?;
            passes += 1;
        }
        for s in 0..chunk {
            results.push(ps.take_result(s).expect("chunk job done"));
        }
        start += chunk;
    }
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / (passes.max(1) * b) as f64,
        wall_secs: timer.secs(),
        calls_per_job: passes as f64 * b as f64 / n_jobs as f64,
        results,
        positions_evaluated: ps.positions_evaluated,
        downshifts: 0,
        min_batch: b,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::forecast::FpiReuse;
    use crate::sampler::mock::MockArm;
    use crate::sampler::noise::JobNoise;
    use crate::sampler::predictive::PredictiveSampler;

    fn reference_samples(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let m1 = MockArm::new(1, 3, 6, 4, 2, 2.5, 21);
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), 4));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }

    #[test]
    fn continuous_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_continuous(&m, Box::new(FpiReuse), 11, 3).unwrap();
        assert_eq!(rep.results.len(), 11);
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i} sample changed under scheduling");
        }
        assert!(rep.occupancy > 0.0 && rep.occupancy <= 1.0);
    }

    #[test]
    fn sync_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_sync_chunks(&m, Box::new(FpiReuse), 11, 3).unwrap();
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    #[test]
    fn continuous_at_least_as_efficient() {
        // With heterogeneous convergence, slot refill can only reduce the
        // number of passes needed for a queue of jobs.
        let m = MockArm::new(4, 3, 8, 5, 2, 3.0, 33);
        let cont = run_continuous(&m, Box::new(FpiReuse), 16, 9).unwrap();
        let sync = run_sync_chunks(&m, Box::new(FpiReuse), 16, 9).unwrap();
        assert!(
            cont.total_passes <= sync.total_passes,
            "continuous {} > sync {}",
            cont.total_passes,
            sync.total_passes
        );
        assert!(cont.occupancy >= sync.occupancy - 1e-9);
    }

    #[test]
    fn occupancy_and_calls_per_job_stay_bounded() {
        // Property: as jobs drain, occupancy stays in [1/B, 1] (every pass
        // has at least one active slot, at most B) and calls_per_job stays
        // in [1, B*d] (every job needs >= 1 pass; no job survives more
        // than d passes). The identity occupancy * passes * B = total
        // job-iterations ties the two together.
        use crate::substrate::proptest_lite::check;
        check("scheduler-bounds", 16, |g| {
            let b = g.usize_in(1, 7);
            let m = MockArm::new(b, g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6), 1, g.f64_in(0.0, 4.0) as f32, g.rng.next_u64());
            let n = g.usize_in(1, 20);
            let rep = run_continuous(&m, Box::new(FpiReuse), n, g.rng.next_u64()).map_err(|e| e.to_string())?;
            let (bf, d) = (b as f64, m.dim() as f64);
            crate::prop_assert!(
                rep.occupancy >= 1.0 / bf - 1e-9 && rep.occupancy <= 1.0 + 1e-9,
                "occupancy {} outside [1/{b}, 1] (n={n})",
                rep.occupancy
            );
            crate::prop_assert!(rep.calls_per_job >= 1.0 - 1e-9, "calls_per_job {} < 1", rep.calls_per_job);
            crate::prop_assert!(rep.calls_per_job <= bf * d + 1e-9, "calls_per_job {} > B*d = {}", rep.calls_per_job, bf * d);
            let iterations = rep.occupancy * rep.total_passes as f64 * bf;
            crate::prop_assert!(iterations >= n as f64 - 1e-6, "total iterations {iterations} < n={n}");
            let pct = rep.calls_pct(m.dim());
            crate::prop_assert!((pct - 100.0 * rep.calls_per_job / d).abs() < 1e-9, "calls_pct helper disagrees");
            Ok(())
        });
    }

    #[test]
    fn queue_drain_downshifts_to_smaller_batches_bitwise() {
        // THE down-shifting acceptance gate: a queue draining through a
        // [b=1, b=2, b=4] family must migrate the surviving jobs onto
        // smaller executables — reaching b=1 for the straggler — while
        // every per-job sample stays bitwise identical to the fixed-batch
        // (and batch-1) references. Several seeds are scheduled so the
        // drain tail is exercised in different shapes; a straggler tail
        // that reaches batch 1 must occur.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m2 = MockArm { batch: 2, ..m4.clone() };
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let mut saw_b1 = false;
        for seed in 0..8u64 {
            let n = 9;
            let noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, m4.dim(), 4)).collect();
            let rep = run_continuous_family(&family, Box::new(FpiReuse), noises).unwrap();
            let fixed = run_continuous(&m4, Box::new(FpiReuse), n, seed).unwrap();
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, fixed.results[i].x, "seed {seed} job {i}: down-shifting changed the sample");
            }
            let refs = reference_samples(n, seed);
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, refs[i], "seed {seed} job {i}: family schedule diverged from batch-1 reference");
            }
            // Down-shifting can only shed slot-passes.
            assert!(
                rep.calls_per_job <= fixed.calls_per_job + 1e-9,
                "seed {seed}: down-shifted calls/job {} > fixed {}",
                rep.calls_per_job,
                fixed.calls_per_job
            );
            assert!(rep.min_batch < 4 || rep.downshifts == 0, "min_batch must track migrations");
            saw_b1 |= rep.min_batch == 1;
        }
        assert!(saw_b1, "no schedule drained to the b=1 executable — straggler tails must down-shift");
    }

    #[test]
    fn starts_on_smallest_batch_that_fits() {
        // A 2-job queue on a [1, 4] family must run on b=4 only while it
        // needs to — and a 1-job queue must start (and stay) on b=1.
        let m4 = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let one = run_continuous_family(&family, Box::new(FpiReuse), vec![JobNoise::new(1, 0, m4.dim(), 3)]).unwrap();
        assert_eq!(one.min_batch, 1);
        assert_eq!(one.downshifts, 0, "initial sizing is not a migration");
        assert_eq!(one.occupancy, 1.0, "b=1 schedule must be fully occupied");
        let refs = reference_samples_small(2, 1, &m4);
        let two = run_continuous_family(&family, Box::new(FpiReuse), (0..2).map(|id| JobNoise::new(1, id, m4.dim(), 3)).collect()).unwrap();
        for (i, job) in two.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i}");
        }
    }

    #[test]
    fn handles_fewer_jobs_than_slots() {
        let m = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let rep = run_continuous(&m, Box::new(FpiReuse), 2, 1).unwrap();
        assert_eq!(rep.results.len(), 2);
        let refs = reference_samples_small(2, 1, &m);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    fn reference_samples_small(n: usize, seed: u64, m4: &MockArm) -> Vec<Vec<i32>> {
        let m1 = MockArm { batch: 1, ..m4.clone() };
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), m1.k));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }
}
