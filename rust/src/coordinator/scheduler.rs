//! Continuous batching — the scheduling system the paper defers to future
//! work (§4.1: "We leave the implementation of a scheduling system to
//! future work, which would allow sampling at an average rate equal to the
//! batch size 1 setting").
//!
//! In synchronous batching the slowest image pins the whole batch: every
//! other slot idles (recomputes already-final values) until the straggler
//! converges. Here a converged slot is immediately refilled with the next
//! queued job, so the batch's occupancy — and per-job ARM-call cost —
//! approaches the batch-size-1 rate. Per-job noise is keyed by job id
//! (not slot), so results are bitwise identical to any other placement —
//! the refill tests rely on that invariant.
//!
//! Given a *family* of step models (one per exported batch size), the
//! schedule is **elastic** in both directions. Down-shift: once the queue
//! is dry and fewer jobs remain in flight than the current batch, the
//! survivors are migrated — state and all, via
//! [`PredictiveSampler::extract_slot`] — onto the smallest exported batch
//! that still fits, so a draining tail pays for b=1 passes instead of b=B
//! ones. Up-shift: jobs can keep *arriving* while the schedule runs (a
//! [`JobFeed`] is polled between passes), and when the live queue deepens
//! past the current batch the in-flight slots migrate onto the next
//! larger exported batch and the queued jobs are admitted into the freed
//! capacity. Placement irrelevance (noise keyed by job id) is what makes
//! both migrations provably exact.

use crate::sampler::forecast::Forecaster;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::{PredictiveSampler, SlotState};
use crate::sampler::{JobResult, StepModel};
use crate::substrate::timer::Timer;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// Outcome of scheduling `n_jobs` through a fixed-size batch engine.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// Per-job results in job-id order.
    pub results: Vec<JobResult>,
    /// Total ARM passes executed.
    pub total_passes: usize,
    /// Mean active slots per pass (≤ batch size).
    pub occupancy: f64,
    pub wall_secs: f64,
    /// ARM calls per job (slot-passes / n — the batched cost model —
    /// for comparison against the paper's batch-1 rate).
    pub calls_per_job: f64,
    /// Output rows the backends were asked for (log-prob positions +
    /// forecast-head rows), summed over passes — the hot-path bench's
    /// useful-work metric.
    pub positions_evaluated: usize,
    /// Times the schedule migrated to a smaller exported batch size.
    pub downshifts: usize,
    /// Times the schedule migrated to a larger exported batch size (a
    /// live queue deepened past the current batch mid-schedule).
    pub upshifts: usize,
    /// Smallest batch size the schedule executed on.
    pub min_batch: usize,
}

/// A job admitted to a live schedule: its noise block plus an opaque tag
/// the feed uses to route the completed result (the serving layer packs a
/// request id and per-request job index into it).
pub struct LiveJob {
    pub tag: u64,
    pub noise: JobNoise,
}

/// Mid-schedule counters handed to [`JobFeed::complete`] — enough for the
/// serving layer to answer a request the moment its last job finishes
/// instead of waiting for the whole schedule to end.
#[derive(Clone, Copy, Debug)]
pub struct LiveStats {
    /// ARM passes executed so far.
    pub passes: usize,
    /// Slot-passes (Σ batch over passes) accumulated so far.
    pub slot_passes: usize,
    /// Jobs completed so far (including the one being delivered).
    pub completed: usize,
    pub upshifts: usize,
    pub downshifts: usize,
}

/// Live job source for an elastic schedule. The scheduler polls it
/// between passes, so jobs can be appended while the schedule runs; the
/// schedule ends when the feed is dry and every admitted job converged.
pub trait JobFeed {
    /// Non-blocking poll for newly arrived jobs.
    fn poll(&mut self) -> Vec<LiveJob>;
    /// A job converged; called in completion order, mid-schedule.
    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats);
}

/// The closed feed: nothing arrives; results are collected by tag (which
/// [`run_continuous_family_mode`] assigns as the job's queue index).
struct CollectFeed {
    results: Vec<Option<JobResult>>,
}

impl JobFeed for CollectFeed {
    fn poll(&mut self) -> Vec<LiveJob> {
        Vec::new()
    }
    fn complete(&mut self, tag: u64, result: JobResult, _stats: &LiveStats) {
        self.results[tag as usize] = Some(result);
    }
}

/// Deterministic replay feed: releases each burst once the schedule has
/// polled `tick` times (the scheduler polls once per pass, so ticks are
/// pass counts) and collects results by tag, which must index `0..n`.
/// Bursts must be sorted by tick. This is how tests and benches drive
/// reproducible live-arrival scenarios without threads or clocks.
pub struct TickBurstFeed {
    bursts: VecDeque<(usize, Vec<LiveJob>)>,
    polls: usize,
    pub results: Vec<Option<JobResult>>,
    /// Stats snapshot delivered with each completion, in order.
    pub completions: Vec<LiveStats>,
}

impl TickBurstFeed {
    pub fn new(n_jobs: usize, bursts: Vec<(usize, Vec<LiveJob>)>) -> TickBurstFeed {
        debug_assert!(bursts.windows(2).all(|w| w[0].0 <= w[1].0), "bursts must be sorted by tick");
        TickBurstFeed { bursts: bursts.into(), polls: 0, results: (0..n_jobs).map(|_| None).collect(), completions: Vec::new() }
    }
}

impl JobFeed for TickBurstFeed {
    fn poll(&mut self) -> Vec<LiveJob> {
        let t = self.polls;
        self.polls += 1;
        let mut out = Vec::new();
        while self.bursts.front().is_some_and(|(at, _)| *at <= t) {
            out.extend(self.bursts.pop_front().expect("non-empty").1);
        }
        out
    }
    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats) {
        self.results[tag as usize] = Some(result);
        self.completions.push(*stats);
    }
}

/// Per-job ARM calls as a percentage of the baseline's `d` calls — the
/// one normalization both the scheduler reports and the serving layer's
/// per-group responses use.
pub fn calls_pct_of(calls_per_job: f64, dim: usize) -> f64 {
    100.0 * calls_per_job / dim as f64
}

impl ScheduleReport {
    /// See [`calls_pct_of`].
    pub fn calls_pct(&self, dim: usize) -> f64 {
        calls_pct_of(self.calls_per_job, dim)
    }
}

/// Continuous batching: keep every slot busy by refilling converged slots
/// from the queue. Jobs `0..n_jobs` get noise keyed `(seed, job_id)`.
pub fn run_continuous<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    n_jobs: usize,
    seed: u64,
) -> Result<ScheduleReport> {
    let d = model.dim();
    let k = model.categories();
    let noises = (0..n_jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    run_continuous_noises(model, forecaster, noises)
}

/// Continuous batching over an explicit job queue (each job brings its own
/// noise block — used by the server to merge requests with different
/// seeds into one schedule).
pub fn run_continuous_noises<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family(&[model], forecaster, noises)
}

/// Continuous batching with **batch down-shifting** over a family of step
/// models for the same weights at different exported batch sizes. Starts
/// on the smallest batch that fits the queue and migrates surviving jobs
/// to smaller batches as the queue drains. Single-element families reduce
/// to plain continuous batching.
pub fn run_continuous_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    run_continuous_family_mode(models, forecaster, noises, true)
}

/// As [`run_continuous_family`]; `use_plan = false` forces full-shape
/// passes (the pre-plan hot path, kept for `benches/sampler_hotpath.rs`).
pub fn run_continuous_family_mode<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
    use_plan: bool,
) -> Result<ScheduleReport> {
    let initial: Vec<LiveJob> = noises.into_iter().enumerate().map(|(id, noise)| LiveJob { tag: id as u64, noise }).collect();
    let mut feed = CollectFeed { results: (0..initial.len()).map(|_| None).collect() };
    let mut rep = schedule_family(models, forecaster, initial, &mut feed, use_plan, false)?;
    rep.results = feed.results.into_iter().map(|r| r.expect("all jobs complete")).collect();
    Ok(rep)
}

/// Elastic continuous batching over a **live** queue: `initial` jobs plus
/// whatever `feed` delivers while the schedule runs. Results are handed
/// to [`JobFeed::complete`] as they converge (the returned report's
/// `results` is empty). The schedule up-shifts when the live queue
/// outgrows the current batch and down-shifts as it drains; both
/// directions migrate in-flight slots state-and-all, so every sample is
/// bitwise identical to the same job scheduled any other way.
///
/// Unlike the closed-queue scheduler (which sizes for latency: the
/// smallest exported batch that fits *everything*, even half-empty), the
/// live scheduler sizes for **occupancy**: the largest exported batch the
/// runnable jobs can completely fill, **parking** any excess in-flight
/// slots (state and all) to resume ahead of fresh admissions. Every pass
/// therefore runs a full batch, which is exactly the paper's §4.1 target
/// of batched sampling at the batch-size-1 ARM-call rate.
pub fn run_elastic_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
) -> Result<ScheduleReport> {
    schedule_family(models, forecaster, initial, feed, true, true)
}

/// The one scheduling loop under every batching mode. `occupancy_sizing`
/// selects the resize policy: `false` = the closed-queue rule (smallest
/// export ≥ runnable jobs; never parks), `true` = the live elastic rule
/// (largest export the runnable jobs fill; excess in-flight slots park).
fn schedule_family<M: StepModel>(
    models: &[&M],
    forecaster: Box<dyn Forecaster>,
    initial: Vec<LiveJob>,
    feed: &mut dyn JobFeed,
    use_plan: bool,
    occupancy_sizing: bool,
) -> Result<ScheduleReport> {
    ensure!(!models.is_empty(), "empty model family");
    // Batch sizes ascending. The family must be one model at different
    // exported batch sizes: migrating a job across different shapes would
    // corrupt its noise indexing, and across different weights would
    // silently break exactness. Shape agreement is checkable here
    // (t_fore may legitimately differ — logp-only variants export 0);
    // weight identity is the caller's contract.
    let mut order: Vec<usize> = (0..models.len()).collect();
    order.sort_by_key(|&i| models[i].batch());
    let shapes_agree = models
        .iter()
        .all(|m| m.dim() == models[0].dim() && m.categories() == models[0].categories() && m.pixels() == models[0].pixels());
    ensure!(shapes_agree, "model family mixes shapes");
    // A fore-reading policy migrates forecast-head blocks between family
    // members, so their head shapes must agree too — fail fast here
    // rather than panicking mid-schedule at the first downshift.
    let fores_agree = models.iter().all(|m| m.t_fore() == models[0].t_fore());
    ensure!(fores_agree || !forecaster.reads_fore(), "fore-reading policy over a family with mixed t_fore");
    // Two sizing rules over the ascending exports. `fit`: smallest batch
    // that holds `need` jobs (largest otherwise) — the closed-queue rule,
    // which favors tail latency by keeping every runnable job in a slot.
    // `fill`: largest batch `need` jobs can completely occupy — the live
    // rule, which favors the batched ARM-call rate and parks the excess.
    let fit = |need: usize| -> usize { order.iter().copied().find(|&i| models[i].batch() >= need).unwrap_or(*order.last().unwrap()) };
    let fill = |need: usize| -> usize { order.iter().copied().filter(|&i| models[i].batch() <= need).last().unwrap_or(order[0]) };
    let choose = |need: usize| -> usize {
        if occupancy_sizing {
            fill(need.max(1))
        } else {
            fit(need.max(1))
        }
    };

    let timer = Timer::start();
    let mut queue: VecDeque<LiveJob> = initial.into();
    // Mid-flight jobs lifted out when the batch shrinks below the
    // in-flight count (occupancy sizing only); resumed, oldest first,
    // ahead of fresh admissions.
    let mut parked: VecDeque<(u64, SlotState)> = VecDeque::new();
    let mut cur = choose(queue.len());
    let mut ps = PredictiveSampler::new(models[cur], forecaster);
    ps.set_plan_mode(use_plan);
    let mut slot_job: Vec<Option<u64>> = vec![None; models[cur].batch()];
    let mut completed = 0usize;
    let mut active_accum = 0usize;
    let mut capacity_accum = 0usize;
    let mut passes = 0usize;
    let mut positions = 0usize;
    let mut downshifts = 0usize;
    let mut upshifts = 0usize;
    let mut min_batch = models[cur].batch();

    loop {
        // Merge live arrivals before deciding whether anything is left.
        queue.extend(feed.poll());
        let in_flight = slot_job.iter().filter(|j| j.is_some()).count();
        let runnable = in_flight + parked.len() + queue.len();
        if runnable == 0 {
            break;
        }
        // Elastic resize. Larger than the current batch (the live queue
        // deepened) => up-shift; smaller (the queue drained) =>
        // down-shift. Both carry each job's full mid-flight state —
        // migrated or parked — so no pass repeats and no sample changes.
        let target = choose(runnable);
        if models[target].batch() != models[cur].batch() {
            if models[target].batch() > models[cur].batch() {
                upshifts += 1;
            } else {
                downshifts += 1;
            }
            positions += ps.positions_evaluated;
            let mut moved = Vec::with_capacity(in_flight);
            for (s, sj) in slot_job.iter_mut().enumerate() {
                if let Some(job) = sj.take() {
                    moved.push((job, ps.extract_slot(s).expect("in-flight slot")));
                }
            }
            let fc = ps.into_forecaster();
            cur = target;
            min_batch = min_batch.min(models[cur].batch());
            ps = PredictiveSampler::new(models[cur], fc);
            ps.set_plan_mode(use_plan);
            slot_job = vec![None; models[cur].batch()];
            let batch = models[cur].batch();
            for (s, (job, st)) in moved.drain(..batch.min(moved.len())).enumerate() {
                ps.install_slot(s, st);
                slot_job[s] = Some(job);
            }
            // A shrink below the in-flight count parks the rest (FIFO by
            // park time behind anything already parked).
            parked.extend(moved);
        }
        // Fill every free slot: parked jobs resume first, then fresh
        // admissions from the queue.
        for (s, sj) in slot_job.iter_mut().enumerate() {
            if sj.is_none() {
                if let Some((job, st)) = parked.pop_front() {
                    ps.install_slot(s, st);
                    *sj = Some(job);
                } else if let Some(job) = queue.pop_front() {
                    let got = ps.admit(job.noise).expect("free slot");
                    debug_assert_eq!(got, s);
                    *sj = Some(job.tag);
                }
            }
        }
        active_accum += slot_job.iter().filter(|j| j.is_some()).count();
        capacity_accum += models[cur].batch();
        ps.step()?;
        passes += 1;
        for (s, sj) in slot_job.iter_mut().enumerate() {
            if sj.is_some() && ps.slot_done(s) {
                let tag = sj.take().unwrap();
                completed += 1;
                let stats = LiveStats { passes, slot_passes: capacity_accum, completed, upshifts, downshifts };
                feed.complete(tag, ps.take_result(s).expect("done slot"), &stats);
            }
        }
    }
    positions += ps.positions_evaluated;

    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / capacity_accum.max(1) as f64,
        wall_secs: timer.secs(),
        calls_per_job: capacity_accum as f64 / completed.max(1) as f64,
        results: Vec::new(),
        positions_evaluated: positions,
        downshifts,
        upshifts,
        min_batch,
    })
}

/// Synchronous batching baseline: process jobs in batch-size chunks; each
/// chunk runs until its slowest job converges (the paper's Table-1/2
/// semantics, extended to a queue of jobs). One sampler — and its `[B*d]`
/// input and step-output buffers — is built once and reset between chunks
/// instead of reallocated per chunk.
pub fn run_sync_chunks<M: StepModel>(model: &M, forecaster: Box<dyn Forecaster>, n_jobs: usize, seed: u64) -> Result<ScheduleReport> {
    let b = model.batch();
    let d = model.dim();
    let k = model.categories();
    let timer = Timer::start();
    let mut ps = PredictiveSampler::new(model, forecaster);
    let mut results: Vec<JobResult> = Vec::with_capacity(n_jobs);
    let mut passes = 0usize;
    let mut active_accum = 0usize;
    let mut start = 0usize;
    while start < n_jobs {
        let chunk = (n_jobs - start).min(b);
        for s in 0..chunk {
            ps.reset_slot(s, JobNoise::new(seed, (start + s) as u64, d, k));
        }
        for s in chunk..b {
            ps.clear_slot(s);
        }
        while (0..chunk).any(|s| !ps.slot_done(s)) {
            active_accum += (0..chunk).filter(|&s| !ps.slot_done(s)).count();
            ps.step()?;
            passes += 1;
        }
        for s in 0..chunk {
            results.push(ps.take_result(s).expect("chunk job done"));
        }
        start += chunk;
    }
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / (passes.max(1) * b) as f64,
        wall_secs: timer.secs(),
        calls_per_job: passes as f64 * b as f64 / n_jobs as f64,
        results,
        positions_evaluated: ps.positions_evaluated,
        downshifts: 0,
        upshifts: 0,
        min_batch: b,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::forecast::FpiReuse;
    use crate::sampler::mock::MockArm;
    use crate::sampler::noise::JobNoise;
    use crate::sampler::predictive::PredictiveSampler;

    fn reference_samples(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let m1 = MockArm::new(1, 3, 6, 4, 2, 2.5, 21);
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), 4));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }

    #[test]
    fn continuous_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_continuous(&m, Box::new(FpiReuse), 11, 3).unwrap();
        assert_eq!(rep.results.len(), 11);
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i} sample changed under scheduling");
        }
        assert!(rep.occupancy > 0.0 && rep.occupancy <= 1.0);
    }

    #[test]
    fn sync_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_sync_chunks(&m, Box::new(FpiReuse), 11, 3).unwrap();
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    #[test]
    fn continuous_at_least_as_efficient() {
        // With heterogeneous convergence, slot refill can only reduce the
        // number of passes needed for a queue of jobs.
        let m = MockArm::new(4, 3, 8, 5, 2, 3.0, 33);
        let cont = run_continuous(&m, Box::new(FpiReuse), 16, 9).unwrap();
        let sync = run_sync_chunks(&m, Box::new(FpiReuse), 16, 9).unwrap();
        assert!(
            cont.total_passes <= sync.total_passes,
            "continuous {} > sync {}",
            cont.total_passes,
            sync.total_passes
        );
        assert!(cont.occupancy >= sync.occupancy - 1e-9);
    }

    #[test]
    fn occupancy_and_calls_per_job_stay_bounded() {
        // Property: as jobs drain, occupancy stays in [1/B, 1] (every pass
        // has at least one active slot, at most B) and calls_per_job stays
        // in [1, B*d] (every job needs >= 1 pass; no job survives more
        // than d passes). The identity occupancy * passes * B = total
        // job-iterations ties the two together.
        use crate::substrate::proptest_lite::check;
        check("scheduler-bounds", 16, |g| {
            let b = g.usize_in(1, 7);
            let m = MockArm::new(b, g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6), 1, g.f64_in(0.0, 4.0) as f32, g.rng.next_u64());
            let n = g.usize_in(1, 20);
            let rep = run_continuous(&m, Box::new(FpiReuse), n, g.rng.next_u64()).map_err(|e| e.to_string())?;
            let (bf, d) = (b as f64, m.dim() as f64);
            crate::prop_assert!(
                rep.occupancy >= 1.0 / bf - 1e-9 && rep.occupancy <= 1.0 + 1e-9,
                "occupancy {} outside [1/{b}, 1] (n={n})",
                rep.occupancy
            );
            crate::prop_assert!(rep.calls_per_job >= 1.0 - 1e-9, "calls_per_job {} < 1", rep.calls_per_job);
            crate::prop_assert!(rep.calls_per_job <= bf * d + 1e-9, "calls_per_job {} > B*d = {}", rep.calls_per_job, bf * d);
            let iterations = rep.occupancy * rep.total_passes as f64 * bf;
            crate::prop_assert!(iterations >= n as f64 - 1e-6, "total iterations {iterations} < n={n}");
            let pct = rep.calls_pct(m.dim());
            crate::prop_assert!((pct - 100.0 * rep.calls_per_job / d).abs() < 1e-9, "calls_pct helper disagrees");
            Ok(())
        });
    }

    #[test]
    fn queue_drain_downshifts_to_smaller_batches_bitwise() {
        // THE down-shifting acceptance gate: a queue draining through a
        // [b=1, b=2, b=4] family must migrate the surviving jobs onto
        // smaller executables — reaching b=1 for the straggler — while
        // every per-job sample stays bitwise identical to the fixed-batch
        // (and batch-1) references. Several seeds are scheduled so the
        // drain tail is exercised in different shapes; a straggler tail
        // that reaches batch 1 must occur.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m2 = MockArm { batch: 2, ..m4.clone() };
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let mut saw_b1 = false;
        for seed in 0..8u64 {
            let n = 9;
            let noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, m4.dim(), 4)).collect();
            let rep = run_continuous_family(&family, Box::new(FpiReuse), noises).unwrap();
            let fixed = run_continuous(&m4, Box::new(FpiReuse), n, seed).unwrap();
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, fixed.results[i].x, "seed {seed} job {i}: down-shifting changed the sample");
            }
            let refs = reference_samples(n, seed);
            for (i, job) in rep.results.iter().enumerate() {
                assert_eq!(job.x, refs[i], "seed {seed} job {i}: family schedule diverged from batch-1 reference");
            }
            // Down-shifting can only shed slot-passes.
            assert!(
                rep.calls_per_job <= fixed.calls_per_job + 1e-9,
                "seed {seed}: down-shifted calls/job {} > fixed {}",
                rep.calls_per_job,
                fixed.calls_per_job
            );
            assert!(rep.min_batch < 4 || rep.downshifts == 0, "min_batch must track migrations");
            saw_b1 |= rep.min_batch == 1;
        }
        assert!(saw_b1, "no schedule drained to the b=1 executable — straggler tails must down-shift");
    }

    fn live_jobs(ids: std::ops::Range<usize>, seed: u64, d: usize, k: usize) -> Vec<LiveJob> {
        ids.map(|id| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) }).collect()
    }

    #[test]
    fn live_arrivals_upshift_and_stay_bitwise() {
        // THE up-shifting acceptance gate: a schedule that starts with one
        // job on the b=1 executable and sees the queue deepen mid-flight
        // must migrate onto larger exported batches — and every sample
        // must stay bitwise identical to the batch-1 reference and to the
        // same jobs scheduled all-at-once.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m2 = MockArm { batch: 2, ..m4.clone() };
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m2, &m4];
        let (d, k) = (m4.dim(), 4);
        let mut saw_upshift = false;
        for seed in 0..6u64 {
            let n = 9;
            let initial = live_jobs(0..1, seed, d, k);
            let bursts = vec![(1, live_jobs(1..4, seed, d, k)), (3, live_jobs(4..n, seed, d, k))];
            let mut feed = TickBurstFeed::new(n, bursts);
            let rep = run_elastic_family(&family, Box::new(FpiReuse), initial, &mut feed).unwrap();
            let refs = reference_samples(n, seed);
            for (id, r) in feed.results.iter().enumerate() {
                let r = r.as_ref().expect("job completed");
                assert_eq!(r.x, refs[id], "seed {seed} job {id}: up-shifting changed the sample");
            }
            let all_noises: Vec<JobNoise> = (0..n).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
            let all_at_once = run_continuous_family(&family, Box::new(FpiReuse), all_noises).unwrap();
            for (id, job) in all_at_once.results.iter().enumerate() {
                assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "seed {seed} job {id}: live arrival order changed the sample");
            }
            assert_eq!(feed.completions.len(), n, "every completion must be delivered through the feed");
            assert!(feed.completions.windows(2).all(|w| w[0].completed < w[1].completed), "completion stats must be monotone");
            saw_upshift |= rep.upshifts > 0;
            // A grown-then-drained queue must also shed batch again.
            assert!(rep.upshifts == 0 || rep.min_batch <= 2 || rep.downshifts > 0, "seed {seed}: grown schedule never downshifted");
        }
        assert!(saw_upshift, "queue deepening never up-shifted the batch");
    }

    #[test]
    fn elastic_closed_queue_stays_exact_and_sheds_waste() {
        // A dry feed degenerates the elastic scheduler to a closed queue:
        // samples must stay bitwise identical to the latency-sized
        // continuous schedule, the batch never grows (nothing arrives),
        // and occupancy sizing (fill the largest export, park the rest)
        // must spend no more slot-passes per job than fit sizing does.
        let m4 = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let (d, k) = (m4.dim(), 4);
        let n = 7;
        let mut feed = TickBurstFeed::new(n, Vec::new());
        let rep = run_elastic_family(&family, Box::new(FpiReuse), live_jobs(0..n, 5, d, k), &mut feed).unwrap();
        let fixed = run_continuous_family(&family, Box::new(FpiReuse), (0..n).map(|id| JobNoise::new(5, id as u64, d, k)).collect()).unwrap();
        assert_eq!(rep.upshifts, 0, "nothing arrived, nothing to grow for");
        assert!(
            rep.calls_per_job <= fixed.calls_per_job + 1e-9,
            "occupancy sizing must not waste slot-passes: elastic {} vs fit {}",
            rep.calls_per_job,
            fixed.calls_per_job
        );
        assert!(rep.occupancy > fixed.occupancy - 1e-9, "parking exists to keep batches full");
        for (id, job) in fixed.results.iter().enumerate() {
            assert_eq!(feed.results[id].as_ref().unwrap().x, job.x, "job {id}: parking or sizing changed the sample");
        }
    }

    #[test]
    fn starts_on_smallest_batch_that_fits() {
        // A 2-job queue on a [1, 4] family must run on b=4 only while it
        // needs to — and a 1-job queue must start (and stay) on b=1.
        let m4 = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let m1 = MockArm { batch: 1, ..m4.clone() };
        let family: Vec<&MockArm> = vec![&m1, &m4];
        let one = run_continuous_family(&family, Box::new(FpiReuse), vec![JobNoise::new(1, 0, m4.dim(), 3)]).unwrap();
        assert_eq!(one.min_batch, 1);
        assert_eq!(one.downshifts, 0, "initial sizing is not a migration");
        assert_eq!(one.occupancy, 1.0, "b=1 schedule must be fully occupied");
        let refs = reference_samples_small(2, 1, &m4);
        let two = run_continuous_family(&family, Box::new(FpiReuse), (0..2).map(|id| JobNoise::new(1, id, m4.dim(), 3)).collect()).unwrap();
        for (i, job) in two.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i}");
        }
    }

    #[test]
    fn handles_fewer_jobs_than_slots() {
        let m = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let rep = run_continuous(&m, Box::new(FpiReuse), 2, 1).unwrap();
        assert_eq!(rep.results.len(), 2);
        let refs = reference_samples_small(2, 1, &m);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    fn reference_samples_small(n: usize, seed: u64, m4: &MockArm) -> Vec<Vec<i32>> {
        let m1 = MockArm { batch: 1, ..m4.clone() };
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), m1.k));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }
}
