//! Continuous batching — the scheduling system the paper defers to future
//! work (§4.1: "We leave the implementation of a scheduling system to
//! future work, which would allow sampling at an average rate equal to the
//! batch size 1 setting").
//!
//! In synchronous batching the slowest image pins the whole batch: every
//! other slot idles (recomputes already-final values) until the straggler
//! converges. Here a converged slot is immediately refilled with the next
//! queued job, so the batch's occupancy — and per-job ARM-call cost —
//! approaches the batch-size-1 rate. Per-job noise is keyed by job id
//! (not slot), so results are bitwise identical to any other placement —
//! the refill tests rely on that invariant.

use crate::sampler::forecast::Forecaster;
use crate::sampler::noise::JobNoise;
use crate::sampler::predictive::PredictiveSampler;
use crate::sampler::{JobResult, StepModel};
use crate::substrate::timer::Timer;
use anyhow::Result;

/// Outcome of scheduling `n_jobs` through a fixed-size batch engine.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// Per-job results in job-id order.
    pub results: Vec<JobResult>,
    /// Total ARM passes executed.
    pub total_passes: usize,
    /// Mean active slots per pass (≤ batch size).
    pub occupancy: f64,
    pub wall_secs: f64,
    /// ARM calls per job (total_passes * B / n — the batched cost model —
    /// for comparison against the paper's batch-1 rate).
    pub calls_per_job: f64,
}

/// Per-job ARM calls as a percentage of the baseline's `d` calls — the
/// one normalization both the scheduler reports and the serving layer's
/// per-group responses use.
pub fn calls_pct_of(calls_per_job: f64, dim: usize) -> f64 {
    100.0 * calls_per_job / dim as f64
}

impl ScheduleReport {
    /// See [`calls_pct_of`].
    pub fn calls_pct(&self, dim: usize) -> f64 {
        calls_pct_of(self.calls_per_job, dim)
    }
}

/// Continuous batching: keep every slot busy by refilling converged slots
/// from the queue. Jobs `0..n_jobs` get noise keyed `(seed, job_id)`.
pub fn run_continuous<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    n_jobs: usize,
    seed: u64,
) -> Result<ScheduleReport> {
    let d = model.dim();
    let k = model.categories();
    let noises = (0..n_jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    run_continuous_noises(model, forecaster, noises)
}

/// Continuous batching over an explicit job queue (each job brings its own
/// noise block — used by the server to merge requests with different
/// seeds into one schedule).
pub fn run_continuous_noises<M: StepModel>(
    model: &M,
    forecaster: Box<dyn Forecaster>,
    noises: Vec<JobNoise>,
) -> Result<ScheduleReport> {
    let n_jobs = noises.len();
    let b = model.batch();
    let timer = Timer::start();
    let mut ps = PredictiveSampler::new(model, forecaster);
    let mut slot_job: Vec<Option<usize>> = vec![None; b];
    let mut results: Vec<Option<JobResult>> = (0..n_jobs).map(|_| None).collect();
    let mut queue = noises.into_iter().enumerate().collect::<std::collections::VecDeque<_>>();
    let mut completed = 0usize;
    let mut active_accum = 0usize;
    let mut passes = 0usize;

    // Prime the slots.
    for s in 0..b {
        if let Some((id, noise)) = queue.pop_front() {
            ps.reset_slot(s, noise);
            slot_job[s] = Some(id);
        }
    }

    while completed < n_jobs {
        active_accum += slot_job.iter().filter(|j| j.is_some()).count();
        ps.step()?;
        passes += 1;
        for s in 0..b {
            if slot_job[s].is_some() && ps.slot_done(s) {
                let job = slot_job[s].take().unwrap();
                results[job] = Some(ps.take_result(s).expect("done slot"));
                completed += 1;
                if let Some((id, noise)) = queue.pop_front() {
                    ps.reset_slot(s, noise);
                    slot_job[s] = Some(id);
                }
            }
        }
    }

    let results: Vec<JobResult> = results.into_iter().map(|r| r.expect("all jobs complete")).collect();
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / (passes.max(1) * b) as f64,
        wall_secs: timer.secs(),
        calls_per_job: passes as f64 * b as f64 / n_jobs as f64,
        results,
    })
}

/// Synchronous batching baseline: process jobs in batch-size chunks; each
/// chunk runs until its slowest job converges (the paper's Table-1/2
/// semantics, extended to a queue of jobs).
pub fn run_sync_chunks<M: StepModel>(
    model: &M,
    mut make_forecaster: impl FnMut() -> Box<dyn Forecaster>,
    n_jobs: usize,
    seed: u64,
) -> Result<ScheduleReport> {
    let b = model.batch();
    let d = model.dim();
    let k = model.categories();
    let timer = Timer::start();
    let mut results: Vec<JobResult> = Vec::with_capacity(n_jobs);
    let mut passes = 0usize;
    let mut active_accum = 0usize;
    let mut start = 0usize;
    while start < n_jobs {
        let chunk = (n_jobs - start).min(b);
        let mut ps = PredictiveSampler::new(model, make_forecaster());
        for s in 0..chunk {
            ps.reset_slot(s, JobNoise::new(seed, (start + s) as u64, d, k));
        }
        while (0..chunk).any(|s| !ps.slot_done(s)) {
            active_accum += (0..chunk).filter(|&s| !ps.slot_done(s)).count();
            ps.step()?;
            passes += 1;
        }
        for s in 0..chunk {
            results.push(ps.take_result(s).expect("chunk job done"));
        }
        start += chunk;
    }
    Ok(ScheduleReport {
        total_passes: passes,
        occupancy: active_accum as f64 / (passes.max(1) * b) as f64,
        wall_secs: timer.secs(),
        calls_per_job: passes as f64 * b as f64 / n_jobs as f64,
        results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::forecast::FpiReuse;
    use crate::sampler::mock::MockArm;
    use crate::sampler::noise::JobNoise;
    use crate::sampler::predictive::PredictiveSampler;

    fn reference_samples(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let m1 = MockArm::new(1, 3, 6, 4, 2, 2.5, 21);
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), 4));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }

    #[test]
    fn continuous_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_continuous(&m, Box::new(FpiReuse), 11, 3).unwrap();
        assert_eq!(rep.results.len(), 11);
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i], "job {i} sample changed under scheduling");
        }
        assert!(rep.occupancy > 0.0 && rep.occupancy <= 1.0);
    }

    #[test]
    fn sync_matches_per_job_samples() {
        let m = MockArm::new(4, 3, 6, 4, 2, 2.5, 21);
        let rep = run_sync_chunks(&m, || Box::new(FpiReuse), 11, 3).unwrap();
        let refs = reference_samples(11, 3);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    #[test]
    fn continuous_at_least_as_efficient() {
        // With heterogeneous convergence, slot refill can only reduce the
        // number of passes needed for a queue of jobs.
        let m = MockArm::new(4, 3, 8, 5, 2, 3.0, 33);
        let cont = run_continuous(&m, Box::new(FpiReuse), 16, 9).unwrap();
        let sync = run_sync_chunks(&m, || Box::new(FpiReuse), 16, 9).unwrap();
        assert!(
            cont.total_passes <= sync.total_passes,
            "continuous {} > sync {}",
            cont.total_passes,
            sync.total_passes
        );
        assert!(cont.occupancy >= sync.occupancy - 1e-9);
    }

    #[test]
    fn occupancy_and_calls_per_job_stay_bounded() {
        // Property: as jobs drain, occupancy stays in [1/B, 1] (every pass
        // has at least one active slot, at most B) and calls_per_job stays
        // in [1, B*d] (every job needs >= 1 pass; no job survives more
        // than d passes). The identity occupancy * passes * B = total
        // job-iterations ties the two together.
        use crate::substrate::proptest_lite::check;
        check("scheduler-bounds", 16, |g| {
            let b = g.usize_in(1, 7);
            let m = MockArm::new(b, g.usize_in(1, 4), g.usize_in(2, 7), g.usize_in(2, 6), 1, g.f64_in(0.0, 4.0) as f32, g.rng.next_u64());
            let n = g.usize_in(1, 20);
            let rep = run_continuous(&m, Box::new(FpiReuse), n, g.rng.next_u64()).map_err(|e| e.to_string())?;
            let (bf, d) = (b as f64, m.dim() as f64);
            crate::prop_assert!(
                rep.occupancy >= 1.0 / bf - 1e-9 && rep.occupancy <= 1.0 + 1e-9,
                "occupancy {} outside [1/{b}, 1] (n={n})",
                rep.occupancy
            );
            crate::prop_assert!(rep.calls_per_job >= 1.0 - 1e-9, "calls_per_job {} < 1", rep.calls_per_job);
            crate::prop_assert!(rep.calls_per_job <= bf * d + 1e-9, "calls_per_job {} > B*d = {}", rep.calls_per_job, bf * d);
            let iterations = rep.occupancy * rep.total_passes as f64 * bf;
            crate::prop_assert!(iterations >= n as f64 - 1e-6, "total iterations {iterations} < n={n}");
            let pct = rep.calls_pct(m.dim());
            crate::prop_assert!((pct - 100.0 * rep.calls_per_job / d).abs() < 1e-9, "calls_pct helper disagrees");
            Ok(())
        });
    }

    #[test]
    fn handles_fewer_jobs_than_slots() {
        let m = MockArm::new(4, 2, 5, 3, 1, 2.0, 5);
        let rep = run_continuous(&m, Box::new(FpiReuse), 2, 1).unwrap();
        assert_eq!(rep.results.len(), 2);
        let refs = reference_samples_small(2, 1, &m);
        for (i, job) in rep.results.iter().enumerate() {
            assert_eq!(job.x, refs[i]);
        }
    }

    fn reference_samples_small(n: usize, seed: u64, m4: &MockArm) -> Vec<Vec<i32>> {
        let m1 = MockArm { batch: 1, ..m4.clone() };
        (0..n)
            .map(|id| {
                let mut ps = PredictiveSampler::new(&m1, Box::new(FpiReuse));
                ps.reset_slot(0, JobNoise::new(seed, id as u64, m1.dim(), m1.k));
                while !ps.slot_done(0) {
                    ps.step().unwrap();
                }
                ps.take_result(0).unwrap().x
            })
            .collect()
    }
}
