//! Pluggable scheduling policies: batch **sizing** and mid-flight
//! **admission**, extracted from the scheduler and server so the
//! rate-vs-latency trade is an explicit, documented dial instead of
//! constants buried in `scheduler.rs` (see `docs/ARCHITECTURE.md`).
//!
//! Two decisions are pluggable, and both are *work placement only* —
//! never correctness. Per-job noise is keyed by `(seed, job index)`, so
//! any sizing or admission choice produces bitwise the same samples
//! (property-tested in `tests/sampler_props.rs`, `policy-exactness`):
//!
//! * [`SizingPolicy`] — which exported batch size an elastic schedule
//!   runs on, re-decided between ARM passes. [`OccupancyFirst`] fills
//!   the largest export the runnable jobs can occupy completely (the
//!   paper's §4.1 batch-1 ARM-call-rate target; excess in-flight slots
//!   park). [`LatencyLean`] fits every runnable job into the smallest
//!   export that holds them all, accepting dead slots. [`SloHybrid`]
//!   sizes for occupancy until the projected queue delay exceeds a
//!   target, then up-shifts — occupancy-first economics under an
//!   explicit latency ceiling.
//! * [`AdmissionPolicy`] — whether a live schedule absorbs a mid-flight
//!   arrival of its own `(model, method)` group or leaves it queued for
//!   the next batching window (or a thief). [`OldestFirst`] replaces
//!   the old blunt 8×`max_batch` absorb budget with age-based fairness:
//!   absorb only while no *other* group's queued request has been
//!   waiting meaningfully longer, so a hot group cannot starve its
//!   neighbours. [`AbsorbBudget`] keeps the legacy cap available.
//!
//! Selection is wired through [`crate::coordinator::config::ServeConfig`]
//! (`policy`, `slo`, `admission`; CLI `--policy`, `--slo-ms`,
//! `--absorb-budget`) and lands in the scheduler via
//! [`crate::coordinator::scheduler::run_elastic_family_policy`].
#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Everything a [`SizingPolicy`] may consult, snapshotted by the
/// scheduler before each resize decision. Counts are jobs, not slots.
#[derive(Clone, Copy, Debug)]
pub struct SizingCtx {
    /// Jobs currently installed in batch slots (mid-flight).
    pub in_flight: usize,
    /// Mid-flight jobs parked out of their slots, waiting to resume.
    pub parked: usize,
    /// Fresh jobs queued for admission.
    pub queued: usize,
    /// ARM passes the schedule has run so far.
    pub passes: usize,
    /// How many passes the oldest waiting (parked or queued) job has
    /// been waiting; 0 when nothing waits.
    pub oldest_wait_passes: usize,
    /// Model dimension `d` — the worst-case passes a job can need, used
    /// as the convergence prior before any job has completed.
    pub dim: usize,
    /// EWMA of wall-seconds per ARM pass (`None` before the first pass).
    pub pass_secs: Option<f64>,
    /// EWMA of passes a job needs to converge (`None` before the first
    /// completion).
    pub passes_per_job: Option<f64>,
}

impl SizingCtx {
    /// Total runnable jobs (in-flight + parked + queued), floored at 1.
    pub fn need(&self) -> usize {
        (self.in_flight + self.parked + self.queued).max(1)
    }
}

/// Batch-sizing policy for the elastic scheduler: between ARM passes,
/// pick which exported batch size the schedule should run on.
///
/// Contract: `choose` must return one of `exports` (non-empty,
/// ascending). The scheduler falls back to the fit rule on a value not
/// in the family, so a buggy policy degrades to latency-lean sizing
/// instead of panicking. Sizing never affects samples — only which
/// slots run when — so implementations are free to be heuristic.
pub trait SizingPolicy {
    /// Stable label for reports and metrics (`ScheduleReport::policy`,
    /// the server's `schedules_by_policy` counters).
    fn name(&self) -> &'static str;
    /// Choose a batch size from `exports` for the current state.
    fn choose(&self, exports: &[usize], ctx: &SizingCtx) -> usize;
}

/// The *fit* rule: smallest export that holds `need` jobs (the largest
/// export when nothing fits). Favors tail latency — every runnable job
/// gets a slot — at the cost of dead slots on partial batches.
pub fn fit_size(exports: &[usize], need: usize) -> usize {
    let need = need.max(1);
    exports.iter().copied().find(|&b| b >= need).unwrap_or_else(|| *exports.last().expect("non-empty export family"))
}

/// The *fill* rule: largest export `need` jobs can completely occupy
/// (the smallest export when even that cannot be filled). Favors the
/// batched ARM-call rate — every pass runs a full batch — at the cost
/// of parking excess jobs.
pub fn fill_size(exports: &[usize], need: usize) -> usize {
    let need = need.max(1);
    exports.iter().copied().rev().find(|&b| b <= need).unwrap_or_else(|| *exports.first().expect("non-empty export family"))
}

/// Occupancy-first sizing (the live scheduler's default, PR 3's rule):
/// always [`fill_size`]. Every pass runs a full batch — the paper's
/// §4.1 batch-1 ARM-call-rate target — but small odd-sized groups on
/// sparse export families serialize (3 jobs on a `{1, 4}` family run
/// b=1, one at a time).
#[derive(Clone, Copy, Debug, Default)]
pub struct OccupancyFirst;

impl SizingPolicy for OccupancyFirst {
    fn name(&self) -> &'static str {
        "occupancy"
    }
    fn choose(&self, exports: &[usize], ctx: &SizingCtx) -> usize {
        fill_size(exports, ctx.need())
    }
}

/// Latency-lean sizing (the closed-queue scheduler's rule since PR 2):
/// always [`fit_size`]. No job ever waits for a slot, so per-job
/// latency is minimal, but partial batches burn slot-passes on dead
/// slots.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyLean;

impl SizingPolicy for LatencyLean {
    fn name(&self) -> &'static str {
        "latency"
    }
    fn choose(&self, exports: &[usize], ctx: &SizingCtx) -> usize {
        fit_size(exports, ctx.need())
    }
}

/// Queue-delay target for [`SloHybrid`].
#[derive(Clone, Copy, Debug)]
pub enum SloTarget {
    /// Wall-clock target (the serving `--slo-ms` knob). Projected delay
    /// is passes × the schedule's measured per-pass wall-time EWMA;
    /// before an estimate exists the policy up-shifts conservatively
    /// (protect the SLO, not the call rate).
    Wall(Duration),
    /// Pass-denominated target. Fully deterministic — no clock reads —
    /// so tests and benches use it to pin exact policy trajectories.
    Passes(f64),
}

/// SLO-driven hybrid sizing: occupancy-first economics under an
/// explicit latency ceiling. Sizes with [`fill_size`] (full batches,
/// batch-1 call rate) while the *projected queue delay* — accrued wait
/// of the oldest waiting job plus the cohorts of full batches that must
/// converge before the last waiting job gets a slot — stays within the
/// target, and up-shifts to [`fit_size`] the moment it would not.
///
/// The projection uses the schedule's own convergence EWMA, falling
/// back to the worst case (`d` passes per job, the ancestral rate)
/// before any job has completed, so a cold schedule errs on the side of
/// the SLO.
#[derive(Clone, Copy, Debug)]
pub struct SloHybrid {
    /// The queue-delay ceiling.
    pub target: SloTarget,
}

impl SloHybrid {
    /// Projected worst-case queue delay, in passes, if the schedule
    /// sized to `fill_b` (leaving `need - fill_b` jobs waiting).
    ///
    /// When sizing to `fill_b` would **evict seated jobs**
    /// (`fill_b < in_flight`), the projection uses the worst-case prior
    /// (`d` passes) instead of the convergence EWMA. The EWMA reflects
    /// *completed* — typically fast — jobs, so it can badly underestimate
    /// a seated straggler's remaining passes; and an eviction right after
    /// an SLO up-shift has just zeroed the evictees' accrued wait, so an
    /// optimistic projection here would park-and-reseat the same jobs in
    /// a starvation loop. Using the worst case makes SLO up-shifts sticky
    /// until the batch drains naturally (`need` small enough that nothing
    /// seated is evicted), while leaving loose targets (above `d`-scale
    /// delays) free to park — so the extreme targets still reproduce
    /// occupancy-first and latency-lean exactly.
    fn projected_delay_passes(&self, fill_b: usize, ctx: &SizingCtx) -> f64 {
        let waiting = ctx.need() - fill_b;
        let rounds = waiting.div_ceil(fill_b);
        let worst = ctx.dim.max(1) as f64;
        let per_job = if fill_b < ctx.in_flight { worst } else { ctx.passes_per_job.unwrap_or(worst) };
        ctx.oldest_wait_passes as f64 + rounds as f64 * per_job
    }
}

impl SizingPolicy for SloHybrid {
    fn name(&self) -> &'static str {
        "slo"
    }
    fn choose(&self, exports: &[usize], ctx: &SizingCtx) -> usize {
        let need = ctx.need();
        let fill_b = fill_size(exports, need);
        let fit_b = fit_size(exports, need);
        if fit_b <= fill_b {
            // `need` fills an export exactly (or exceeds the largest):
            // occupancy sizing leaves nobody waiting that fit would seat.
            return fill_b;
        }
        let delay = self.projected_delay_passes(fill_b, ctx);
        let exceeded = match self.target {
            SloTarget::Passes(p) => delay > p,
            SloTarget::Wall(d) => match ctx.pass_secs {
                Some(s) => delay * s > d.as_secs_f64(),
                None => true,
            },
        };
        if exceeded {
            fit_b
        } else {
            fill_b
        }
    }
}

/// Everything an [`AdmissionPolicy`] may consult about one mid-flight
/// arrival of the executing group, snapshotted under the pool lock.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionCtx {
    /// Jobs in the arriving request.
    pub jobs: usize,
    /// Jobs this schedule has already absorbed mid-flight (the initial
    /// batching window is not counted).
    pub absorbed: usize,
    /// How long ago the serving plane admitted the arriving request
    /// (its dispatcher admission timestamp — the same clock batching
    /// windows key on).
    pub age: Duration,
    /// Age of the oldest request of any *other* group queued on this
    /// worker — the request the absorption would starve. `None` when no
    /// other group waits.
    pub oldest_other_age: Option<Duration>,
}

/// Mid-flight admission policy: whether an executing group's live
/// schedule absorbs its own arrival or leaves it queued for the next
/// batching window (or a work-stealing neighbour). Denial never drops a
/// request — it only defers it — and absorption never changes samples,
/// so this is purely a group-throughput vs cross-group-latency dial.
pub trait AdmissionPolicy {
    /// Stable label for metrics.
    fn name(&self) -> &'static str;
    /// Whether to absorb the arrival described by `ctx`.
    fn admit(&self, ctx: &AdmissionCtx) -> bool;
}

/// Age-based fairness (the default): absorb an arrival only while no
/// other group's queued request has been waiting more than `slack`
/// longer than it — oldest-admission-first across groups. With nothing
/// else queued the schedule absorbs freely (work conservation); the
/// moment an older neighbour waits, the hot group stops growing and the
/// neighbour runs next.
#[derive(Clone, Copy, Debug)]
pub struct OldestFirst {
    /// Grace margin before an older neighbour blocks absorption.
    /// Serving uses `max_wait` — a neighbour inside its own batching
    /// window would not have executed yet anyway.
    pub slack: Duration,
}

impl AdmissionPolicy for OldestFirst {
    fn name(&self) -> &'static str {
        "oldest-first"
    }
    fn admit(&self, ctx: &AdmissionCtx) -> bool {
        match ctx.oldest_other_age {
            None => true,
            Some(other) => ctx.age + self.slack >= other,
        }
    }
}

/// The legacy blunt cap (PR 3's absorb budget): absorb until `budget`
/// jobs have been absorbed, regardless of who else waits.
#[derive(Clone, Copy, Debug)]
pub struct AbsorbBudget {
    /// Mid-flight jobs the schedule may absorb in total.
    pub budget: usize,
}

impl AdmissionPolicy for AbsorbBudget {
    fn name(&self) -> &'static str {
        "budget"
    }
    fn admit(&self, ctx: &AdmissionCtx) -> bool {
        ctx.absorbed < self.budget
    }
}

/// A convergence estimate for one workload: mean ARM passes a job needs
/// to converge, and mean wall-seconds per ARM pass. Produced by the
/// server's [`ConvergenceBook`] from completed schedules and used to
/// *seed* a fresh schedule's EWMAs
/// ([`crate::coordinator::scheduler::run_elastic_family_primed`]), so
/// [`SloHybrid`]'s cold-start projections start from observed history
/// instead of the worst-case `d` prior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConvergencePrior {
    /// Mean passes a job needs to converge.
    pub passes_per_job: f64,
    /// Mean wall-seconds per ARM pass.
    pub pass_secs: f64,
}

/// Smoothing factor for the cross-schedule estimates: heavier than the
/// in-schedule EWMA (each observation already averages a whole
/// schedule).
const BOOK_ALPHA: f64 = 0.3;

/// Server-level convergence history, shared by every engine worker: one
/// EWMA'd [`ConvergencePrior`] per workload key (the server keys by
/// `"model/method"`). Before this existed, every fresh schedule's SLO
/// projection assumed the worst case (`d` passes per job) until its own
/// first completion — so cold-start up-shift decisions were maximally
/// conservative on every schedule, forever, no matter how much history
/// the server had. The book closes that loop: schedules observe in,
/// fresh schedules seed from it.
///
/// Seeding only biases *sizing* — samples are bitwise identical under
/// any prior, like every other policy decision.
#[derive(Debug, Default)]
pub struct ConvergenceBook {
    inner: Mutex<BTreeMap<String, (ConvergencePrior, u64)>>,
}

impl ConvergenceBook {
    /// An empty book.
    pub fn new() -> ConvergenceBook {
        ConvergenceBook::default()
    }

    /// Fold one completed schedule's observation into `key`'s estimate.
    /// Non-finite or non-positive observations are ignored (an empty or
    /// zero-pass schedule has nothing to teach).
    pub fn observe(&self, key: &str, obs: ConvergencePrior) {
        if !(obs.passes_per_job.is_finite() && obs.passes_per_job > 0.0 && obs.pass_secs.is_finite() && obs.pass_secs > 0.0) {
            return;
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let slot = inner.entry(key.to_string()).or_insert((obs, 0));
        if slot.1 > 0 {
            slot.0.passes_per_job += BOOK_ALPHA * (obs.passes_per_job - slot.0.passes_per_job);
            slot.0.pass_secs += BOOK_ALPHA * (obs.pass_secs - slot.0.pass_secs);
        }
        slot.1 += 1;
    }

    /// The current estimate for `key`, if any schedule has completed.
    pub fn prior(&self, key: &str) -> Option<ConvergencePrior> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).get(key).map(|(est, _)| *est)
    }

    /// Every estimate with its observation count (metrics snapshot),
    /// in key order — the `BTreeMap` iterates sorted, so the serialized
    /// `convergence` object is byte-stable however schedules interleaved.
    pub fn entries(&self) -> Vec<(String, ConvergencePrior, u64)> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.iter().map(|(k, (est, n))| (k.clone(), *est, *n)).collect()
    }
}

/// Serving-config selector for the sizing policy (`--policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`OccupancyFirst`].
    Occupancy,
    /// [`LatencyLean`].
    Latency,
    /// [`SloHybrid`] with the config's wall-clock `slo` target.
    Slo,
}

impl PolicyKind {
    /// Parse a `--policy` flag value.
    pub fn parse(name: &str) -> Option<PolicyKind> {
        Some(match name {
            "occupancy" | "fill" => PolicyKind::Occupancy,
            "latency" | "fit" => PolicyKind::Latency,
            "slo" => PolicyKind::Slo,
            _ => return None,
        })
    }

    /// The canonical flag spelling.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Occupancy => "occupancy",
            PolicyKind::Latency => "latency",
            PolicyKind::Slo => "slo",
        }
    }
}

/// Serving-config selector for the admission policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionKind {
    /// [`OldestFirst`] with `max_wait` slack (the default).
    OldestFirst,
    /// [`AbsorbBudget`] with an explicit job cap (`--absorb-budget`).
    Budget(usize),
}

/// Build the sizing policy a server execution runs under.
pub fn sizing_for(kind: PolicyKind, slo: Duration) -> Box<dyn SizingPolicy> {
    match kind {
        PolicyKind::Occupancy => Box::new(OccupancyFirst),
        PolicyKind::Latency => Box::new(LatencyLean),
        PolicyKind::Slo => Box::new(SloHybrid { target: SloTarget::Wall(slo) }),
    }
}

/// Build the admission policy a server execution runs under. `slack` is
/// the serving batching window (`max_wait`).
pub fn admission_for(kind: AdmissionKind, slack: Duration) -> Box<dyn AdmissionPolicy> {
    match kind {
        AdmissionKind::OldestFirst => Box::new(OldestFirst { slack }),
        AdmissionKind::Budget(budget) => Box::new(AbsorbBudget { budget }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(in_flight: usize, parked: usize, queued: usize) -> SizingCtx {
        SizingCtx { in_flight, parked, queued, passes: 0, oldest_wait_passes: 0, dim: 48, pass_secs: None, passes_per_job: None }
    }

    #[test]
    fn fit_and_fill_rules() {
        let exports = [1usize, 4];
        assert_eq!(fit_size(&exports, 1), 1);
        assert_eq!(fit_size(&exports, 2), 4);
        assert_eq!(fit_size(&exports, 3), 4);
        assert_eq!(fit_size(&exports, 4), 4);
        assert_eq!(fit_size(&exports, 9), 4, "beyond the family: the largest export");
        assert_eq!(fit_size(&exports, 0), 1, "need floors at 1");
        assert_eq!(fill_size(&exports, 1), 1);
        assert_eq!(fill_size(&exports, 3), 1, "cannot fill b=4: run full b=1 batches");
        assert_eq!(fill_size(&exports, 4), 4);
        assert_eq!(fill_size(&exports, 9), 4);
        assert_eq!(fill_size(&[4, 8], 2), 4, "nothing fillable: the smallest export");
    }

    #[test]
    fn occupancy_and_latency_policies_follow_their_rules() {
        let exports = [1usize, 2, 4];
        for need in 1..9 {
            let c = ctx(0, 0, need);
            assert_eq!(OccupancyFirst.choose(&exports, &c), fill_size(&exports, need), "need {need}");
            assert_eq!(LatencyLean.choose(&exports, &c), fit_size(&exports, need), "need {need}");
        }
        assert_eq!(OccupancyFirst.name(), "occupancy");
        assert_eq!(LatencyLean.name(), "latency");
    }

    #[test]
    fn slo_hybrid_interpolates_between_fill_and_fit() {
        let exports = [1usize, 4];
        // 3 jobs on {1, 4}: fill leaves 2 waiting through 2 cohorts.
        let c = ctx(1, 0, 2);
        let loose = SloHybrid { target: SloTarget::Passes(1e9) };
        let tight = SloHybrid { target: SloTarget::Passes(0.5) };
        assert_eq!(loose.choose(&exports, &c), 1, "within a loose target the hybrid keeps full b=1 batches");
        assert_eq!(tight.choose(&exports, &c), 4, "a tight target forces the up-shift");
        // A filled export never up-shifts: nobody fit would seat waits.
        let full = ctx(4, 0, 0);
        assert_eq!(tight.choose(&exports, &full), 4);
        let one = ctx(1, 0, 0);
        assert_eq!(tight.choose(&exports, &one), 1, "a single job has no queue to protect");
    }

    #[test]
    fn slo_hybrid_uses_conservative_prior_then_ewma() {
        let exports = [1usize, 4];
        // Cold (no completions): prior is d passes per waiting cohort —
        // 2 cohorts * 48 = 96 projected passes.
        let cold = ctx(1, 0, 2);
        let mid = SloHybrid { target: SloTarget::Passes(50.0) };
        assert_eq!(mid.choose(&exports, &cold), 4, "cold schedules err toward the SLO");
        // Warm: jobs converge in ~3 passes, projection 6 <= 50.
        let warm = SizingCtx { passes_per_job: Some(3.0), ..cold };
        assert_eq!(mid.choose(&exports, &warm), 1, "a fast-converging schedule keeps occupancy sizing");
        // Accrued wait counts against the target too.
        let stale = SizingCtx { oldest_wait_passes: 60, ..warm };
        assert_eq!(mid.choose(&exports, &stale), 4, "jobs already waiting past the target force the up-shift");
    }

    #[test]
    fn slo_hybrid_does_not_thrash_seated_jobs() {
        // Anti-oscillation: right after an SLO up-shift seats everyone,
        // the evictees' accrued wait is zero and the convergence EWMA may
        // badly underestimate a seated straggler — an optimistic
        // projection would park-and-reseat the same jobs in a loop. A
        // down-shift that would evict seated jobs must therefore be
        // judged against the worst-case prior, not the EWMA.
        let exports = [1usize, 4];
        let mid = SloHybrid { target: SloTarget::Passes(50.0) };
        // 3 jobs, all seated (post-up-shift), EWMA says jobs are fast:
        // parking 2 of them projects 2 cohorts * d=48 = 96 > 50 — stay up.
        let seated = SizingCtx { passes_per_job: Some(3.0), ..ctx(3, 0, 0) };
        assert_eq!(mid.choose(&exports, &seated), 4, "never re-park seated jobs on an optimistic EWMA");
        // The same EWMA with nobody evicted (1 seated, 2 queued) still
        // projects from the EWMA and keeps occupancy sizing.
        let queued = SizingCtx { passes_per_job: Some(3.0), ..ctx(1, 0, 2) };
        assert_eq!(mid.choose(&exports, &queued), 1, "fresh admissions still size by the EWMA");
        // A loose target (above d-scale delays) may still park seated
        // jobs — that is what keeps it equivalent to occupancy-first.
        let loose = SloHybrid { target: SloTarget::Passes(1e9) };
        assert_eq!(loose.choose(&exports, &seated), 1, "loose targets keep occupancy-first economics");
    }

    #[test]
    fn slo_wall_target_upshifts_without_an_estimate() {
        let exports = [1usize, 4];
        let c = ctx(1, 0, 2);
        let p = SloHybrid { target: SloTarget::Wall(Duration::from_millis(100)) };
        assert_eq!(p.choose(&exports, &c), 4, "no pass-time estimate: protect the SLO");
        let warm = SizingCtx { pass_secs: Some(1e-6), passes_per_job: Some(2.0), ..c };
        assert_eq!(p.choose(&exports, &warm), 1, "microsecond passes project far under a 100ms target");
        let slow = SizingCtx { pass_secs: Some(0.5), passes_per_job: Some(2.0), ..c };
        assert_eq!(p.choose(&exports, &slow), 4, "half-second passes blow a 100ms target");
    }

    #[test]
    fn oldest_first_admission_is_age_ordered() {
        let p = OldestFirst { slack: Duration::from_millis(10) };
        let base = AdmissionCtx { jobs: 2, absorbed: 0, age: Duration::from_millis(5), oldest_other_age: None };
        assert!(p.admit(&base), "nothing else waits: absorb freely");
        let younger_other = AdmissionCtx { oldest_other_age: Some(Duration::from_millis(3)), ..base };
        assert!(p.admit(&younger_other), "the arrival is older than the neighbour");
        let slightly_older = AdmissionCtx { oldest_other_age: Some(Duration::from_millis(12)), ..base };
        assert!(p.admit(&slightly_older), "inside the slack the arrival still absorbs");
        let much_older = AdmissionCtx { oldest_other_age: Some(Duration::from_millis(40)), ..base };
        assert!(!p.admit(&much_older), "a starved neighbour blocks absorption");
    }

    #[test]
    fn absorb_budget_admission_caps_total_jobs() {
        let p = AbsorbBudget { budget: 8 };
        let go = AdmissionCtx { jobs: 4, absorbed: 7, age: Duration::ZERO, oldest_other_age: Some(Duration::from_secs(9)) };
        assert!(p.admit(&go), "budget admission ignores neighbour ages");
        let stop = AdmissionCtx { absorbed: 8, ..go };
        assert!(!p.admit(&stop), "an exhausted budget stops absorbing");
    }

    #[test]
    fn convergence_book_ewma_and_misses() {
        let book = ConvergenceBook::new();
        assert_eq!(book.prior("m/fpi"), None, "an unseen key has no prior");
        book.observe("m/fpi", ConvergencePrior { passes_per_job: 4.0, pass_secs: 0.01 });
        let first = book.prior("m/fpi").unwrap();
        assert_eq!(first.passes_per_job, 4.0, "the first observation seeds the estimate directly");
        book.observe("m/fpi", ConvergencePrior { passes_per_job: 8.0, pass_secs: 0.01 });
        let second = book.prior("m/fpi").unwrap();
        assert!(second.passes_per_job > 4.0 && second.passes_per_job < 8.0, "later observations blend by EWMA: {}", second.passes_per_job);
        // Garbage observations must not poison the estimate.
        book.observe("m/fpi", ConvergencePrior { passes_per_job: f64::NAN, pass_secs: 0.01 });
        book.observe("m/fpi", ConvergencePrior { passes_per_job: 0.0, pass_secs: 0.01 });
        assert_eq!(book.prior("m/fpi").unwrap(), second, "non-finite / non-positive observations are ignored");
        // Keys are independent; entries() reports counts.
        book.observe("m/zeros", ConvergencePrior { passes_per_job: 2.0, pass_secs: 0.02 });
        let entries = book.entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].0, "m/fpi");
        assert_eq!(entries[0].2, 2, "only valid observations count");
        assert_eq!(entries[1].2, 1);
    }

    #[test]
    fn kind_parsing_and_builders() {
        assert_eq!(PolicyKind::parse("occupancy"), Some(PolicyKind::Occupancy));
        assert_eq!(PolicyKind::parse("fill"), Some(PolicyKind::Occupancy));
        assert_eq!(PolicyKind::parse("latency"), Some(PolicyKind::Latency));
        assert_eq!(PolicyKind::parse("fit"), Some(PolicyKind::Latency));
        assert_eq!(PolicyKind::parse("slo"), Some(PolicyKind::Slo));
        assert_eq!(PolicyKind::parse("wat"), None);
        for kind in [PolicyKind::Occupancy, PolicyKind::Latency, PolicyKind::Slo] {
            assert_eq!(PolicyKind::parse(kind.label()), Some(kind), "label must round-trip");
            assert_eq!(sizing_for(kind, Duration::from_millis(50)).name(), kind.label());
        }
        assert_eq!(admission_for(AdmissionKind::OldestFirst, Duration::ZERO).name(), "oldest-first");
        assert_eq!(admission_for(AdmissionKind::Budget(4), Duration::ZERO).name(), "budget");
    }
}
