//! Model-name → engine routing with lazy loading and LRU eviction.
//!
//! Engines are expensive (compiling every batch-size executable), so they
//! are created on first request and cached. Thread-affine like everything
//! PJRT: a `Router` lives on the engine thread.
//!
//! The placement plane ([`crate::coordinator::placement`]) decides which
//! workers may *own* which engines; this module supplies the mechanics it
//! needs on each worker: recency tracking ([`Router::engine`] bumps the
//! touched model to most-recent), explicit unloading ([`Router::unload`]),
//! and capacity enforcement — [`Router::make_room`] evicts
//! least-recently-used engines *before* a lazy load so residency never
//! exceeds the cap even transiently, with [`Router::enforce_cap`] as the
//! after-the-fact safety net. The cumulative [`Router::loads`] /
//! [`Router::evictions`] counters feed the server's per-worker
//! `engine_loads` / `evictions` gauges.

use crate::coordinator::engine::Engine;
use crate::runtime::artifact::Manifest;
use crate::runtime::step::CatalogStats;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct Router {
    manifest: Manifest,
    engines: BTreeMap<String, Engine>,
    /// Model names by recency of use, least-recent first.
    recency: Vec<String>,
    /// Cumulative engine loads since construction (reloads included).
    loads: u64,
    /// Cumulative LRU evictions since construction.
    evictions: u64,
    /// Whether lazily-loaded engines build shape-variant catalogs
    /// (`ServeConfig::variants` / `--no-variants`).
    variants: bool,
    /// Catalog telemetry of engines that have since been unloaded, folded
    /// in at eviction time so [`Router::catalog_totals`] stays monotonic
    /// across the LRU churn.
    retired: CatalogStats,
}

impl Router {
    pub fn new(manifest: Manifest) -> Router {
        Self::with_variants(manifest, true)
    }

    /// As [`Router::new`], with the shape-variant catalog toggled
    /// explicitly (the server threads `ServeConfig::variants` through).
    pub fn with_variants(manifest: Manifest, variants: bool) -> Router {
        Router {
            manifest,
            engines: BTreeMap::new(),
            recency: Vec::new(),
            loads: 0,
            evictions: 0,
            variants,
            retired: CatalogStats::default(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Models available for routing.
    pub fn model_names(&self) -> Vec<String> {
        self.manifest.models.keys().cloned().collect()
    }

    /// Engine for `model`, loading it on first use (and again after an
    /// eviction). Every call marks `model` most-recently-used.
    pub fn engine(&mut self, model: &str) -> Result<&Engine> {
        if !self.engines.contains_key(model) {
            let eng = Engine::load_with(&self.manifest, model, self.variants)?;
            self.engines.insert(model.to_string(), eng);
            self.loads += 1;
        }
        self.touch(model);
        Ok(self.engines.get(model).expect("just inserted"))
    }

    fn touch(&mut self, model: &str) {
        if let Some(pos) = self.recency.iter().position(|m| m == model) {
            self.recency.remove(pos);
        }
        self.recency.push(model.to_string());
    }

    /// Drop `model`'s engine if resident, freeing its executables.
    /// Returns whether anything was unloaded.
    pub fn unload(&mut self, model: &str) -> bool {
        if let Some(pos) = self.recency.iter().position(|m| m == model) {
            self.recency.remove(pos);
        }
        match self.engines.remove(model) {
            Some(eng) => {
                // Fold the departing engine's catalog telemetry into the
                // retired totals so eviction never loses counted work.
                if let Some(st) = eng.catalog_stats() {
                    self.retired.merge(&st);
                }
                true
            }
            None => false,
        }
    }

    /// Catalog telemetry summed over every engine this router ever loaded:
    /// resident engines' live counters plus the retired totals of evicted
    /// ones. Empty stats when no engine serves a catalog.
    pub fn catalog_totals(&self) -> CatalogStats {
        let mut total = self.retired.clone();
        for eng in self.engines.values() {
            if let Some(st) = eng.catalog_stats() {
                total.merge(&st);
            }
        }
        total
    }

    /// Evict least-recently-used engines until at most `cap` stay
    /// resident (the `CapacityCapped` placement policy's safety net).
    /// Returns how many engines were evicted.
    pub fn enforce_cap(&mut self, cap: usize) -> usize {
        let mut evicted = 0;
        while self.engines.len() > cap {
            let victim = self.recency.first().expect("resident engines are recency-tracked").clone();
            self.unload(&victim);
            evicted += 1;
        }
        self.evictions += evicted as u64;
        evicted
    }

    /// Make room for `model`'s engine under a residency cap: if it is
    /// not already resident, evict least-recently-used engines until the
    /// upcoming lazy load fits within `cap`. Called *before* the load —
    /// evicting afterwards would let residency peak at `cap + 1`, which
    /// breaks the capacity policy's promise of a hard per-worker memory
    /// bound. Returns how many engines were evicted.
    pub fn make_room(&mut self, model: &str, cap: usize) -> usize {
        if self.engines.contains_key(model) {
            return 0;
        }
        let mut evicted = 0;
        while self.engines.len() >= cap.max(1) {
            let victim = self.recency.first().expect("resident engines are recency-tracked").clone();
            self.unload(&victim);
            evicted += 1;
        }
        self.evictions += evicted as u64;
        evicted
    }

    /// Number of currently-loaded engines.
    pub fn loaded(&self) -> usize {
        self.engines.len()
    }

    /// Names of the currently-resident engines (sorted, for gauges).
    pub fn resident_models(&self) -> Vec<String> {
        self.engines.keys().cloned().collect()
    }

    /// Cumulative engine loads since construction (reloads included).
    pub fn loads(&self) -> u64 {
        self.loads
    }

    /// Cumulative LRU evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{write_mock_manifest, MockModelSpec};

    fn mock_router(tag: &str, names: &[&str]) -> Router {
        let dir = std::env::temp_dir().join(format!("predsamp-router-{tag}-{}", std::process::id()));
        let specs: Vec<MockModelSpec> = names.iter().enumerate().map(|(i, n)| MockModelSpec::new(n, i as u64 + 1)).collect();
        write_mock_manifest(&dir, &specs).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        Router::new(man)
    }

    #[test]
    fn lazy_loading_and_caching() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let mut r = Router::new(man);
        assert_eq!(r.loaded(), 0);
        assert!(r.model_names().contains(&"mnist_bin".to_string()));
        r.engine("mnist_bin").unwrap();
        assert_eq!(r.loaded(), 1);
        r.engine("mnist_bin").unwrap(); // cached
        assert_eq!(r.loaded(), 1);
        assert!(r.engine("not_a_model").is_err());
    }

    #[test]
    fn lru_eviction_under_capacity_cap() {
        // The CapacityCapped mechanism: loading beyond the cap must evict
        // the least-recently-*used* engine — touch order, not load order.
        let mut r = mock_router("lru", &["a", "b", "c"]);
        r.engine("a").unwrap();
        r.engine("b").unwrap();
        r.engine("c").unwrap();
        assert_eq!(r.loaded(), 3);
        assert_eq!(r.loads(), 3);
        r.engine("a").unwrap(); // cached touch: "b" is now the LRU
        assert_eq!(r.loads(), 3, "a cache hit is not a load");
        assert_eq!(r.enforce_cap(2), 1);
        assert_eq!(r.resident_models(), vec!["a".to_string(), "c".to_string()], "the LRU engine (b) must be the eviction victim");
        assert_eq!(r.evictions(), 1);
        // Reloading an evicted engine counts as a fresh load.
        r.engine("b").unwrap();
        assert_eq!(r.loads(), 4);
        // A cap at the resident count evicts nothing.
        assert_eq!(r.enforce_cap(3), 0);
        assert_eq!(r.evictions(), 1);
    }

    #[test]
    fn make_room_evicts_before_the_load_never_after() {
        // The capacity promise: residency must never exceed the cap,
        // even transiently — so room is made *before* the lazy load.
        let mut r = mock_router("room", &["a", "b", "c"]);
        r.engine("a").unwrap();
        assert_eq!(r.make_room("a", 1), 0, "a resident model needs no room");
        assert_eq!(r.make_room("b", 1), 1, "at the cap, the LRU engine goes first");
        assert_eq!(r.loaded(), 0, "room is made before the load, not after");
        r.engine("b").unwrap();
        assert_eq!(r.loaded(), 1);
        assert_eq!(r.make_room("c", 2), 0, "under the cap nothing is evicted");
        r.engine("c").unwrap();
        assert_eq!(r.loaded(), 2);
        assert_eq!(r.evictions(), 1);
    }

    #[test]
    fn catalog_totals_survive_eviction() {
        use crate::coordinator::config::Method;
        let dir = std::env::temp_dir().join(format!("predsamp-router-cat-{}", std::process::id()));
        let mut spec = MockModelSpec::new("a", 1);
        spec.spans = vec![6];
        write_mock_manifest(&dir, &[spec]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Router::new(man.clone());
        r.engine("a").unwrap().sample_batch(Method::Fpi, 4, 3).unwrap();
        let before = r.catalog_totals();
        assert!(before.variant_hits + before.full_shape_fallbacks > 0, "catalog passes must be counted");
        assert!(before.positions_evaluated > 0);
        assert!(r.unload("a"));
        let after = r.catalog_totals();
        assert_eq!(after.variant_hits, before.variant_hits, "eviction must not lose counted work");
        assert_eq!(after.positions_evaluated, before.positions_evaluated);
        // With variants off the router serves no catalogs anywhere.
        let mut off = Router::with_variants(man, false);
        off.engine("a").unwrap().sample_batch(Method::Fpi, 4, 3).unwrap();
        let none = off.catalog_totals();
        assert_eq!((none.variant_hits, none.positions_evaluated), (0, 0));
    }

    #[test]
    fn unload_frees_and_reports() {
        let mut r = mock_router("unload", &["a", "b"]);
        r.engine("a").unwrap();
        assert!(r.unload("a"), "resident engine must unload");
        assert!(!r.unload("a"), "second unload is a no-op");
        assert!(!r.unload("never_loaded"));
        assert_eq!(r.loaded(), 0);
        assert!(r.resident_models().is_empty());
    }
}
