//! Model-name → engine routing with lazy loading.
//!
//! Engines are expensive (compiling every batch-size executable), so they
//! are created on first request and cached. Thread-affine like everything
//! PJRT: a `Router` lives on the engine thread.

use crate::coordinator::engine::Engine;
use crate::runtime::artifact::Manifest;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct Router {
    manifest: Manifest,
    engines: BTreeMap<String, Engine>,
}

impl Router {
    pub fn new(manifest: Manifest) -> Router {
        Router { manifest, engines: BTreeMap::new() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Models available for routing.
    pub fn model_names(&self) -> Vec<String> {
        self.manifest.models.keys().cloned().collect()
    }

    /// Engine for `model`, loading it on first use.
    pub fn engine(&mut self, model: &str) -> Result<&Engine> {
        if !self.engines.contains_key(model) {
            let eng = Engine::load(&self.manifest, model)?;
            self.engines.insert(model.to_string(), eng);
        }
        Ok(self.engines.get(model).expect("just inserted"))
    }

    /// Number of currently-loaded engines.
    pub fn loaded(&self) -> usize {
        self.engines.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_loading_and_caching() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let mut r = Router::new(man);
        assert_eq!(r.loaded(), 0);
        assert!(r.model_names().contains(&"mnist_bin".to_string()));
        r.engine("mnist_bin").unwrap();
        assert_eq!(r.loaded(), 1);
        r.engine("mnist_bin").unwrap(); // cached
        assert_eq!(r.loaded(), 1);
        assert!(r.engine("not_a_model").is_err());
    }
}
