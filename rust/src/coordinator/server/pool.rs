//! The shared work pool: per-worker FIFO queues, the group routing
//! table, executing markers, and whole-group work stealing — everything
//! routing-related under one lock, so queueing, routing, and steals are
//! mutually atomic.

use crate::coordinator::config::Method;
use crate::coordinator::placement::PlacementPolicy;
use crate::coordinator::protocol;
use crate::substrate::readiness::Waker;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

pub(crate) type GroupKey = (String, Method);

/// One finished (or streamed) piece of a request's answer, routed from
/// an engine worker or the dispatcher back to the connection shard that
/// owns the request's connection, which appends the bytes to that
/// connection's outbound queue. mpsc FIFO ordering guarantees a
/// request's stream events hit the wire before its final reply.
pub(crate) struct Completion {
    /// Connection shard that owns `conn`. The channel the completion
    /// travels on already targets that shard; the index rides along for
    /// logs and delivery assertions.
    pub(crate) shard: usize,
    /// Connection the reply belongs to (shard-assigned connection id).
    pub(crate) conn: u64,
    /// The request's in-flight sequence number (unique per shard).
    pub(crate) seq: u64,
    /// Wire bytes: the JSON line (newline included) plus any binary frame.
    pub(crate) bytes: Vec<u8>,
    /// Final reply (retires the in-flight entry) vs a stream event.
    pub(crate) last: bool,
}

/// Sender half of one shard's completion channel, paired with that
/// shard's readiness waker: a completion sent from an engine thread
/// interrupts the shard's `wait` instantly instead of waiting out the
/// idle tick. The message is enqueued before the wake fires, so a woken
/// shard always finds it.
#[derive(Clone)]
pub(crate) struct CompletionTx {
    pub(crate) tx: mpsc::Sender<Completion>,
    pub(crate) waker: Arc<dyn Waker>,
}

impl CompletionTx {
    pub(crate) fn send(&self, c: Completion) -> Result<(), mpsc::SendError<Completion>> {
        self.tx.send(c)?;
        self.waker.wake();
        Ok(())
    }
}

/// Reply handle carried by every queued request: where the answer goes
/// (shard + connection + sequence number on the owning shard's
/// completion channel) and how the client asked for it delivered (id
/// echo, streaming, binary framing). `send` keeps the old
/// `mpsc::Sender<String>` call shape so the engine paths read unchanged.
#[derive(Clone)]
pub(crate) struct Reply {
    pub(crate) tx: CompletionTx,
    pub(crate) shard: usize,
    pub(crate) conn: u64,
    pub(crate) seq: u64,
    pub(crate) id: Option<u64>,
    pub(crate) stream: bool,
    pub(crate) frame: bool,
    /// Federation hop count from the request envelope. The engine pool
    /// ignores it; the front-tier router reads it to refuse forwarding
    /// loops (`hop >= max_hops`) and to advance it on the next tier.
    pub(crate) hop: u32,
}

impl Reply {
    fn dispatch(&self, line: String, frame: Option<Vec<u8>>, last: bool) -> Result<(), mpsc::SendError<Completion>> {
        let line = match self.id {
            Some(id) => protocol::with_id(&line, id),
            None => line,
        };
        let mut bytes = line.into_bytes();
        bytes.push(b'\n');
        if let Some(f) = frame {
            bytes.extend_from_slice(&f);
        }
        self.tx.send(Completion { shard: self.shard, conn: self.conn, seq: self.seq, bytes, last })
    }

    /// Send the final reply line (id echoed, no binary frame).
    pub(crate) fn send(&self, line: String) -> Result<(), mpsc::SendError<Completion>> {
        self.dispatch(line, None, true)
    }

    /// Send the final reply line followed by its binary sample frame.
    pub(crate) fn send_framed(&self, line: String, frame: Vec<u8>) -> Result<(), mpsc::SendError<Completion>> {
        self.dispatch(line, Some(frame), true)
    }

    /// Send a non-final stream event (optionally with a one-row frame).
    pub(crate) fn send_event(&self, line: String, frame: Option<Vec<u8>>) -> Result<(), mpsc::SendError<Completion>> {
        self.dispatch(line, frame, false)
    }

    /// A reply whose completions go nowhere (unit-test fixture).
    #[cfg(test)]
    pub(crate) fn discard() -> Reply {
        let (tx, rx) = mpsc::channel();
        drop(rx);
        let tx = CompletionTx { tx, waker: Arc::new(crate::substrate::readiness::NoopWaker) };
        Reply { tx, shard: 0, conn: 0, seq: 0, id: None, stream: false, frame: false, hop: 0 }
    }
}

/// Load units an `eval` contributes to a worker's queue depth. eval_bpd
/// runs a full test-set pass, so it must weigh like a batch of jobs or
/// least-loaded routing would pile groups behind it.
pub(crate) const EVAL_LOAD: usize = 8;

/// Shared state of one `(model, method)` batching group. Held by the
/// routing table and by every queued request of the group, so a steal can
/// retarget the route atomically under the pool lock.
pub(crate) struct GroupSlot {
    /// Worker currently owning the group.
    pub(crate) worker: AtomicUsize,
    /// Outstanding jobs; the routing entry dies when this drains to zero.
    pub(crate) pending: AtomicUsize,
}

/// A sample request admitted to the serving plane.
pub(crate) struct PendingSample {
    pub(crate) model: String,
    pub(crate) method: Method,
    pub(crate) n: usize,
    pub(crate) seed: u64,
    pub(crate) return_samples: bool,
    pub(crate) decode: bool,
    pub(crate) reply: Reply,
    /// When the dispatcher admitted the request. Batching windows close
    /// at `admitted + max_wait`, so time spent queued behind other groups
    /// counts against the window instead of restarting it.
    pub(crate) admitted: Instant,
    pub(crate) group: Arc<GroupSlot>,
}

/// Work queued to one engine worker.
pub(crate) enum Work {
    Sample(PendingSample),
    Eval {
        model: String,
        reply: Reply,
        /// Dispatcher admission time — age-based admission must see a
        /// queued eval too, or a hot absorbing group could starve it.
        admitted: Instant,
    },
}

/// Everything routing-related under one lock: per-worker FIFO queues, the
/// group routing table, and what each worker is executing right now —
/// so queueing, routing, and whole-group steals are mutually atomic.
pub(crate) struct PoolState {
    pub(crate) queues: Vec<VecDeque<Work>>,
    /// Per-worker executing group: its live schedule absorbs its own
    /// arrivals, so thieves must never take it.
    pub(crate) executing: Vec<Option<GroupKey>>,
    /// (model, method) → group slot; sticky while `pending > 0`.
    pub(crate) routes: BTreeMap<GroupKey, Arc<GroupSlot>>,
    /// Workers whose thread has exited (panic included): the dispatcher
    /// routes around them so requests never queue where nobody drains.
    pub(crate) dead: Vec<bool>,
}

/// The shared work pool engine workers and the dispatcher operate on.
pub(crate) struct Pool {
    pub(crate) state: Mutex<PoolState>,
    pub(crate) cv: Condvar,
    pub(crate) shutdown: AtomicBool,
    /// Queue depth per worker (jobs routed, not yet answered).
    pub(crate) loads: Vec<Arc<AtomicUsize>>,
}

/// Fail one request (shutdown / unknown model / engine error) and release
/// its load and group accounting.
pub(crate) fn fail_request(p: PendingSample, load: &AtomicUsize, why: &str) {
    let _ = p.reply.send(protocol::err(why));
    p.group.pending.fetch_sub(p.n, Ordering::SeqCst);
    load.fetch_sub(p.n, Ordering::SeqCst);
}

/// Fail every queued work item (shutdown) and release its accounting.
pub(crate) fn abort_queue(queue: VecDeque<Work>, load: &AtomicUsize, why: &str) {
    for w in queue {
        match w {
            Work::Sample(p) => fail_request(p, load, why),
            Work::Eval { reply, .. } => {
                let _ = reply.send(protocol::err(why));
                load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
            }
        }
    }
}

/// Move every queued request of `key` from `queue` into `group`,
/// preserving arrival order.
pub(crate) fn take_group_arrivals(queue: &mut VecDeque<Work>, key: &GroupKey, group: &mut Vec<PendingSample>) {
    let mut i = 0;
    while i < queue.len() {
        let hit = matches!(&queue[i], Work::Sample(p) if p.model == key.0 && p.method == key.1);
        if hit {
            let Some(Work::Sample(p)) = queue.remove(i) else { unreachable!("just matched") };
            group.push(p);
        } else {
            i += 1;
        }
    }
}

/// Steal work from a loaded worker into `thief`'s queue. Victims are
/// tried heaviest-queue first (evals weigh [`EVAL_LOAD`]); from each, the
/// oldest whole queued `(model, method)` group moves atomically — every
/// queued request of the key at once, arrival order preserved, and the
/// route retargeted — all under the pool lock, so sticky batching and
/// PJRT thread-affinity survive the migration. Groups currently executing
/// are never stolen (their owner's live schedule is absorbing arrivals),
/// and neither is any group — or eval — whose model the thief may not
/// host under the placement policy (a pinned model must never migrate
/// off its worker subset). A victim with nothing but its executing group
/// still yields any queued eval the thief is eligible for (evals are not
/// sticky). Returns whether anything moved.
pub(crate) fn steal_group(st: &mut PoolState, thief: usize, loads: &[Arc<AtomicUsize>], placement: &dyn PlacementPolicy) -> bool {
    let mut victims: Vec<(usize, usize)> = st
        .queues
        .iter()
        .enumerate()
        .filter(|&(w, _)| w != thief)
        .map(|(w, q)| {
            let weight: usize = q
                .iter()
                .map(|it| match it {
                    Work::Sample(p) => p.n,
                    Work::Eval { .. } => EVAL_LOAD,
                })
                .sum();
            (w, weight)
        })
        .filter(|&(_, weight)| weight > 0)
        .collect();
    victims.sort_by(|a, b| b.1.cmp(&a.1));
    for (v, _) in victims {
        let executing = st.executing[v].clone();
        let key = st.queues[v].iter().find_map(|it| match it {
            Work::Sample(p) => {
                let k = (p.model.clone(), p.method);
                if executing.as_ref() == Some(&k) || !placement.eligible(&k.0, thief) {
                    None
                } else {
                    Some(k)
                }
            }
            Work::Eval { .. } => None,
        });
        if let Some(key) = key {
            let mut moved: Vec<PendingSample> = Vec::new();
            take_group_arrivals(&mut st.queues[v], &key, &mut moved);
            if !moved.is_empty() {
                let jobs: usize = moved.iter().map(|p| p.n).sum();
                moved[0].group.worker.store(thief, Ordering::SeqCst);
                loads[v].fetch_sub(jobs, Ordering::SeqCst);
                loads[thief].fetch_add(jobs, Ordering::SeqCst);
                for p in moved {
                    st.queues[thief].push_back(Work::Sample(p));
                }
                return true;
            }
        }
        let eval_pos = st.queues[v].iter().position(|it| matches!(it, Work::Eval { model, .. } if placement.eligible(model, thief)));
        if let Some(eval) = eval_pos.and_then(|pos| st.queues[v].remove(pos)) {
            loads[v].fetch_sub(EVAL_LOAD, Ordering::SeqCst);
            loads[thief].fetch_add(EVAL_LOAD, Ordering::SeqCst);
            st.queues[thief].push_back(eval);
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::ReplicateAll;

    fn sample(model: &str, method: Method, n: usize, widx: usize, routes: &mut BTreeMap<GroupKey, Arc<GroupSlot>>) -> Work {
        let group = Arc::clone(
            routes
                .entry((model.to_string(), method))
                .or_insert_with(|| Arc::new(GroupSlot { worker: AtomicUsize::new(widx), pending: AtomicUsize::new(0) })),
        );
        group.pending.fetch_add(n, Ordering::SeqCst);
        let reply = Reply::discard(); // replies are discarded in these unit tests
        let (model, admitted) = (model.to_string(), Instant::now());
        Work::Sample(PendingSample { model, method, n, seed: 0, return_samples: false, decode: false, reply, admitted, group })
    }

    fn queued_keys(q: &VecDeque<Work>) -> Vec<(String, Method)> {
        q.iter()
            .filter_map(|w| match w {
                Work::Sample(p) => Some((p.model.clone(), p.method)),
                Work::Eval { .. } => None,
            })
            .collect()
    }

    fn pool_state(workers: usize) -> PoolState {
        PoolState {
            queues: (0..workers).map(|_| VecDeque::new()).collect(),
            executing: vec![None; workers],
            routes: BTreeMap::new(),
            dead: vec![false; workers],
        }
    }

    #[test]
    fn steal_moves_whole_group_atomically_and_retargets_route() {
        // Victim (worker 0) queues two groups interleaved; the thief
        // (worker 1) must take the oldest non-executing group *whole*,
        // preserve arrival order, retarget its route, and move the load.
        let mut routes = BTreeMap::new();
        let mut st = pool_state(2);
        st.queues[0].push_back(sample("a", Method::Fpi, 2, 0, &mut routes));
        st.queues[0].push_back(sample("b", Method::Fpi, 3, 0, &mut routes));
        st.queues[0].push_back(sample("a", Method::Fpi, 1, 0, &mut routes));
        let loads = vec![Arc::new(AtomicUsize::new(6)), Arc::new(AtomicUsize::new(0))];
        assert!(steal_group(&mut st, 1, &loads, &ReplicateAll));
        // Group "a" (the oldest) moved whole: both its requests, in order.
        assert_eq!(queued_keys(&st.queues[1]), vec![("a".to_string(), Method::Fpi), ("a".to_string(), Method::Fpi)]);
        assert_eq!(queued_keys(&st.queues[0]), vec![("b".to_string(), Method::Fpi)]);
        assert_eq!(routes[&("a".to_string(), Method::Fpi)].worker.load(Ordering::SeqCst), 1, "route must follow the stolen group");
        assert_eq!(routes[&("b".to_string(), Method::Fpi)].worker.load(Ordering::SeqCst), 0, "unstolen route must not move");
        assert_eq!(loads[0].load(Ordering::SeqCst), 3);
        assert_eq!(loads[1].load(Ordering::SeqCst), 3);
    }

    #[test]
    fn steal_skips_executing_groups() {
        // The only queued group on the victim is the one it is executing
        // (mid-flight arrivals owned by its live schedule): no steal. A
        // second, non-executing group is fair game.
        let mut routes = BTreeMap::new();
        let mut st = pool_state(2);
        st.queues[0].push_back(sample("a", Method::Fpi, 2, 0, &mut routes));
        st.executing[0] = Some(("a".to_string(), Method::Fpi));
        let loads = vec![Arc::new(AtomicUsize::new(2)), Arc::new(AtomicUsize::new(0))];
        assert!(!steal_group(&mut st, 1, &loads, &ReplicateAll), "executing group must not be stolen");
        assert_eq!(st.queues[0].len(), 1);
        st.queues[0].push_back(sample("b", Method::Zeros, 1, 0, &mut routes));
        assert!(steal_group(&mut st, 1, &loads, &ReplicateAll), "queued group behind an executing one is stealable");
        assert_eq!(queued_keys(&st.queues[1]), vec![("b".to_string(), Method::Zeros)]);
        assert_eq!(queued_keys(&st.queues[0]), vec![("a".to_string(), Method::Fpi)]);
    }

    #[test]
    fn steal_prefers_most_loaded_victim_and_needs_queued_work() {
        let mut routes = BTreeMap::new();
        let mut st = pool_state(3);
        let loads = vec![Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(1)), Arc::new(AtomicUsize::new(9))];
        assert!(!steal_group(&mut st, 0, &loads, &ReplicateAll), "nothing queued, nothing to steal");
        st.queues[1].push_back(sample("a", Method::Fpi, 1, 1, &mut routes));
        st.queues[2].push_back(sample("b", Method::Fpi, 9, 2, &mut routes));
        assert!(steal_group(&mut st, 0, &loads, &ReplicateAll));
        assert_eq!(queued_keys(&st.queues[0]), vec![("b".to_string(), Method::Fpi)], "steal must come from the most-loaded queue");
    }

    #[test]
    fn steal_falls_through_to_lighter_victims_and_evals() {
        // The heaviest victim's only queued group is executing; the thief
        // must fall through to the lighter victim's free group rather
        // than give up (work conservation). Once only an eval remains
        // queued anywhere, that moves too — evals are not sticky.
        let mut routes = BTreeMap::new();
        let mut st = pool_state(3);
        st.queues[1].push_back(sample("hot", Method::Fpi, 9, 1, &mut routes));
        st.executing[1] = Some(("hot".to_string(), Method::Fpi));
        st.queues[2].push_back(sample("cold", Method::Fpi, 1, 2, &mut routes));
        let loads = vec![Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(9)), Arc::new(AtomicUsize::new(1))];
        assert!(steal_group(&mut st, 0, &loads, &ReplicateAll), "a lighter victim with a free group must still be robbed");
        assert_eq!(queued_keys(&st.queues[0]), vec![("cold".to_string(), Method::Fpi)]);
        assert_eq!(st.queues[2].len(), 0);
        // Only the executing group's arrivals and an eval remain: the
        // eval is the one stealable item.
        st.queues[1].push_back(Work::Eval { model: "hot".into(), reply: Reply::discard(), admitted: Instant::now() });
        assert!(steal_group(&mut st, 2, &loads, &ReplicateAll), "a queued eval behind an executing group is stealable");
        assert!(matches!(st.queues[2].front(), Some(Work::Eval { .. })), "the eval must have moved to the thief");
        assert_eq!(st.queues[1].len(), 1, "the executing group's queued request must stay");
    }

    /// Test placement: `model` may only live on `worker`; everything
    /// else replicates anywhere.
    struct PinOne {
        model: &'static str,
        worker: usize,
    }

    impl PlacementPolicy for PinOne {
        fn name(&self) -> &'static str {
            "pin-one"
        }
        fn eligible(&self, model: &str, worker: usize) -> bool {
            model != self.model || worker == self.worker
        }
    }

    #[test]
    fn steal_respects_group_eligibility() {
        // THE steal-eligibility gate: the victim's oldest queued group is
        // pinned away from the thief, so the thief must skip it and take
        // the next hostable group instead — and with nothing hostable at
        // all, steal nothing rather than strand a pinned group off its
        // worker subset.
        let placement = PinOne { model: "pinned", worker: 0 };
        let mut routes = BTreeMap::new();
        let mut st = pool_state(2);
        st.queues[0].push_back(sample("pinned", Method::Fpi, 4, 0, &mut routes));
        st.queues[0].push_back(sample("free", Method::Fpi, 1, 0, &mut routes));
        let loads = vec![Arc::new(AtomicUsize::new(5)), Arc::new(AtomicUsize::new(0))];
        assert!(steal_group(&mut st, 1, &loads, &placement), "the hostable group behind the pinned one must still move");
        assert_eq!(queued_keys(&st.queues[1]), vec![("free".to_string(), Method::Fpi)]);
        assert_eq!(queued_keys(&st.queues[0]), vec![("pinned".to_string(), Method::Fpi)], "the pinned group must stay home");
        assert_eq!(routes[&("pinned".to_string(), Method::Fpi)].worker.load(Ordering::SeqCst), 0);
        assert!(!steal_group(&mut st, 1, &loads, &placement), "nothing hostable left: the thief must come away empty");
    }

    #[test]
    fn steal_respects_eval_eligibility() {
        // An eval needs the model's engine too: a thief outside the
        // model's pin set must leave the eval queued for an eligible
        // worker.
        let placement = PinOne { model: "pinned", worker: 0 };
        let mut st = pool_state(3);
        st.queues[0].push_back(Work::Eval { model: "pinned".into(), reply: Reply::discard(), admitted: Instant::now() });
        let loads = vec![Arc::new(AtomicUsize::new(8)), Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(0))];
        assert!(!steal_group(&mut st, 1, &loads, &placement), "an ineligible thief must not steal the eval");
        assert_eq!(st.queues[0].len(), 1, "the eval must stay queued");
        // A second eval for an unpinned model is fair game.
        st.queues[0].push_back(Work::Eval { model: "free".into(), reply: Reply::discard(), admitted: Instant::now() });
        assert!(steal_group(&mut st, 1, &loads, &placement), "the eligible eval behind it must still move");
        assert!(matches!(st.queues[1].front(), Some(Work::Eval { model, .. }) if model == "free"));
        assert_eq!(st.queues[0].len(), 1, "the pinned eval must stay");
    }
}
