//! TCP serving: line-delimited JSON over a sharded nonblocking
//! connection plane, dispatched to a sharded pool of engine workers with
//! elastic batching, work stealing, and an explicit model-placement
//! plane.
//!
//! Topology:
//!
//! ```text
//! clients ──TCP──▶ connection plane (cfg.conn_threads event-loop
//!                  shards, conn.rs): shard 0 accepts and round-robins
//!                  sockets; each shard owns its connections outright
//!                  and learns readiness from substrate::readiness
//!                  (epoll on Linux, portable scan elsewhere), with
//!                  per-connection buffers, pipelining by request id,
//!                  and edge hardening
//!                      │ (Request, Reply) over mpsc    ▲ per-shard
//!                      ▼                               │ completions
//!                                                      │ (engine replies
//!                                                      │  + stream events)
//!                dispatcher: answers ping/info/metrics, routes each
//!                (model, method) batching group to the least-loaded
//!                *eligible* engine worker (ties: engine already warm,
//!                then fewest loaded engines, then round-robin; sticky
//!                while the group has jobs in flight)
//!                      │ shared work pool (per-worker queues + routing
//!                      │ table under one lock)
//!        ┌─────────────┼─────────────┐
//!        ▼             ▼             ▼
//!   engine worker 0  worker 1 …  worker N-1   (cfg.engine_threads)
//!   each: Router + Metrics + admission-keyed batching window
//!        │                           ▲
//!        └── executing group absorbs │ idle workers steal whole queued
//!            its own live arrivals   │ groups they can host
//! ```
//!
//! PJRT handles are thread-affine, so every worker owns its own `Router`
//! and engines load lazily on the worker that needs them. *Which* workers
//! may own which models is the placement plane's call
//! ([`crate::coordinator::placement`], `cfg.placement`): replicate-all
//! (the default — every worker eligible for everything, bit-identical to
//! the pre-placement fleet), per-model worker pins (manifest `"pin"`
//! field / `--pin model=0,2`), or a per-worker engine cap with LRU
//! eviction (`--max-engines`). Eligibility applies everywhere a model
//! lands on a worker: fresh-group routing, dead-worker re-homing, eval
//! routing, and group stealing.
//!
//! Three mechanisms keep the fleet work-conserving on top of sharding:
//!
//! * **Live-queue elasticity** — a group being executed keeps absorbing
//!   its own mid-flight arrivals: the worker's schedule polls the shared
//!   queue between ARM passes ([`crate::coordinator::engine::Engine::sample_elastic`]),
//!   up-shifts onto a larger exported batch when the queue deepens, and
//!   answers each request the moment its last job converges — instead of
//!   stashing arrivals for the next batching window. How the schedule
//!   *sizes* those batches and *which* arrivals it absorbs are pluggable
//!   policies ([`crate::coordinator::policy`]): `cfg.policy`/`cfg.slo`
//!   select occupancy-first, latency-lean, or SLO-hybrid sizing — the
//!   SLO hybrid's cold-start projections seeded from the server-level
//!   [`ConvergenceBook`] — and `cfg.admission` gates absorption
//!   (age-based oldest-first fairness by default, so a hot group cannot
//!   starve queued neighbours).
//! * **Group stealing** — a worker whose queue drains pulls a whole
//!   queued `(model, method)` group it is eligible to host from the
//!   most-loaded worker. Groups move atomically (every queued request at
//!   once, order preserved, route retargeted under the pool lock), so
//!   sticky batching, PJRT thread-affinity, and placement pins survive
//!   the migration.
//! * **Admission-keyed batching windows** — windows are sized off each
//!   request's *admission* time, not the window's opening: a request
//!   queued behind k other groups executes as soon as a worker reaches
//!   it, instead of re-paying `cfg.max_wait` per preceding group.
//!
//! Exactness is untouched by any of it: per-job noise is keyed by
//! `(seed, job index within the request)` — never by worker, slot,
//! batch size, placement, or arrival time — so samples are bitwise
//! identical at any `engine_threads`/`elastic`/`steal`/`placement`
//! setting (see `rust/tests/server_test.rs`).

mod client;
pub(crate) mod conn;
mod feed;
pub(crate) mod pool;
mod worker;

pub use client::Client;

use crate::coordinator::config::ServeConfig;
use crate::coordinator::engine::catalog_value;
use crate::coordinator::metrics::{Metrics, WorkerGauges};
use crate::coordinator::placement::{placement_for, PlacementPolicy};
use crate::coordinator::policy::ConvergenceBook;
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::router::Router;
use crate::coordinator::server::conn::EdgeStats;
use crate::coordinator::server::pool::{GroupSlot, PendingSample, Pool, PoolState, Work, EVAL_LOAD};
use crate::coordinator::server::worker::{worker_loop, WorkerHandle, WorkerShared};
use crate::runtime::artifact::Manifest;
use crate::runtime::step::CatalogStats;
use crate::substrate::json::Value;
use crate::substrate::readiness::Waker;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

pub(crate) enum Msg {
    Req(Request, pool::Reply),
    Shutdown,
}

/// Handle to a running server (for tests and the serving demo).
pub struct ServerHandle {
    pub addr: SocketAddr,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    dispatch_join: Option<std::thread::JoinHandle<()>>,
    conn_joins: Vec<std::thread::JoinHandle<()>>,
    /// Per-shard readiness wakers: fired after `stop` is set so every
    /// shard's `wait` returns immediately instead of sleeping out its
    /// idle tick.
    conn_wakers: Vec<Arc<dyn Waker>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        for w in &self.conn_wakers {
            w.wake();
        }
        if let Some(j) = self.dispatch_join.take() {
            let _ = j.join();
        }
        for j in self.conn_joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        for w in &self.conn_wakers {
            w.wake();
        }
    }
}

/// Bind `cfg.addr` (use port 0 for ephemeral) and serve in background
/// threads. The returned handle reports the bound address. Fails fast if
/// the config is invalid, the manifest is unreadable, or the placement
/// policy does not resolve against them (unknown pinned model,
/// out-of-range worker index).
pub fn spawn(manifest_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let manifest = Manifest::load(&manifest_dir).context("loading manifest for serving")?;
    let placement = placement_for(&cfg.placement, &manifest, cfg.engine_threads).context("resolving placement policy")?;
    let book = Arc::new(ConvergenceBook::new());
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Msg>();

    // The shared work pool, then one engine worker thread per shard: each
    // owns a Router (PJRT state) + Metrics; the placement policy decides
    // which engines it may end up owning.
    let loads: Vec<Arc<AtomicUsize>> = (0..cfg.engine_threads).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let pool = Arc::new(Pool {
        state: Mutex::new(PoolState {
            queues: (0..cfg.engine_threads).map(|_| VecDeque::new()).collect(),
            executing: vec![None; cfg.engine_threads],
            routes: BTreeMap::new(),
            dead: vec![false; cfg.engine_threads],
        }),
        cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
        loads: loads.clone(),
    });
    let mut workers = Vec::with_capacity(cfg.engine_threads);
    for w in 0..cfg.engine_threads {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let engines_loaded = Arc::new(AtomicUsize::new(0));
        let engine_loads = Arc::new(AtomicUsize::new(0));
        let evictions = Arc::new(AtomicUsize::new(0));
        let resident = Arc::new(Mutex::new(Vec::new()));
        let catalog = Arc::new(Mutex::new(CatalogStats::default()));
        let shared = WorkerShared {
            load: Arc::clone(&loads[w]),
            metrics: Arc::clone(&metrics),
            engines_loaded: Arc::clone(&engines_loaded),
            engine_loads: Arc::clone(&engine_loads),
            evictions: Arc::clone(&evictions),
            resident: Arc::clone(&resident),
            catalog: Arc::clone(&catalog),
            book: Arc::clone(&book),
            placement: Arc::clone(&placement),
        };
        let man = manifest.clone();
        let cfg2 = cfg.clone();
        let pool2 = Arc::clone(&pool);
        let join = std::thread::Builder::new()
            .name(format!("predsamp-engine-{w}"))
            .spawn(move || worker_loop(Router::with_variants(man, cfg2.variants), cfg2, w, pool2, shared))?;
        workers.push(WorkerHandle { load: Arc::clone(&loads[w]), metrics, engines_loaded, engine_loads, evictions, resident, catalog, join });
    }

    // Dispatcher: owns the request channel and the group routing table.
    let edge = Arc::new(EdgeStats::new(cfg.readiness.resolve().label(), cfg.conn_threads));
    let pool2 = Arc::clone(&pool);
    let placement2 = Arc::clone(&placement);
    let book2 = Arc::clone(&book);
    let edge2 = Arc::clone(&edge);
    let dispatch_join = std::thread::Builder::new()
        .name("predsamp-dispatch".into())
        .spawn(move || dispatch_loop(manifest, workers, pool2, rx, placement2, book2, edge2))?;

    // The connection plane: `cfg.conn_threads` event-loop shards, each
    // owning its connections, readiness source, and completion channel;
    // shard 0 accepts and round-robins sockets to the fleet.
    let (conn_joins, conn_wakers) = conn::spawn_shards(listener, &cfg, &tx, &stop, &edge).context("spawning connection shards")?;

    Ok(ServerHandle { addr, tx, stop, dispatch_join: Some(dispatch_join), conn_joins, conn_wakers })
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

/// Least-loaded live worker *eligible for `model`* under the placement
/// policy. Ties break toward workers with the model's engine already
/// resident (a warm worker serves the group without paying a redundant
/// lazy engine load), then the fewest loaded engines (an idle fleet
/// spreads lazy loads instead of serializing them on worker 0), then
/// round-robin among exact ties. `None` when no eligible worker is
/// alive.
fn route_worker(workers: &[WorkerHandle], rr: &mut usize, dead: &[bool], placement: &dyn PlacementPolicy, model: &str) -> Option<usize> {
    let costs: Vec<(usize, (usize, usize, usize))> = workers
        .iter()
        .enumerate()
        .filter(|&(i, _)| !dead[i] && placement.eligible(model, i))
        .map(|(i, w)| {
            let cold = if w.hosts(model) { 0 } else { 1 };
            (i, (w.load.load(Ordering::SeqCst), cold, w.engines_loaded.load(Ordering::SeqCst)))
        })
        .collect();
    let best = costs.iter().map(|&(_, c)| c).min()?;
    let ties: Vec<usize> = costs.iter().filter(|&&(_, c)| c == best).map(|&(i, _)| i).collect();
    let pick = ties[*rr % ties.len()];
    *rr += 1;
    Some(pick)
}

/// Why routing found no worker: every worker died, or the live ones are
/// all ineligible for the model under the placement policy.
fn route_error(model: &str, dead: &[bool]) -> String {
    if dead.iter().all(|&d| d) {
        "engine workers unavailable".to_string()
    } else {
        format!("no eligible engine worker for model {model:?} under the placement policy")
    }
}

fn dispatch_loop(
    manifest: Manifest,
    workers: Vec<WorkerHandle>,
    pool: Arc<Pool>,
    rx: mpsc::Receiver<Msg>,
    placement: Arc<dyn PlacementPolicy>,
    book: Arc<ConvergenceBook>,
    edge: Arc<EdgeStats>,
) {
    let started = Instant::now();
    let mut disp = Metrics::new();
    let mut rr = 0usize; // round-robin cursor for routing ties
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        match msg {
            Msg::Shutdown => break,
            Msg::Req(req, reply) => {
                disp.record_request();
                match req {
                    Request::Ping => {
                        let _ = reply.send(protocol::ok(vec![("pong", Value::Bool(true))]));
                    }
                    Request::Info => {
                        let _ = reply.send(info_response(&manifest, &workers, &*placement));
                    }
                    Request::Metrics => {
                        let _ = reply.send(metrics_response(&disp, &workers, started.elapsed().as_secs_f64(), &*placement, &book, &edge));
                    }
                    Request::Eval { model } => {
                        // Evals need the model's engine too, so they route
                        // by eligibility like any group — the old "any
                        // worker owns a full Router" shortcut does not
                        // survive pinning.
                        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
                        let Some(w) = route_worker(&workers, &mut rr, &st.dead, &*placement, &model) else {
                            let msg = route_error(&model, &st.dead);
                            drop(st);
                            disp.record_error();
                            let _ = reply.send(protocol::err(&msg));
                            continue;
                        };
                        workers[w].load.fetch_add(EVAL_LOAD, Ordering::SeqCst);
                        st.queues[w].push_back(Work::Eval { model, reply, admitted: Instant::now() });
                        drop(st);
                        pool.cv.notify_all();
                    }
                    Request::Sample { model, method, n, seed, return_samples, decode } => {
                        // Route under the pool lock: a sticky group follows
                        // its (possibly stolen) worker, a fresh group goes
                        // to the least-loaded eligible one, and no steal
                        // can interleave between the route read and the
                        // push.
                        let key = (model.clone(), method);
                        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
                        let sticky = match st.routes.get(&key) {
                            Some(g) if g.pending.load(Ordering::SeqCst) > 0 => Some(Arc::clone(g)),
                            _ => None,
                        };
                        let group = match sticky {
                            Some(g) => g,
                            None => match route_worker(&workers, &mut rr, &st.dead, &*placement, &key.0) {
                                Some(w) => {
                                    let g = Arc::new(GroupSlot { worker: AtomicUsize::new(w), pending: AtomicUsize::new(0) });
                                    st.routes.insert(key.clone(), Arc::clone(&g));
                                    g
                                }
                                None => {
                                    let msg = route_error(&key.0, &st.dead);
                                    drop(st);
                                    disp.record_error();
                                    let _ = reply.send(protocol::err(&msg));
                                    continue;
                                }
                            },
                        };
                        let mut widx = group.worker.load(Ordering::SeqCst);
                        if st.dead[widx] {
                            // The sticky worker died: re-home the group on
                            // an eligible survivor.
                            match route_worker(&workers, &mut rr, &st.dead, &*placement, &key.0) {
                                Some(w) => {
                                    group.worker.store(w, Ordering::SeqCst);
                                    widx = w;
                                }
                                None => {
                                    let msg = route_error(&key.0, &st.dead);
                                    drop(st);
                                    disp.record_error();
                                    let _ = reply.send(protocol::err(&msg));
                                    continue;
                                }
                            }
                        }
                        group.pending.fetch_add(n, Ordering::SeqCst);
                        workers[widx].load.fetch_add(n, Ordering::SeqCst);
                        let ps = PendingSample { model, method, n, seed, return_samples, decode, reply, admitted: Instant::now(), group };
                        st.queues[widx].push_back(Work::Sample(ps));
                        if st.routes.len() > 64 {
                            st.routes.retain(|_, g| g.pending.load(Ordering::SeqCst) > 0);
                        }
                        drop(st);
                        pool.cv.notify_all();
                    }
                }
            }
        }
    }
    pool.shutdown.store(true, Ordering::SeqCst);
    pool.cv.notify_all();
    for w in workers {
        let _ = w.join.join();
    }
}

fn info_response(manifest: &Manifest, workers: &[WorkerHandle], placement: &dyn PlacementPolicy) -> String {
    let models: Vec<Value> = manifest
        .models
        .values()
        .map(|m| {
            Value::obj(vec![
                ("name", Value::str(m.name.clone())),
                ("dim", Value::num(m.dim as f64)),
                ("categories", Value::num(m.categories as f64)),
                ("kind", Value::str(format!("{:?}", m.kind))),
                ("bpd", Value::num(m.bpd)),
                ("mock", Value::Bool(m.mock.is_some())),
                (
                    "eligible_workers",
                    Value::Arr((0..workers.len()).filter(|&w| placement.eligible(&m.name, w)).map(|w| Value::num(w as f64)).collect()),
                ),
            ])
        })
        .collect();
    let warr: Vec<Value> = workers
        .iter()
        .enumerate()
        .map(|(i, w)| {
            Value::obj(vec![
                ("id", Value::num(i as f64)),
                ("queue_depth", Value::num(w.load.load(Ordering::SeqCst) as f64)),
                ("engines_loaded", Value::num(w.engines_loaded.load(Ordering::SeqCst) as f64)),
                ("resident_models", Value::Arr(w.resident_models().into_iter().map(Value::str).collect())),
            ])
        })
        .collect();
    protocol::ok(vec![
        ("models", Value::Arr(models)),
        ("engine_workers", Value::num(workers.len() as f64)),
        ("placement", Value::str(placement.name())),
        ("workers", Value::Arr(warr)),
    ])
}

fn metrics_response(disp: &Metrics, workers: &[WorkerHandle], uptime_s: f64, placement: &dyn PlacementPolicy, book: &ConvergenceBook, edge: &EdgeStats) -> String {
    let mut total = Metrics::new();
    total.merge(disp);
    let mut warr = Vec::with_capacity(workers.len());
    let (mut engine_loads, mut evictions) = (0usize, 0usize);
    let mut cat_total = CatalogStats::default();
    for (i, w) in workers.iter().enumerate() {
        let cat = w.catalog_totals();
        let gauges = WorkerGauges {
            id: i,
            queue_depth: w.load.load(Ordering::SeqCst),
            engines_loaded: w.engines_loaded.load(Ordering::SeqCst),
            engine_loads: w.engine_loads.load(Ordering::SeqCst),
            evictions: w.evictions.load(Ordering::SeqCst),
            variant_hits: cat.variant_hits,
            full_shape_fallbacks: cat.full_shape_fallbacks,
            variant_positions: cat.positions_evaluated,
            resident: w.resident_models(),
        };
        engine_loads += gauges.engine_loads;
        evictions += gauges.evictions;
        cat_total.merge(&cat);
        let m = w.metrics.lock().unwrap_or_else(|e| e.into_inner());
        total.merge(&m);
        warr.push(m.worker_value(&gauges));
    }
    let Value::Obj(mut obj) = total.snapshot() else {
        unreachable!("snapshot is an object")
    };
    obj.insert("engine_workers".into(), Value::num(workers.len() as f64));
    obj.insert("uptime_s".into(), Value::num(uptime_s));
    obj.insert("placement".into(), Value::str(placement.name()));
    obj.insert("engine_loads".into(), Value::num(engine_loads as f64));
    obj.insert("evictions".into(), Value::num(evictions as f64));
    obj.insert("variants".into(), catalog_value(&cat_total));
    let mut conv = BTreeMap::new();
    for (key, est, n) in book.entries() {
        conv.insert(
            key,
            Value::obj(vec![
                ("passes_per_job", Value::num(est.passes_per_job)),
                ("pass_secs", Value::num(est.pass_secs)),
                ("schedules", Value::num(n as f64)),
            ]),
        );
    }
    obj.insert("convergence".into(), Value::Obj(conv));
    obj.insert("edge".into(), edge.value());
    obj.insert("workers".into(), Value::Arr(warr));
    protocol::ok(vec![("metrics", Value::Obj(obj))])
}
