//! The connection plane: `conn_threads` event-loop shards, each owning
//! its connections' sockets, buffers, token buckets, and in-flight maps
//! outright — no shared state and no locks on the hot path. Shard 0
//! owns the listener and round-robins accepted sockets to the shards
//! over per-shard handoff channels; engine replies travel each shard's
//! own completion channel. Which sockets a shard services per tick
//! comes from a [`ReadinessSource`] (`substrate::readiness`):
//!
//! * `scan` — every registered socket every tick, bit-for-bit the
//!   pre-sharding nonblocking scan (portable fallback);
//! * `epoll` (Linux, the `auto` default there) — only sockets the
//!   kernel flagged, edge-triggered with explicit rearm, so a tick
//!   costs O(ready) instead of O(open connections). The shard's waker
//!   is an eventfd registered like any other fd: an engine completion
//!   interrupts the wait instantly instead of waiting out the idle
//!   tick.
//!
//! Per-connection state and the request state machine are unchanged
//! from the single-threaded edge: per-connection read/write buffers,
//! multiple in-flight requests per connection (pipelined by request
//! `id`), and replies routed back through the owning shard's completion
//! channel into per-connection outbound queues. Delivery semantics are
//! shard-invariant — a connection lives its whole life on one shard,
//! and completions are FIFO per shard — so bitwise exactness holds
//! under every `{scan, epoll} × conn_threads` combination.
//!
//! Edge hardening lives here, all `ServeConfig` knobs:
//!
//! * `max_line_len` — enforced *while* buffering, so an endless line is
//!   rejected long before it can exhaust memory;
//! * `outbound_cap` — read-side backpressure: a connection whose
//!   unflushed output exceeds the cap stops being *read* until the peer
//!   drains it, without stalling any other connection;
//! * `rate_limit` — per-connection token bucket (one-second burst);
//! * `max_conns` — excess accepts get an error line and are closed
//!   (enforced at accept against the fleet-wide open-connection gauge);
//! * `reply_timeout` — an unanswered request fails to the client, and
//!   the engine's eventual reply is logged and counted as orphaned
//!   rather than silently dropped. The timeout scan is deadline-gated:
//!   each shard tracks its earliest pending deadline and skips the scan
//!   entirely until it is due.

use crate::coordinator::config::ServeConfig;
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::server::pool::{Completion, CompletionTx, Reply};
use crate::coordinator::server::Msg;
use crate::substrate::json::Value;
use crate::substrate::readiness::{self, Interest, ReadinessSource, Token, Waker};
use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Idle tick: the longest a shard blocks in `wait` when nothing is
/// ready. Completions and handoffs wake it immediately through the
/// shard waker; anything else waits at most one tick.
const TICK: Duration = Duration::from_millis(5);

/// Readiness token for the listener on the shard that owns it.
/// (`Token::MAX` itself is reserved by the readiness source's waker.)
const LISTENER_TOKEN: Token = Token::MAX - 1;

/// Per-shard connection-plane gauges, one entry per shard in the `edge`
/// metrics section.
#[derive(Default)]
pub(crate) struct ShardStats {
    /// Connections currently owned by this shard.
    pub(crate) conns: AtomicUsize,
    /// Loop iterations (each one `wait` + service pass).
    pub(crate) ticks: AtomicU64,
    /// Connection readiness events reported across all ticks. Divided
    /// by `ticks` this is the per-tick edge cost: ≈ open connections
    /// under `scan`, ≈ the active fraction under `epoll`.
    pub(crate) ready_events: AtomicU64,
    /// Waker fires (engine completions, socket handoffs, shutdown).
    pub(crate) wakeups: AtomicU64,
}

/// Connection-plane counters, surfaced as the `edge` section of the
/// `metrics` response: fleet-wide totals plus per-shard gauges and the
/// resolved readiness-backend label.
pub(crate) struct EdgeStats {
    /// Resolved readiness backend label (`"scan"` / `"epoll"`).
    pub(crate) backend: &'static str,
    pub(crate) open_conns: AtomicUsize,
    pub(crate) total_conns: AtomicU64,
    pub(crate) bytes_in: AtomicU64,
    pub(crate) bytes_out: AtomicU64,
    pub(crate) overlimit_rejections: AtomicU64,
    pub(crate) ratelimit_rejections: AtomicU64,
    pub(crate) conn_cap_rejections: AtomicU64,
    pub(crate) reply_timeouts: AtomicU64,
    pub(crate) orphaned_replies: AtomicU64,
    pub(crate) shards: Vec<ShardStats>,
}

impl EdgeStats {
    pub(crate) fn new(backend: &'static str, conn_threads: usize) -> EdgeStats {
        EdgeStats {
            backend,
            open_conns: AtomicUsize::new(0),
            total_conns: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            overlimit_rejections: AtomicU64::new(0),
            ratelimit_rejections: AtomicU64::new(0),
            conn_cap_rejections: AtomicU64::new(0),
            reply_timeouts: AtomicU64::new(0),
            orphaned_replies: AtomicU64::new(0),
            shards: (0..conn_threads.max(1)).map(|_| ShardStats::default()).collect(),
        }
    }

    pub(crate) fn value(&self) -> Value {
        let shards: Vec<Value> = self
            .shards
            .iter()
            .map(|s| {
                let ticks = s.ticks.load(Ordering::SeqCst);
                let ready = s.ready_events.load(Ordering::SeqCst);
                Value::obj(vec![
                    ("conns", Value::num(s.conns.load(Ordering::SeqCst) as f64)),
                    ("ticks", Value::num(ticks as f64)),
                    ("ready_events", Value::num(ready as f64)),
                    ("ready_per_tick", Value::num(ready as f64 / ticks.max(1) as f64)),
                    ("wakeups", Value::num(s.wakeups.load(Ordering::SeqCst) as f64)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("readiness", Value::str(self.backend)),
            ("conn_threads", Value::num(self.shards.len() as f64)),
            ("open_conns", Value::num(self.open_conns.load(Ordering::SeqCst) as f64)),
            ("total_conns", Value::num(self.total_conns.load(Ordering::SeqCst) as f64)),
            ("bytes_in", Value::num(self.bytes_in.load(Ordering::SeqCst) as f64)),
            ("bytes_out", Value::num(self.bytes_out.load(Ordering::SeqCst) as f64)),
            ("overlimit_rejections", Value::num(self.overlimit_rejections.load(Ordering::SeqCst) as f64)),
            ("ratelimit_rejections", Value::num(self.ratelimit_rejections.load(Ordering::SeqCst) as f64)),
            ("conn_cap_rejections", Value::num(self.conn_cap_rejections.load(Ordering::SeqCst) as f64)),
            ("reply_timeouts", Value::num(self.reply_timeouts.load(Ordering::SeqCst) as f64)),
            ("orphaned_replies", Value::num(self.orphaned_replies.load(Ordering::SeqCst) as f64)),
            ("shards", Value::Arr(shards)),
        ])
    }
}

/// Shard waker that counts fires into its shard's `wakeups` gauge
/// before delegating to the readiness source's real waker.
struct CountingWaker {
    inner: Arc<dyn Waker>,
    edge: Arc<EdgeStats>,
    shard: usize,
}

impl Waker for CountingWaker {
    fn wake(&self) {
        self.edge.shards[self.shard].wakeups.fetch_add(1, Ordering::Relaxed);
        self.inner.wake();
    }
}

/// Per-connection request rate limiter: classic token bucket with a
/// one-second burst (`rate` tokens), `rate` == 0 disabling the limit.
struct TokenBucket {
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rate: u32, now: Instant) -> TokenBucket {
        TokenBucket { rate: rate as f64, tokens: rate as f64, last: now }
    }

    fn allow(&mut self, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.rate);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Split one complete line off the front of `buf`, stripping the `\n`
/// terminator and, when present, a preceding `\r` (CRLF clients).
fn take_line(buf: &mut Vec<u8>) -> Option<Vec<u8>> {
    let pos = buf.iter().position(|&b| b == b'\n')?;
    let mut line: Vec<u8> = buf.drain(..=pos).collect();
    line.pop();
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    Some(line)
}

/// One client connection's event-loop state.
struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet split into complete request lines.
    rbuf: Vec<u8>,
    /// Bytes queued for the peer; `wpos..` is the unflushed tail.
    wbuf: Vec<u8>,
    wpos: usize,
    bucket: TokenBucket,
    /// Requests dispatched from this connection and not yet answered
    /// (or timed out) — a half-closed connection stays open for these.
    inflight: usize,
    /// Peer sent EOF: stop reading, finish delivering, then close.
    read_closed: bool,
    /// Hard close (protocol violation / shutdown): flush `wbuf`, drop.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream, cfg: &ServeConfig, now: Instant) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            bucket: TokenBucket::new(cfg.rate_limit, now),
            inflight: 0,
            read_closed: false,
            closing: false,
        }
    }

    /// Unflushed outbound bytes (what backpressure measures).
    fn outstanding(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn push_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }
}

/// One request awaiting its engine reply: who asked, the reply deadline,
/// and whether the deadline already fired (late replies then count as
/// orphaned instead of reaching a client that moved on).
struct Inflight {
    conn: u64,
    id: Option<u64>,
    deadline: Instant,
    timed_out: bool,
}

/// Everything one shard loop is handed at spawn. Built by
/// [`spawn_shards`]; consumed by [`shard_loop`].
pub(crate) struct ShardCtx {
    pub(crate) shard: usize,
    pub(crate) cfg: ServeConfig,
    /// Request channel into the dispatcher (shared by all shards).
    pub(crate) tx: mpsc::Sender<Msg>,
    /// Receiving end of this shard's completion channel.
    pub(crate) crx: mpsc::Receiver<Completion>,
    /// Its sender half (cloned into every `Reply` this shard creates).
    pub(crate) ctx: CompletionTx,
    /// The listener; `Some` on exactly one shard (shard 0).
    pub(crate) listener: Option<TcpListener>,
    /// Sockets round-robined to this shard by the listener shard.
    pub(crate) handoff_rx: mpsc::Receiver<TcpStream>,
    /// All shards' handoff senders + wakers; non-empty only on the
    /// listener shard.
    pub(crate) handoffs: Vec<(mpsc::Sender<TcpStream>, Arc<dyn Waker>)>,
    pub(crate) source: Box<dyn ReadinessSource>,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) edge: Arc<EdgeStats>,
}

/// Raw fd for readiness registration. Only the epoll backend reads it,
/// so the non-Unix placeholder never reaches a syscall.
#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> readiness::RawFd {
    t.as_raw_fd()
}
#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> readiness::RawFd {
    -1
}

/// Spawn the sharded connection plane: `cfg.conn_threads` event-loop
/// threads, each with its own readiness source and completion channel.
/// Returns the shard join handles and the per-shard wakers (which
/// `ServerHandle::stop` fires so every shard notices shutdown at once).
pub(crate) fn spawn_shards(
    listener: TcpListener,
    cfg: &ServeConfig,
    tx: &mpsc::Sender<Msg>,
    stop: &Arc<AtomicBool>,
    edge: &Arc<EdgeStats>,
) -> std::io::Result<(Vec<std::thread::JoinHandle<()>>, Vec<Arc<dyn Waker>>)> {
    let n = cfg.conn_threads.max(1);
    let kind = cfg.readiness.resolve();
    let mut sources: Vec<Box<dyn ReadinessSource>> = Vec::with_capacity(n);
    let mut wakers: Vec<Arc<dyn Waker>> = Vec::with_capacity(n);
    for shard in 0..n {
        let source = readiness::source(kind)?;
        wakers.push(Arc::new(CountingWaker { inner: source.waker(), edge: Arc::clone(edge), shard }));
        sources.push(source);
    }
    let mut handoff_txs = Vec::with_capacity(n);
    let mut handoff_rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (htx, hrx) = mpsc::channel::<TcpStream>();
        handoff_txs.push(htx);
        handoff_rxs.push(hrx);
    }
    let handoffs: Vec<(mpsc::Sender<TcpStream>, Arc<dyn Waker>)> = handoff_txs.into_iter().zip(wakers.iter().cloned()).collect();
    let mut listener = Some(listener);
    let mut joins = Vec::with_capacity(n);
    for (shard, (source, handoff_rx)) in sources.into_iter().zip(handoff_rxs).enumerate() {
        let (ctx_tx, crx) = mpsc::channel::<Completion>();
        let sctx = ShardCtx {
            shard,
            cfg: cfg.clone(),
            tx: tx.clone(),
            crx,
            ctx: CompletionTx { tx: ctx_tx, waker: Arc::clone(&wakers[shard]) },
            listener: if shard == 0 { listener.take() } else { None },
            handoff_rx,
            handoffs: if shard == 0 { handoffs.clone() } else { Vec::new() },
            source,
            stop: Arc::clone(stop),
            edge: Arc::clone(edge),
        };
        joins.push(std::thread::Builder::new().name(format!("predsamp-conn-{shard}")).spawn(move || shard_loop(sctx))?);
    }
    Ok((joins, wakers))
}

/// Accept-side state on the listener-owning shard: round-robin cursor
/// over every shard's handoff channel (its own included, so adoption is
/// uniform).
struct Acceptor {
    listener: TcpListener,
    handoffs: Vec<(mpsc::Sender<TcpStream>, Arc<dyn Waker>)>,
    rr: usize,
}

impl Acceptor {
    /// Accept every pending connection (nonblocking). Over `max_conns`,
    /// the socket gets a best-effort error line and closes immediately;
    /// otherwise its `open_conns` slot is reserved here and the socket
    /// is handed to the next shard in rotation.
    fn accept_new(&mut self, cfg: &ServeConfig, edge: &EdgeStats) -> bool {
        let mut any = false;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    any = true;
                    edge.total_conns.fetch_add(1, Ordering::SeqCst);
                    let open = edge.open_conns.load(Ordering::SeqCst);
                    if open >= cfg.max_conns {
                        edge.conn_cap_rejections.fetch_add(1, Ordering::SeqCst);
                        log::warn!("rejecting connection from {peer}: {open} already open (max_conns)");
                        // Accepted sockets are blocking by default; one
                        // short error line fits any send buffer.
                        let mut s = stream;
                        let _ = s.write_all(protocol::err("connection limit reached").as_bytes());
                        let _ = s.write_all(b"\n");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    edge.open_conns.fetch_add(1, Ordering::SeqCst);
                    let target = self.rr % self.handoffs.len();
                    self.rr += 1;
                    let (htx, waker) = &self.handoffs[target];
                    if htx.send(stream).is_ok() {
                        waker.wake();
                    } else {
                        // Target shard already exited (shutdown race).
                        edge.open_conns.fetch_sub(1, Ordering::SeqCst);
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        }
        any
    }
}

struct Shard {
    idx: usize,
    cfg: ServeConfig,
    tx: mpsc::Sender<Msg>,
    ctx: CompletionTx,
    edge: Arc<EdgeStats>,
    conns: BTreeMap<u64, Conn>,
    inflight: BTreeMap<u64, Inflight>,
    /// Next connection id: starts at the shard index, steps by
    /// `conn_threads`, so ids are globally unique without coordination.
    next_conn: u64,
    /// Next in-flight sequence number (same striping; unique per shard
    /// is all correctness needs, globally unique helps the logs).
    next_seq: u64,
    /// Id stride == `conn_threads`.
    stride: u64,
    /// Lower bound on the earliest pending reply deadline; `None` means
    /// no request is in flight and the timeout scan can be skipped.
    next_deadline: Option<Instant>,
}

/// One shard's event loop. Owns its connections and the receiving ends
/// of its completion and handoff channels; the shard holding the
/// listener also accepts. Exits when `stop` is set, closing every owned
/// connection.
pub(crate) fn shard_loop(sctx: ShardCtx) {
    let ShardCtx { shard: idx, cfg, tx, crx, ctx, listener, handoff_rx, handoffs, mut source, stop, edge } = sctx;
    let stride = cfg.conn_threads.max(1) as u64;
    let mut acceptor = listener.map(|l| {
        if let Err(e) = source.register(raw_fd(&l), LISTENER_TOKEN, Interest::READ) {
            log::warn!("failed to register listener with {} readiness: {e}", source.backend());
        }
        Acceptor { listener: l, handoffs, rr: 0 }
    });
    let mut shard = Shard {
        idx,
        cfg,
        tx,
        ctx,
        edge: Arc::clone(&edge),
        conns: BTreeMap::new(),
        inflight: BTreeMap::new(),
        next_conn: idx as u64,
        next_seq: idx as u64,
        stride,
        next_deadline: None,
    };
    let mut ready: Vec<Token> = Vec::new();
    let mut dirty: Vec<u64> = Vec::new();
    let mut busy = true;
    while !stop.load(Ordering::SeqCst) {
        let timeout = if busy { Duration::ZERO } else { shard.idle_timeout(Instant::now()) };
        if source.wait(timeout, &mut ready).is_err() {
            // A broken readiness source would spin the loop; degrade to
            // a plain sleep tick and service everything we own.
            std::thread::sleep(TICK);
            ready.clear();
            ready.extend(shard.conns.keys().copied());
            if acceptor.is_some() {
                ready.push(LISTENER_TOKEN);
            }
        }
        let stats = &edge.shards[idx];
        stats.ticks.fetch_add(1, Ordering::Relaxed);
        busy = false;
        dirty.clear();
        let mut accept_ready = false;
        for &token in &ready {
            if token == LISTENER_TOKEN {
                accept_ready = true;
            } else {
                dirty.push(token);
            }
        }
        stats.ready_events.fetch_add(dirty.len() as u64, Ordering::Relaxed);
        if accept_ready {
            if let Some(a) = acceptor.as_mut() {
                busy |= a.accept_new(&shard.cfg, &edge);
                let _ = source.rearm(raw_fd(&a.listener), LISTENER_TOKEN, Interest::READ);
            }
        }
        // Adopt sockets round-robined here by the listener shard.
        while let Ok(stream) = handoff_rx.try_recv() {
            busy = true;
            if let Some(id) = shard.adopt(stream, source.as_mut()) {
                dirty.push(id);
            }
        }
        // Engine replies → owning connections' outbound queues.
        while let Ok(c) = crx.try_recv() {
            busy = true;
            if let Some(id) = shard.deliver(c) {
                dirty.push(id);
            }
        }
        shard.scan_timeouts(&mut dirty);
        busy |= shard.service_dirty(&mut dirty, source.as_mut());
    }
    // Shutdown: every owned socket closes (clients observe EOF), and
    // cap reservations for sockets still queued for adoption release.
    let open = shard.conns.len();
    shard.conns.clear();
    edge.open_conns.fetch_sub(open, Ordering::SeqCst);
    edge.shards[idx].conns.store(0, Ordering::SeqCst);
    while handoff_rx.try_recv().is_ok() {
        edge.open_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Shard {
    /// How long `wait` may block when the previous pass was idle: one
    /// tick, shortened to the earliest pending reply deadline.
    fn idle_timeout(&self, now: Instant) -> Duration {
        match self.next_deadline {
            Some(d) => d.saturating_duration_since(now).min(TICK),
            None => TICK,
        }
    }

    /// Take ownership of a handed-off socket: assign its id, register it
    /// with this shard's readiness source, and start servicing it.
    fn adopt(&mut self, stream: TcpStream, source: &mut dyn ReadinessSource) -> Option<u64> {
        let id = self.next_conn;
        self.next_conn += self.stride;
        if let Err(e) = source.register(raw_fd(&stream), id, Interest::READ) {
            log::warn!("failed to register connection {id} with {} readiness: {e}", source.backend());
            self.edge.open_conns.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        self.conns.insert(id, Conn::new(stream, &self.cfg, Instant::now()));
        self.edge.shards[self.idx].conns.fetch_add(1, Ordering::Relaxed);
        Some(id)
    }

    /// Route one completion into its connection's outbound queue — or,
    /// when the request timed out or its connection is gone, log and
    /// count the orphaned reply (never silently dropped). Returns the
    /// connection id when bytes were queued to a live connection.
    fn deliver(&mut self, c: Completion) -> Option<u64> {
        debug_assert_eq!(c.shard, self.idx, "completion routed to the wrong shard");
        let Some(fl) = self.inflight.get_mut(&c.seq) else {
            self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
            log::debug!("orphaned reply for closed connection {} (seq {}, {} bytes)", c.conn, c.seq, c.bytes.len());
            return None;
        };
        if fl.timed_out {
            self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
            log::warn!("orphaned reply: request seq {} on connection {} already timed out ({} bytes dropped)", c.seq, c.conn, c.bytes.len());
            if c.last {
                self.inflight.remove(&c.seq);
            }
            return None;
        }
        if !c.last {
            // Stream events are visible progress: refresh the deadline.
            // `next_deadline` stays a valid lower bound (the deadline
            // only moved later), costing at most one early scan.
            fl.deadline = Instant::now() + self.cfg.reply_timeout;
        }
        if c.last {
            self.inflight.remove(&c.seq);
        }
        match self.conns.get_mut(&c.conn) {
            Some(conn) => {
                if c.last {
                    conn.inflight = conn.inflight.saturating_sub(1);
                }
                conn.wbuf.extend_from_slice(&c.bytes);
                Some(c.conn)
            }
            None => {
                self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
                log::debug!("orphaned reply for closed connection {} (seq {})", c.conn, c.seq);
                None
            }
        }
    }

    /// One IO pass over every connection in `dirty` (deduplicated):
    /// ready sockets, fresh adoptions, completion targets, and timeout
    /// victims. Under `scan` readiness this is every owned connection —
    /// exactly the pre-sharding full pass. Kept connections are rearmed
    /// with their current interest; closed ones are deregistered.
    /// Returns whether any bytes moved (the loop's idle detector).
    fn service_dirty(&mut self, dirty: &mut Vec<u64>, source: &mut dyn ReadinessSource) -> bool {
        let mut busy = false;
        dirty.sort_unstable();
        dirty.dedup();
        for &id in dirty.iter() {
            let Some(mut conn) = self.conns.remove(&id) else { continue };
            let (keep, conn_busy) = self.service(id, &mut conn);
            busy |= conn_busy;
            if keep {
                let interest = Interest {
                    read: !conn.closing && !conn.read_closed && conn.outstanding() < self.cfg.outbound_cap,
                    write: conn.outstanding() > 0,
                };
                let _ = source.rearm(raw_fd(&conn.stream), id, interest);
                self.conns.insert(id, conn);
            } else {
                let _ = source.deregister(raw_fd(&conn.stream), id);
                self.inflight.retain(|_, fl| fl.conn != id);
                self.edge.open_conns.fetch_sub(1, Ordering::SeqCst);
                self.edge.shards[self.idx].conns.fetch_sub(1, Ordering::Relaxed);
                log::debug!("connection {id} closed");
            }
        }
        busy
    }

    /// Flush, read, parse, dispatch for one connection. Returns
    /// `(keep, busy)`.
    fn service(&mut self, id: u64, conn: &mut Conn) -> (bool, bool) {
        let mut busy = false;
        match self.flush(conn) {
            Ok(n) => busy |= n > 0,
            Err(_) => return (false, true),
        }
        if !conn.closing && !conn.read_closed && conn.outstanding() < self.cfg.outbound_cap {
            let mut scratch = [0u8; 16384];
            loop {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        conn.read_closed = true;
                        if !conn.rbuf.is_empty() {
                            // A final partial line is *not* a request:
                            // drop it rather than execute a truncated one.
                            log::debug!("dropping {} bytes of unterminated trailing input on connection {id}", conn.rbuf.len());
                            conn.rbuf.clear();
                        }
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        self.edge.bytes_in.fetch_add(n as u64, Ordering::SeqCst);
                        conn.rbuf.extend_from_slice(&scratch[..n]);
                        self.drain_lines(id, conn);
                        // Backpressure check against what this chunk's
                        // replies (errors, ping) already queued.
                        if conn.closing || conn.outstanding() >= self.cfg.outbound_cap {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return (false, true),
                }
            }
        }
        // Flush again so same-tick answers (ping, protocol errors) leave
        // without waiting for the next pass.
        match self.flush(conn) {
            Ok(n) => busy |= n > 0,
            Err(_) => return (false, true),
        }
        if conn.closing && conn.outstanding() == 0 {
            return (false, true);
        }
        if conn.read_closed && conn.outstanding() == 0 && conn.inflight == 0 {
            return (false, true);
        }
        (true, busy)
    }

    /// Process every complete line buffered on `conn`, enforcing
    /// `max_line_len` *while buffering*: a line over the limit — even one
    /// that never terminates — is rejected and the connection closed the
    /// moment the buffer crosses the cap.
    fn drain_lines(&mut self, id: u64, conn: &mut Conn) {
        loop {
            match take_line(&mut conn.rbuf) {
                Some(line) => {
                    if line.len() > self.cfg.max_line_len {
                        self.reject_overlimit(conn, line.len());
                        return;
                    }
                    self.handle_line(id, conn, &line);
                    if conn.closing {
                        return;
                    }
                }
                None => {
                    if conn.rbuf.len() > self.cfg.max_line_len {
                        self.reject_overlimit(conn, conn.rbuf.len());
                    }
                    return;
                }
            }
        }
    }

    fn reject_overlimit(&self, conn: &mut Conn, len: usize) {
        self.edge.overlimit_rejections.fetch_add(1, Ordering::SeqCst);
        conn.push_line(&protocol::err(&format!("request line exceeds max_line_len ({len} > {} bytes)", self.cfg.max_line_len)));
        conn.closing = true;
        conn.rbuf = Vec::new();
    }

    /// Parse one request line and dispatch it to the engines, leaving an
    /// in-flight entry behind for the reply (and its timeout).
    fn handle_line(&mut self, id: u64, conn: &mut Conn, line: &[u8]) {
        let Ok(text) = std::str::from_utf8(line) else {
            conn.push_line(&protocol::err("request is not valid utf-8"));
            return;
        };
        if text.trim().is_empty() {
            return;
        }
        let (req, meta) = match protocol::parse_with_meta(text) {
            Ok(x) => x,
            Err(e) => {
                conn.push_line(&protocol::err(&e));
                return;
            }
        };
        let echo = |line: String| match meta.id {
            Some(id) => protocol::with_id(&line, id),
            None => line,
        };
        let now = Instant::now();
        if !conn.bucket.allow(now) {
            self.edge.ratelimit_rejections.fetch_add(1, Ordering::SeqCst);
            conn.push_line(&echo(protocol::err("rate limit exceeded")));
            return;
        }
        let seq = self.next_seq;
        self.next_seq += self.stride;
        let reply = Reply {
            tx: self.ctx.clone(),
            shard: self.idx,
            conn: id,
            seq,
            id: meta.id,
            stream: meta.stream && self.cfg.streaming && matches!(req, Request::Sample { .. }),
            frame: meta.frame && self.cfg.framing,
            hop: meta.hop,
        };
        let deadline = now + self.cfg.reply_timeout;
        self.inflight.insert(seq, Inflight { conn: id, id: meta.id, deadline, timed_out: false });
        self.next_deadline = Some(match self.next_deadline {
            Some(d) => d.min(deadline),
            None => deadline,
        });
        conn.inflight += 1;
        if self.tx.send(Msg::Req(req, reply)).is_err() {
            self.inflight.remove(&seq);
            conn.inflight -= 1;
            conn.push_line(&echo(protocol::err("server shutting down")));
            conn.closing = true;
        }
    }

    /// Fail every in-flight request past its reply deadline to its
    /// client. The entry stays (flagged) so the engine's eventual answer
    /// is recognized and logged as orphaned. Deadline-gated: the pass
    /// over the in-flight map is skipped entirely until the tracked
    /// earliest deadline is due, then the exact minimum is recomputed.
    /// Affected connections are pushed into `dirty` so the error line
    /// flushes this tick even under epoll readiness.
    fn scan_timeouts(&mut self, dirty: &mut Vec<u64>) {
        let now = Instant::now();
        match self.next_deadline {
            Some(d) if now >= d => {}
            _ => return,
        }
        let mut expired: Vec<(u64, u64, Option<u64>)> = Vec::new();
        for (&seq, fl) in self.inflight.iter_mut() {
            if !fl.timed_out && now >= fl.deadline {
                fl.timed_out = true;
                expired.push((seq, fl.conn, fl.id));
            }
        }
        self.next_deadline = self.inflight.values().filter(|fl| !fl.timed_out).map(|fl| fl.deadline).min();
        for (seq, cid, rid) in expired {
            self.edge.reply_timeouts.fetch_add(1, Ordering::SeqCst);
            log::warn!(
                "request seq {seq} on connection {cid} unanswered after {:?} (reply_timeout); its eventual reply will be counted as orphaned",
                self.cfg.reply_timeout
            );
            if let Some(conn) = self.conns.get_mut(&cid) {
                conn.inflight = conn.inflight.saturating_sub(1);
                let line = protocol::err("reply timeout");
                conn.push_line(&match rid {
                    Some(id) => protocol::with_id(&line, id),
                    None => line,
                });
                dirty.push(cid);
            }
        }
    }

    /// Write as much queued output as the socket accepts right now.
    fn flush(&self, conn: &mut Conn) -> std::io::Result<usize> {
        let mut wrote = 0usize;
        while conn.wpos < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
                Ok(n) => {
                    conn.wpos += n;
                    wrote += n;
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if wrote > 0 {
            self.edge.bytes_out.fetch_add(wrote as u64, Ordering::SeqCst);
        }
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        } else if conn.wpos > 1 << 16 {
            // Compact a part-flushed buffer so backpressured connections
            // do not hold both the flushed and unflushed halves forever.
            conn.wbuf.drain(..conn.wpos);
            conn.wpos = 0;
        }
        Ok(wrote)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_limits_and_refills() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2, t0);
        assert!(b.allow(t0));
        assert!(b.allow(t0));
        assert!(!b.allow(t0), "burst exhausted");
        // Half a second refills one token at 2 req/s.
        assert!(b.allow(t0 + Duration::from_millis(600)));
        assert!(!b.allow(t0 + Duration::from_millis(600)));
        // The bucket never banks more than one second of burst.
        assert!(b.allow(t0 + Duration::from_secs(60)));
        assert!(b.allow(t0 + Duration::from_secs(60)));
        assert!(!b.allow(t0 + Duration::from_secs(60)));
    }

    #[test]
    fn token_bucket_zero_rate_is_unlimited() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0, t0);
        for _ in 0..10_000 {
            assert!(b.allow(t0));
        }
    }

    #[test]
    fn take_line_splits_and_keeps_partials() {
        let mut buf = b"{\"op\":\"ping\"}\n{\"op\":\"in".to_vec();
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"ping\"}"[..]));
        assert_eq!(take_line(&mut buf), None, "partial line stays buffered");
        assert_eq!(buf, b"{\"op\":\"in".to_vec());
        buf.extend_from_slice(b"fo\"}\n\n");
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"info\"}"[..]));
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b""[..]), "blank lines pass through for the parser to skip");
        assert_eq!(take_line(&mut buf), None);
    }

    #[test]
    fn take_line_strips_crlf_terminators() {
        // CRLF clients (telnet, windows netcat) terminate with \r\n: the
        // \r must not reach the JSON parser or the byte-length checks.
        let mut buf = b"{\"op\":\"ping\"}\r\n{\"op\":\"info\"}\npartial\r".to_vec();
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"ping\"}"[..]));
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"info\"}"[..]), "LF-only lines are untouched");
        assert_eq!(take_line(&mut buf), None, "a trailing \\r without \\n stays buffered");
        assert_eq!(buf, b"partial\r".to_vec());
        buf.extend_from_slice(b"\n\r\n");
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"partial"[..]));
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b""[..]), "a bare CRLF is a blank line");
        // Only a *terminal* \r is stripped: interior ones survive.
        let mut buf = b"a\rb\r\n".to_vec();
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"a\rb"[..]));
    }

    #[test]
    fn edge_stats_value_reports_backend_and_shards() {
        let edge = EdgeStats::new("scan", 3);
        edge.shards[1].ticks.store(10, Ordering::SeqCst);
        edge.shards[1].ready_events.store(25, Ordering::SeqCst);
        let v = edge.value();
        assert_eq!(v.get("readiness").as_str(), Some("scan"));
        assert_eq!(v.get("conn_threads").as_f64(), Some(3.0));
        let shards = v.get("shards").as_arr().expect("shards array");
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[1].get("ready_per_tick").as_f64(), Some(2.5));
    }
}
