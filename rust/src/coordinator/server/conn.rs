//! The connection plane: one event-loop thread owning every client
//! socket. Nonblocking accept plus a readiness scan over nonblocking
//! connections, with per-connection read/write buffers, multiple
//! in-flight requests per connection (pipelined by request `id`), and
//! replies routed back through the completion channel into
//! per-connection outbound queues — replacing the old blocking
//! thread-per-connection edge, whose thread count was the real
//! concurrency ceiling.
//!
//! Edge hardening lives here, all `ServeConfig` knobs:
//!
//! * `max_line_len` — enforced *while* buffering, so an endless line is
//!   rejected long before it can exhaust memory;
//! * `outbound_cap` — read-side backpressure: a connection whose
//!   unflushed output exceeds the cap stops being *read* until the peer
//!   drains it, without stalling any other connection;
//! * `rate_limit` — per-connection token bucket (one-second burst);
//! * `max_conns` — excess accepts get an error line and are closed;
//! * `reply_timeout` — an unanswered request fails to the client, and
//!   the engine's eventual reply is logged and counted as orphaned
//!   rather than silently dropped.
//!
//! The loop never blocks on any socket: it sleeps on the completion
//! channel (so engine replies wake it instantly) for at most one tick,
//! then rescans. std-only nonblocking sockets — no epoll wrapper is
//! vendored, and a scan over ≤ `max_conns` health-checked fds per tick
//! is well inside this plane's budget.

use crate::coordinator::config::ServeConfig;
use crate::coordinator::protocol::{self, Request};
use crate::coordinator::server::pool::{Completion, Reply};
use crate::coordinator::server::Msg;
use crate::substrate::json::Value;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Idle tick: how long the loop blocks on the completion channel when a
/// pass over every connection found nothing to do. Completions wake it
/// immediately; fresh sockets/bytes wait at most one tick.
const TICK: Duration = Duration::from_millis(5);

/// Connection-plane counters, surfaced as the `edge` section of the
/// `metrics` response.
#[derive(Default)]
pub(crate) struct EdgeStats {
    pub(crate) open_conns: AtomicUsize,
    pub(crate) total_conns: AtomicU64,
    pub(crate) bytes_in: AtomicU64,
    pub(crate) bytes_out: AtomicU64,
    pub(crate) overlimit_rejections: AtomicU64,
    pub(crate) ratelimit_rejections: AtomicU64,
    pub(crate) conn_cap_rejections: AtomicU64,
    pub(crate) reply_timeouts: AtomicU64,
    pub(crate) orphaned_replies: AtomicU64,
}

impl EdgeStats {
    pub(crate) fn value(&self) -> Value {
        Value::obj(vec![
            ("open_conns", Value::num(self.open_conns.load(Ordering::SeqCst) as f64)),
            ("total_conns", Value::num(self.total_conns.load(Ordering::SeqCst) as f64)),
            ("bytes_in", Value::num(self.bytes_in.load(Ordering::SeqCst) as f64)),
            ("bytes_out", Value::num(self.bytes_out.load(Ordering::SeqCst) as f64)),
            ("overlimit_rejections", Value::num(self.overlimit_rejections.load(Ordering::SeqCst) as f64)),
            ("ratelimit_rejections", Value::num(self.ratelimit_rejections.load(Ordering::SeqCst) as f64)),
            ("conn_cap_rejections", Value::num(self.conn_cap_rejections.load(Ordering::SeqCst) as f64)),
            ("reply_timeouts", Value::num(self.reply_timeouts.load(Ordering::SeqCst) as f64)),
            ("orphaned_replies", Value::num(self.orphaned_replies.load(Ordering::SeqCst) as f64)),
        ])
    }
}

/// Per-connection request rate limiter: classic token bucket with a
/// one-second burst (`rate` tokens), `rate` == 0 disabling the limit.
struct TokenBucket {
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rate: u32, now: Instant) -> TokenBucket {
        TokenBucket { rate: rate as f64, tokens: rate as f64, last: now }
    }

    fn allow(&mut self, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.rate);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Split one complete line (newline stripped) off the front of `buf`.
fn take_line(buf: &mut Vec<u8>) -> Option<Vec<u8>> {
    let pos = buf.iter().position(|&b| b == b'\n')?;
    let mut line: Vec<u8> = buf.drain(..=pos).collect();
    line.pop();
    Some(line)
}

/// One client connection's event-loop state.
struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet split into complete request lines.
    rbuf: Vec<u8>,
    /// Bytes queued for the peer; `wpos..` is the unflushed tail.
    wbuf: Vec<u8>,
    wpos: usize,
    bucket: TokenBucket,
    /// Requests dispatched from this connection and not yet answered
    /// (or timed out) — a half-closed connection stays open for these.
    inflight: usize,
    /// Peer sent EOF: stop reading, finish delivering, then close.
    read_closed: bool,
    /// Hard close (protocol violation / shutdown): flush `wbuf`, drop.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream, cfg: &ServeConfig, now: Instant) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            bucket: TokenBucket::new(cfg.rate_limit, now),
            inflight: 0,
            read_closed: false,
            closing: false,
        }
    }

    /// Unflushed outbound bytes (what backpressure measures).
    fn outstanding(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn push_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }
}

/// One request awaiting its engine reply: who asked, the reply deadline,
/// and whether the deadline already fired (late replies then count as
/// orphaned instead of reaching a client that moved on).
struct Inflight {
    conn: u64,
    id: Option<u64>,
    deadline: Instant,
    timed_out: bool,
}

struct ConnPlane {
    cfg: ServeConfig,
    tx: mpsc::Sender<Msg>,
    ctx: mpsc::Sender<Completion>,
    edge: Arc<EdgeStats>,
    conns: HashMap<u64, Conn>,
    inflight: HashMap<u64, Inflight>,
    next_conn: u64,
    next_seq: u64,
}

/// The connection plane's event loop. Owns the listener, every client
/// socket, and the receiving end of the completion channel; exits when
/// `stop` is set, closing every connection.
pub(crate) fn conn_loop(
    listener: TcpListener,
    cfg: ServeConfig,
    tx: mpsc::Sender<Msg>,
    crx: mpsc::Receiver<Completion>,
    ctx: mpsc::Sender<Completion>,
    stop: Arc<AtomicBool>,
    edge: Arc<EdgeStats>,
) {
    let mut plane = ConnPlane {
        cfg,
        tx,
        ctx,
        edge,
        conns: HashMap::new(),
        inflight: HashMap::new(),
        next_conn: 0,
        next_seq: 0,
    };
    while !stop.load(Ordering::SeqCst) {
        let mut busy = plane.accept_new(&listener);
        while let Ok(c) = crx.try_recv() {
            plane.deliver(c);
            busy = true;
        }
        busy |= plane.service_all();
        plane.scan_timeouts();
        if !busy {
            // Idle: block on the completion channel — an engine reply
            // wakes the loop instantly, everything else waits ≤ TICK.
            // The plane holds a sender clone, so the channel cannot
            // disconnect; only deliveries and timeouts come out.
            if let Ok(c) = crx.recv_timeout(TICK) {
                plane.deliver(c);
            }
        }
    }
    // Shutdown: every socket closes (clients observe EOF).
    plane.conns.clear();
    plane.edge.open_conns.store(0, Ordering::SeqCst);
}

impl ConnPlane {
    /// Accept every pending connection (nonblocking). Over `max_conns`,
    /// the socket gets a best-effort error line and closes immediately.
    fn accept_new(&mut self, listener: &TcpListener) -> bool {
        let mut any = false;
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    any = true;
                    self.edge.total_conns.fetch_add(1, Ordering::SeqCst);
                    if self.conns.len() >= self.cfg.max_conns {
                        self.edge.conn_cap_rejections.fetch_add(1, Ordering::SeqCst);
                        log::warn!("rejecting connection from {peer}: {} already open (max_conns)", self.conns.len());
                        // Accepted sockets are blocking by default; one
                        // short error line fits any send buffer.
                        let mut s = stream;
                        let _ = s.write_all(protocol::err("connection limit reached").as_bytes());
                        let _ = s.write_all(b"\n");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let id = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(id, Conn::new(stream, &self.cfg, Instant::now()));
                    self.edge.open_conns.store(self.conns.len(), Ordering::SeqCst);
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        }
        any
    }

    /// Route one completion into its connection's outbound queue — or,
    /// when the request timed out or its connection is gone, log and
    /// count the orphaned reply (satellite: never silently dropped).
    fn deliver(&mut self, c: Completion) {
        let Some(fl) = self.inflight.get_mut(&c.seq) else {
            self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
            log::debug!("orphaned reply for closed connection {} (seq {}, {} bytes)", c.conn, c.seq, c.bytes.len());
            return;
        };
        if fl.timed_out {
            self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
            log::warn!("orphaned reply: request seq {} on connection {} already timed out ({} bytes dropped)", c.seq, c.conn, c.bytes.len());
            if c.last {
                self.inflight.remove(&c.seq);
            }
            return;
        }
        if !c.last {
            // Stream events are visible progress: refresh the deadline.
            fl.deadline = Instant::now() + self.cfg.reply_timeout;
        }
        if c.last {
            self.inflight.remove(&c.seq);
        }
        match self.conns.get_mut(&c.conn) {
            Some(conn) => {
                if c.last {
                    conn.inflight = conn.inflight.saturating_sub(1);
                }
                conn.wbuf.extend_from_slice(&c.bytes);
            }
            None => {
                self.edge.orphaned_replies.fetch_add(1, Ordering::SeqCst);
                log::debug!("orphaned reply for closed connection {} (seq {})", c.conn, c.seq);
            }
        }
    }

    /// One IO pass over every connection; returns whether any bytes
    /// moved (the loop's idle detector).
    fn service_all(&mut self) -> bool {
        let mut busy = false;
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let Some(mut conn) = self.conns.remove(&id) else { continue };
            let (keep, conn_busy) = self.service(id, &mut conn);
            busy |= conn_busy;
            if keep {
                self.conns.insert(id, conn);
            } else {
                self.inflight.retain(|_, fl| fl.conn != id);
                log::debug!("connection {id} closed");
            }
        }
        self.edge.open_conns.store(self.conns.len(), Ordering::SeqCst);
        busy
    }

    /// Flush, read, parse, dispatch for one connection. Returns
    /// `(keep, busy)`.
    fn service(&mut self, id: u64, conn: &mut Conn) -> (bool, bool) {
        let mut busy = false;
        match self.flush(conn) {
            Ok(n) => busy |= n > 0,
            Err(_) => return (false, true),
        }
        if !conn.closing && !conn.read_closed && conn.outstanding() < self.cfg.outbound_cap {
            let mut scratch = [0u8; 16384];
            loop {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        conn.read_closed = true;
                        if !conn.rbuf.is_empty() {
                            // A final partial line is *not* a request:
                            // drop it rather than execute a truncated one.
                            log::debug!("dropping {} bytes of unterminated trailing input on connection {id}", conn.rbuf.len());
                            conn.rbuf.clear();
                        }
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        self.edge.bytes_in.fetch_add(n as u64, Ordering::SeqCst);
                        conn.rbuf.extend_from_slice(&scratch[..n]);
                        self.drain_lines(id, conn);
                        // Backpressure check against what this chunk's
                        // replies (errors, ping) already queued.
                        if conn.closing || conn.outstanding() >= self.cfg.outbound_cap {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return (false, true),
                }
            }
        }
        // Flush again so same-tick answers (ping, protocol errors) leave
        // without waiting for the next pass.
        match self.flush(conn) {
            Ok(n) => busy |= n > 0,
            Err(_) => return (false, true),
        }
        if conn.closing && conn.outstanding() == 0 {
            return (false, true);
        }
        if conn.read_closed && conn.outstanding() == 0 && conn.inflight == 0 {
            return (false, true);
        }
        (true, busy)
    }

    /// Process every complete line buffered on `conn`, enforcing
    /// `max_line_len` *while buffering*: a line over the limit — even one
    /// that never terminates — is rejected and the connection closed the
    /// moment the buffer crosses the cap.
    fn drain_lines(&mut self, id: u64, conn: &mut Conn) {
        loop {
            match take_line(&mut conn.rbuf) {
                Some(line) => {
                    if line.len() > self.cfg.max_line_len {
                        self.reject_overlimit(conn, line.len());
                        return;
                    }
                    self.handle_line(id, conn, &line);
                    if conn.closing {
                        return;
                    }
                }
                None => {
                    if conn.rbuf.len() > self.cfg.max_line_len {
                        self.reject_overlimit(conn, conn.rbuf.len());
                    }
                    return;
                }
            }
        }
    }

    fn reject_overlimit(&self, conn: &mut Conn, len: usize) {
        self.edge.overlimit_rejections.fetch_add(1, Ordering::SeqCst);
        conn.push_line(&protocol::err(&format!("request line exceeds max_line_len ({len} > {} bytes)", self.cfg.max_line_len)));
        conn.closing = true;
        conn.rbuf = Vec::new();
    }

    /// Parse one request line and dispatch it to the engines, leaving an
    /// in-flight entry behind for the reply (and its timeout).
    fn handle_line(&mut self, id: u64, conn: &mut Conn, line: &[u8]) {
        let Ok(text) = std::str::from_utf8(line) else {
            conn.push_line(&protocol::err("request is not valid utf-8"));
            return;
        };
        if text.trim().is_empty() {
            return;
        }
        let (req, meta) = match protocol::parse_with_meta(text) {
            Ok(x) => x,
            Err(e) => {
                conn.push_line(&protocol::err(&e));
                return;
            }
        };
        let echo = |line: String| match meta.id {
            Some(id) => protocol::with_id(&line, id),
            None => line,
        };
        let now = Instant::now();
        if !conn.bucket.allow(now) {
            self.edge.ratelimit_rejections.fetch_add(1, Ordering::SeqCst);
            conn.push_line(&echo(protocol::err("rate limit exceeded")));
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let reply = Reply {
            tx: self.ctx.clone(),
            conn: id,
            seq,
            id: meta.id,
            stream: meta.stream && self.cfg.streaming && matches!(req, Request::Sample { .. }),
            frame: meta.frame && self.cfg.framing,
        };
        self.inflight.insert(seq, Inflight { conn: id, id: meta.id, deadline: now + self.cfg.reply_timeout, timed_out: false });
        conn.inflight += 1;
        if self.tx.send(Msg::Req(req, reply)).is_err() {
            self.inflight.remove(&seq);
            conn.inflight -= 1;
            conn.push_line(&echo(protocol::err("server shutting down")));
            conn.closing = true;
        }
    }

    /// Fail every in-flight request past its reply deadline to its
    /// client. The entry stays (flagged) so the engine's eventual answer
    /// is recognized and logged as orphaned.
    fn scan_timeouts(&mut self) {
        let now = Instant::now();
        let mut expired: Vec<(u64, u64, Option<u64>)> = Vec::new();
        for (&seq, fl) in self.inflight.iter_mut() {
            if !fl.timed_out && now >= fl.deadline {
                fl.timed_out = true;
                expired.push((seq, fl.conn, fl.id));
            }
        }
        for (seq, cid, rid) in expired {
            self.edge.reply_timeouts.fetch_add(1, Ordering::SeqCst);
            log::warn!(
                "request seq {seq} on connection {cid} unanswered after {:?} (reply_timeout); its eventual reply will be counted as orphaned",
                self.cfg.reply_timeout
            );
            if let Some(conn) = self.conns.get_mut(&cid) {
                conn.inflight = conn.inflight.saturating_sub(1);
                let line = protocol::err("reply timeout");
                conn.push_line(&match rid {
                    Some(id) => protocol::with_id(&line, id),
                    None => line,
                });
            }
        }
    }

    /// Write as much queued output as the socket accepts right now.
    fn flush(&self, conn: &mut Conn) -> std::io::Result<usize> {
        let mut wrote = 0usize;
        while conn.wpos < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
                Ok(n) => {
                    conn.wpos += n;
                    wrote += n;
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if wrote > 0 {
            self.edge.bytes_out.fetch_add(wrote as u64, Ordering::SeqCst);
        }
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        } else if conn.wpos > 1 << 16 {
            // Compact a part-flushed buffer so backpressured connections
            // do not hold both the flushed and unflushed halves forever.
            conn.wbuf.drain(..conn.wpos);
            conn.wpos = 0;
        }
        Ok(wrote)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_limits_and_refills() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2, t0);
        assert!(b.allow(t0));
        assert!(b.allow(t0));
        assert!(!b.allow(t0), "burst exhausted");
        // Half a second refills one token at 2 req/s.
        assert!(b.allow(t0 + Duration::from_millis(600)));
        assert!(!b.allow(t0 + Duration::from_millis(600)));
        // The bucket never banks more than one second of burst.
        assert!(b.allow(t0 + Duration::from_secs(60)));
        assert!(b.allow(t0 + Duration::from_secs(60)));
        assert!(!b.allow(t0 + Duration::from_secs(60)));
    }

    #[test]
    fn token_bucket_zero_rate_is_unlimited() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0, t0);
        for _ in 0..10_000 {
            assert!(b.allow(t0));
        }
    }

    #[test]
    fn take_line_splits_and_keeps_partials() {
        let mut buf = b"{\"op\":\"ping\"}\n{\"op\":\"in".to_vec();
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"ping\"}"[..]));
        assert_eq!(take_line(&mut buf), None, "partial line stays buffered");
        assert_eq!(buf, b"{\"op\":\"in".to_vec());
        buf.extend_from_slice(b"fo\"}\n\n");
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b"{\"op\":\"info\"}"[..]));
        assert_eq!(take_line(&mut buf).as_deref(), Some(&b""[..]), "blank lines pass through for the parser to skip");
        assert_eq!(take_line(&mut buf), None);
    }
}
