//! Engine workers: each owns a `Router` (PJRT state is thread-affine)
//! plus `Metrics`, claims work from the shared pool, runs batching
//! windows, and keeps the placement plane's residency promises —
//! enforcing the per-worker engine cap and publishing the resident-model
//! / engine-load / eviction gauges the dispatcher snapshots. Replies go
//! out through each request's [`Reply`] handle, which targets (and
//! wakes) the connection shard that owns the requesting socket.

use crate::coordinator::config::{Method, ServeConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::placement::PlacementPolicy;
use crate::coordinator::policy::{ConvergenceBook, ConvergencePrior};
use crate::coordinator::protocol;
use crate::coordinator::router::Router;
use crate::coordinator::scheduler;
use crate::coordinator::server::feed::execute_elastic_group;
use crate::coordinator::server::pool::{abort_queue, fail_request, steal_group, take_group_arrivals, PendingSample, Pool, Reply, Work, EVAL_LOAD};
use crate::runtime::step::CatalogStats;
use crate::sampler::noise::JobNoise;
use crate::sampler::JobResult;
use crate::substrate::json::Value;
use crate::substrate::timer::Timer;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Everything one engine worker shares with the dispatcher and the
/// serving plane: queue-depth accounting, its metrics, the placement
/// policy, the residency gauges it publishes after every turn, and the
/// server-level convergence history it observes into.
pub(crate) struct WorkerShared {
    /// Jobs routed to this worker and not yet answered (queue depth).
    pub(crate) load: Arc<AtomicUsize>,
    pub(crate) metrics: Arc<Mutex<Metrics>>,
    /// Engines currently resident on this worker.
    pub(crate) engines_loaded: Arc<AtomicUsize>,
    /// Cumulative lazy engine loads (reloads after eviction included).
    pub(crate) engine_loads: Arc<AtomicUsize>,
    /// Cumulative LRU evictions under a capacity-capped placement.
    pub(crate) evictions: Arc<AtomicUsize>,
    /// Names of the engines currently resident (warm-routing + gauges).
    pub(crate) resident: Arc<Mutex<Vec<String>>>,
    /// Shape-variant catalog telemetry across every engine this worker's
    /// router ever loaded (evicted engines included), refreshed by
    /// [`sync_gauges`] after each turn.
    pub(crate) catalog: Arc<Mutex<CatalogStats>>,
    /// Shared per-(model, method) convergence history.
    pub(crate) book: Arc<ConvergenceBook>,
    /// The placement policy the whole fleet runs under.
    pub(crate) placement: Arc<dyn PlacementPolicy>,
}

/// Dispatcher-side handle to one engine worker.
pub(crate) struct WorkerHandle {
    /// Jobs routed to this worker and not yet completed (queue depth).
    pub(crate) load: Arc<AtomicUsize>,
    pub(crate) metrics: Arc<Mutex<Metrics>>,
    pub(crate) engines_loaded: Arc<AtomicUsize>,
    pub(crate) engine_loads: Arc<AtomicUsize>,
    pub(crate) evictions: Arc<AtomicUsize>,
    pub(crate) resident: Arc<Mutex<Vec<String>>>,
    pub(crate) catalog: Arc<Mutex<CatalogStats>>,
    pub(crate) join: std::thread::JoinHandle<()>,
}

impl WorkerHandle {
    /// Snapshot of the resident-model gauge (dispatcher side).
    pub(crate) fn resident_models(&self) -> Vec<String> {
        self.resident.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Snapshot of the worker's shape-variant catalog telemetry.
    pub(crate) fn catalog_totals(&self) -> CatalogStats {
        self.catalog.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Whether `model`'s engine is currently resident on this worker —
    /// the routing hot path's warm check, without cloning the gauge.
    pub(crate) fn hosts(&self, model: &str) -> bool {
        self.resident.lock().unwrap_or_else(|e| e.into_inner()).iter().any(|m| m == model)
    }
}

/// Under a capacity-capped placement, evict least-recently-used engines
/// so `model`'s upcoming lazy load fits within the cap. Runs *before*
/// the worker touches the engine: evicting afterwards would let
/// residency peak at `cap + 1`, breaking the hard per-worker memory
/// bound the policy promises.
pub(crate) fn make_room_for(router: &mut Router, shared: &WorkerShared, model: &str) {
    if let Some(cap) = shared.placement.max_resident() {
        router.make_room(model, cap);
    }
}

/// Publish the worker's residency gauges after a turn — and, under a
/// capacity-capped placement, re-assert the cap as a safety net (the
/// pre-load [`make_room_for`] is what keeps the peak within it).
fn sync_gauges(router: &mut Router, shared: &WorkerShared) {
    if let Some(cap) = shared.placement.max_resident() {
        router.enforce_cap(cap);
    }
    shared.engines_loaded.store(router.loaded(), Ordering::SeqCst);
    shared.engine_loads.store(router.loads() as usize, Ordering::SeqCst);
    shared.evictions.store(router.evictions() as usize, Ordering::SeqCst);
    *shared.resident.lock().unwrap_or_else(|e| e.into_inner()) = router.resident_models();
    *shared.catalog.lock().unwrap_or_else(|e| e.into_inner()) = router.catalog_totals();
}

fn handle_eval(router: &mut Router, model: &str, reply: &Reply, metrics: &Mutex<Metrics>, load: &AtomicUsize) {
    let resp = match router.engine(model).and_then(|e| e.eval_bpd()) {
        Ok(bpd) => protocol::ok(vec![("model", Value::str(model)), ("bpd", Value::num(bpd))]),
        Err(e) => {
            metrics.lock().unwrap_or_else(|e| e.into_inner()).record_error();
            protocol::err(&format!("{e:#}"))
        }
    };
    let _ = reply.send(resp);
    load.fetch_sub(EVAL_LOAD, Ordering::SeqCst);
}

/// Runs on worker-thread exit — panic included: marks the worker dead so
/// the dispatcher routes around it, and fails whatever is queued on it
/// (a request must never sit on a queue nobody will drain).
struct WorkerGuard {
    pool: Arc<Pool>,
    widx: usize,
    load: Arc<AtomicUsize>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let q = {
            let mut st = self.pool.state.lock().unwrap_or_else(|e| e.into_inner());
            st.dead[self.widx] = true;
            std::mem::take(&mut st.queues[self.widx])
        };
        abort_queue(q, &self.load, "engine worker unavailable");
        self.pool.cv.notify_all();
    }
}

pub(crate) fn worker_loop(mut router: Router, cfg: ServeConfig, widx: usize, pool: Arc<Pool>, shared: WorkerShared) {
    let _guard = WorkerGuard { pool: Arc::clone(&pool), widx, load: Arc::clone(&shared.load) };
    loop {
        // Claim the oldest work item on our queue, stealing a whole queued
        // group from the most-loaded worker when ours is empty (only
        // groups this worker may host under the placement policy).
        let mut stole = false;
        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
        let head = loop {
            if pool.shutdown.load(Ordering::SeqCst) {
                let q = std::mem::take(&mut st.queues[widx]);
                drop(st);
                abort_queue(q, &shared.load, "server shutting down");
                return;
            }
            if let Some(w) = st.queues[widx].pop_front() {
                break w;
            }
            if cfg.steal && steal_group(&mut st, widx, &pool.loads, &*shared.placement) {
                stole = true;
                continue;
            }
            st = pool.cv.wait_timeout(st, std::time::Duration::from_millis(100)).unwrap_or_else(|e| e.into_inner()).0;
        };
        match head {
            Work::Eval { model, reply, .. } => {
                drop(st);
                if stole {
                    shared.metrics.lock().unwrap_or_else(|e| e.into_inner()).record_steal();
                }
                make_room_for(&mut router, &shared, &model);
                handle_eval(&mut router, &model, &reply, &shared.metrics, &shared.load);
                sync_gauges(&mut router, &shared);
            }
            Work::Sample(head) => {
                // Mark the group executing before the window opens, still
                // under the claim's lock: thieves skip it from here on,
                // and (on the elastic path) the live schedule owns its
                // arrivals through to the end of execution.
                let key = (head.model.clone(), head.method);
                st.executing[widx] = Some(key.clone());
                // Batching window, sized off the *oldest admission* of the
                // head group: a request that already waited its window
                // while queued behind other groups executes immediately
                // instead of re-paying max_wait per preceding group.
                let deadline = head.admitted + cfg.max_wait;
                let mut group = vec![head];
                loop {
                    take_group_arrivals(&mut st.queues[widx], &key, &mut group);
                    // Evals interleave into the window (otherwise, on a
                    // single-worker server with no thief to rescue them,
                    // they'd wait out the whole group execution too).
                    while let Some(pos) = st.queues[widx].iter().position(|it| matches!(it, Work::Eval { .. })) {
                        let Some(Work::Eval { model, reply, .. }) = st.queues[widx].remove(pos) else { unreachable!("just matched") };
                        drop(st);
                        make_room_for(&mut router, &shared, &model);
                        handle_eval(&mut router, &model, &reply, &shared.metrics, &shared.load);
                        sync_gauges(&mut router, &shared);
                        st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
                    }
                    if pool.shutdown.load(Ordering::SeqCst) {
                        let q = std::mem::take(&mut st.queues[widx]);
                        st.executing[widx] = None;
                        drop(st);
                        for p in group {
                            fail_request(p, &shared.load, "server shutting down");
                        }
                        abort_queue(q, &shared.load, "server shutting down");
                        return;
                    }
                    let group_jobs: usize = group.iter().map(|p| p.n).sum();
                    let now = Instant::now();
                    if group_jobs >= cfg.max_batch || now >= deadline {
                        break;
                    }
                    st = pool.cv.wait_timeout(st, deadline - now).unwrap_or_else(|e| e.into_inner()).0;
                }
                drop(st);
                {
                    // The window just closed: sample each request's queue
                    // age (admission → execution) into the age histogram.
                    let mut m = shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
                    if stole {
                        m.record_steal();
                    }
                    for p in &group {
                        m.record_admission_age(p.admitted.elapsed());
                    }
                }
                let continuous = cfg.continuous && key.1 != Method::Baseline;
                make_room_for(&mut router, &shared, &key.0);
                if continuous && cfg.elastic {
                    execute_elastic_group(&mut router, &shared, group, &pool, widx, &cfg);
                } else {
                    execute_group(&mut router, &shared, group, continuous);
                }
                pool.state.lock().unwrap_or_else(|e| e.into_inner()).executing[widx] = None;
                sync_gauges(&mut router, &shared);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Group execution
// ---------------------------------------------------------------------------

/// Execute a closed group (synchronous chunking, or continuous batching
/// with elasticity disabled): run the whole merged queue, then answer
/// every request with the group-level stats.
pub(crate) fn execute_group(router: &mut Router, shared: &WorkerShared, group: Vec<PendingSample>, continuous: bool) {
    if group.is_empty() {
        return;
    }
    let model = group[0].model.clone();
    let method = group[0].method;
    let total_jobs: usize = group.iter().map(|p| p.n).sum();
    let timer = Timer::start();

    // Returns (per-job results in request order, total batched ARM calls,
    // ARM calls per job under the batched cost model — passes × B / jobs,
    // matching ScheduleReport::calls_per_job — and the schedule's own
    // wall-seconds, which exclude the lazy engine load the outer timer
    // pays on a cold worker).
    let mut run = || -> Result<(Vec<JobResult>, usize, f64, f64)> {
        let engine = router.engine(&model)?;
        let info = &engine.info;
        if !continuous {
            // Synchronous path: per request, pick the smallest exe >= n and
            // run it in chunks. Chunk c covers job ids [done, done + bs):
            // the offset keys fresh noise per chunk — without it every
            // chunk would repeat jobs 0..bs and duplicate samples.
            let mut all = Vec::with_capacity(total_jobs);
            let mut calls = 0usize;
            let mut weighted_calls = 0f64;
            let sched_timer = Timer::start();
            for p in &group {
                // Degraded fallback: an engine exporting no batch sizes
                // (broken artifact) chunks at the request size instead of
                // panicking the worker.
                let bs = engine
                    .batch_sizes()
                    .into_iter()
                    .find(|&b| b >= p.n)
                    .or_else(|| engine.batch_sizes().into_iter().max())
                    .unwrap_or(p.n.max(1));
                let mut done = 0;
                while done < p.n {
                    let res = engine.sample_batch_offset(method, bs, p.seed, done as u64)?;
                    calls += res.arm_calls;
                    weighted_calls += (res.arm_calls * bs) as f64;
                    let take = (p.n - done).min(bs);
                    all.extend(res.jobs.into_iter().take(take));
                    done += take;
                }
            }
            Ok((all, calls, weighted_calls / total_jobs as f64, sched_timer.secs()))
        } else {
            // Continuous batching over the merged job queue, scheduled
            // across every exported batch size: the engine starts on the
            // smallest batch that fits and down-shifts as the queue
            // drains, so a straggler tail stops paying full-batch passes.
            let mut noises = Vec::with_capacity(total_jobs);
            for p in &group {
                for j in 0..p.n {
                    noises.push(JobNoise::new(p.seed, j as u64, info.dim, info.categories));
                }
            }
            let rep = engine.sample_continuous(method, noises)?;
            Ok((rep.results, rep.total_passes, rep.calls_per_job, rep.wall_secs))
        }
    };

    match run() {
        Ok((results, calls, calls_per_job, sched_wall)) => {
            let wall = timer.secs();
            let dim = results.first().map(|r| r.x.len()).unwrap_or(1);
            let calls_pct = scheduler::calls_pct_of(calls_per_job, dim);
            {
                let mut m = shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
                m.record_batch(total_jobs, calls, calls_pct, wall);
                // The closed continuous path schedules under the
                // latency-lean (fit) rule; the chunked path is the
                // synchronous baseline.
                m.record_policy(if continuous { "latency" } else { "sync" });
            }
            if continuous && calls > 0 {
                // Feed the server-level convergence history: mean passes
                // per job, and wall-seconds per pass from the schedule's
                // own clock (the outer `wall` includes the lazy engine
                // load, which would inflate a cold worker's first
                // estimate by orders of magnitude on compiled artifacts).
                let iters: usize = results.iter().map(|r| r.iterations).sum();
                let obs = ConvergencePrior { passes_per_job: iters as f64 / total_jobs as f64, pass_secs: sched_wall / calls as f64 };
                shared.book.observe(&book_key(&model, method), obs);
            }
            let mut offset = 0usize;
            for p in group {
                let mine = &results[offset..offset + p.n];
                offset += p.n;
                if p.reply.stream {
                    // Closed schedules deliver at group end, so the events
                    // land back-to-back just ahead of the summary — the
                    // same client contract as the elastic path, without
                    // per-job hooks inside the engine.
                    for (j, r) in mine.iter().enumerate() {
                        let frame = if p.reply.frame { Some(protocol::encode_frame(std::slice::from_ref(&r.x))) } else { None };
                        let framed = frame.is_some();
                        let _ = p.reply.send_event(protocol::stream_event(j, &r.x, framed), frame);
                    }
                }
                let mut fields = sample_fields(&model, method, calls, calls_per_job, calls_pct, wall, p.n);
                let mut decode_err: Option<String> = None;
                let mut frame_payload: Option<Vec<u8>> = None;
                if p.return_samples {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    if p.reply.frame {
                        fields.push(("frame", Value::Bool(true)));
                        frame_payload = Some(protocol::encode_frame(&xs));
                    } else {
                        fields.push(("samples", protocol::samples_value(&xs)));
                    }
                }
                if p.decode {
                    let xs: Vec<Vec<i32>> = mine.iter().map(|r| r.x.clone()).collect();
                    match router.engine(&model).and_then(|e| e.decode(&xs)) {
                        Ok(imgs) => fields.push(("images", images_value(&imgs))),
                        Err(e) => decode_err = Some(format!("decode: {e:#}")),
                    }
                }
                let resp = match decode_err {
                    Some(msg) => {
                        // The error header carries no "frame" marker, so a
                        // stray binary payload would desync the wire.
                        frame_payload = None;
                        protocol::err(&msg)
                    }
                    None => protocol::ok(fields),
                };
                match frame_payload {
                    Some(f) => {
                        let _ = p.reply.send_framed(resp, f);
                    }
                    None => {
                        let _ = p.reply.send(resp);
                    }
                }
                p.group.pending.fetch_sub(p.n, Ordering::SeqCst);
                shared.load.fetch_sub(p.n, Ordering::SeqCst);
            }
        }
        Err(e) => {
            shared.metrics.lock().unwrap_or_else(|e| e.into_inner()).record_error();
            let msg = format!("{e:#}");
            for p in group {
                fail_request(p, &shared.load, &msg);
            }
        }
    }
}

/// The `ConvergenceBook` key for one workload: `"model/method"`.
pub(crate) fn book_key(model: &str, method: Method) -> String {
    format!("{model}/{}", method.label())
}

pub(crate) fn sample_fields(
    model: &str,
    method: Method,
    arm_calls: usize,
    calls_per_job: f64,
    calls_pct: f64,
    wall: f64,
    n: usize,
) -> Vec<(&'static str, Value)> {
    vec![
        ("model", Value::str(model)),
        ("method", Value::str(method.label())),
        ("arm_calls", Value::num(arm_calls as f64)),
        ("calls_per_job", Value::num(calls_per_job)),
        ("calls_pct", Value::num(calls_pct)),
        ("wall_secs", Value::num(wall)),
        ("n", Value::num(n as f64)),
    ]
}

pub(crate) fn images_value(imgs: &[Vec<f32>]) -> Value {
    Value::Arr(
        imgs.iter()
            .map(|im| Value::Arr(im.iter().map(|&f| Value::num(f as f64)).collect()))
            .collect(),
    )
}
