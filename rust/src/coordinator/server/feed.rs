//! The live-schedule bridge: `ServeFeed` connects an executing group's
//! elastic schedule to the serving plane — absorbing the group's own
//! mid-flight arrivals under the admission policy, answering each
//! request the moment its last job converges (each delivery rides the
//! request's `Reply` handle onto — and wakes — the connection shard
//! owning that socket), and observing the finished schedule into the
//! server-level convergence history.

use crate::coordinator::config::ServeConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{self, AdmissionCtx, AdmissionPolicy, ConvergencePrior};
use crate::coordinator::protocol;
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{self, JobFeed, LiveJob, LiveStats};
use crate::coordinator::server::pool::{fail_request, GroupKey, PendingSample, Pool, Work};
use crate::coordinator::server::worker::{book_key, images_value, sample_fields, WorkerShared};
use crate::sampler::noise::JobNoise;
use crate::sampler::JobResult;
use crate::substrate::json::Value;
use crate::substrate::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One request inside a live schedule.
struct FeedReq {
    p: PendingSample,
    results: Vec<Option<JobResult>>,
    remaining: usize,
    replied: bool,
}

/// Bridges a live schedule to the serving plane: polls the worker's
/// shared queue between ARM passes for mid-flight arrivals of the
/// executing group, and answers each request the moment its last job
/// converges (requests needing the decoder wait for the schedule to end,
/// when the router is borrowable again).
struct ServeFeed<'a> {
    pool: &'a Pool,
    widx: usize,
    key: GroupKey,
    dim: usize,
    categories: usize,
    load: &'a AtomicUsize,
    /// Decides whether an arrival of this group joins the live schedule
    /// or stays queued for the next window (fairness: a hot group must
    /// not starve other groups queued on this worker; whatever it leaves
    /// queued forms a normal next window — or gets stolen). Denial only
    /// defers — samples are identical either way.
    admission: Box<dyn AdmissionPolicy>,
    /// Jobs absorbed mid-flight so far (the initial window not counted).
    absorbed_jobs: usize,
    metrics: &'a Mutex<Metrics>,
    /// Sizing-policy label for the per-policy metric counters.
    policy_label: &'static str,
    /// Completed jobs between mid-schedule metric flushes. Age-based
    /// admission puts no bound on a schedule's lifetime (a hot group on
    /// an idle server absorbs forever), so batch/latency/policy metrics
    /// are flushed as windows every `flush_every` completions instead of
    /// only when the schedule ends — otherwise the `metrics` op would
    /// report an eternally-busy server as idle.
    flush_every: usize,
    /// Jobs / slot-passes / passes already flushed to metrics.
    flushed_jobs: usize,
    flushed_slot_passes: usize,
    flushed_passes: usize,
    /// Wall-clock of the current metrics window.
    window_timer: Timer,
    /// Absorption stops once this many requests have joined the schedule
    /// — a hygiene bound, not a fairness knob: every request leaves a
    /// small routing stub in `reqs` for its tags, so an unboundedly
    /// long-lived schedule would leak. When the cap is hit the schedule
    /// drains and ends, replies flush, and the queued backlog opens a
    /// fresh window immediately (windows are keyed to admission time,
    /// so ending costs no extra `max_wait`).
    absorb_cap: usize,
    /// Requests with jobs in the schedule; tags pack (request index,
    /// job index within the request).
    reqs: Vec<FeedReq>,
    /// Completed decode=true requests, replied after the schedule ends.
    deferred: Vec<usize>,
    /// Jobs completed across the whole schedule (group metrics).
    completed_jobs: usize,
    /// Per-job iterations summed across completions — with
    /// `completed_jobs`, the schedule's mean passes/job observation for
    /// the convergence book.
    total_iters: usize,
    last_stats: Option<LiveStats>,
}

impl<'a> ServeFeed<'a> {
    /// Flush the metrics window ending at `stats`: one `record_batch`
    /// (+ per-policy count) covering everything completed since the last
    /// flush. No-op when the window is empty.
    fn flush_window(&mut self, stats: &LiveStats) {
        let jobs = self.completed_jobs - self.flushed_jobs;
        if jobs == 0 {
            return;
        }
        let slot_passes = stats.slot_passes - self.flushed_slot_passes;
        let passes = stats.passes - self.flushed_passes;
        let calls_per_job = slot_passes as f64 / jobs as f64;
        let wall = self.window_timer.secs();
        {
            let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
            m.record_batch(jobs, passes, scheduler::calls_pct_of(calls_per_job, self.dim), wall);
            m.record_policy(self.policy_label);
        }
        self.flushed_jobs = self.completed_jobs;
        self.flushed_slot_passes = stats.slot_passes;
        self.flushed_passes = stats.passes;
        self.window_timer = Timer::start();
    }

    /// Flush whatever the last completion left unflushed (schedule end).
    fn flush_final(&mut self) {
        if let Some(stats) = self.last_stats {
            self.flush_window(&stats);
        }
    }

    /// Register a request with the schedule, returning its jobs. Noise is
    /// keyed `(seed, job index within the request)` — identical to every
    /// other serving path, which is what makes mid-flight admission exact.
    fn admit_request(&mut self, p: PendingSample) -> Vec<LiveJob> {
        let ri = self.reqs.len() as u64;
        let jobs = (0..p.n)
            .map(|j| LiveJob { tag: ri << 32 | j as u64, noise: JobNoise::new(p.seed, j as u64, self.dim, self.categories) })
            .collect();
        self.reqs.push(FeedReq { remaining: p.n, results: (0..p.n).map(|_| None).collect(), replied: false, p });
        jobs
    }

    /// Answer completed request `ri` with the schedule's stats as of now.
    /// `router` present selects the decode path (only possible once the
    /// schedule ended and the router is borrowable again).
    fn reply_request(&mut self, ri: usize, stats: &LiveStats, router: Option<&mut Router>) {
        let req = &mut self.reqs[ri];
        // Only fully-completed requests are replied (remaining == 0 gates
        // every call site); if that accounting ever breaks, answer the
        // client degraded instead of panicking the whole engine worker.
        if req.results.iter().any(|r| r.is_none()) {
            log::error!("request {}/{:?} answered with job results missing — failing it degraded", self.key.0, self.key.1);
            let _ = req.p.reply.send(protocol::err("internal: job results incomplete"));
            req.replied = true;
            req.results = Vec::new();
            req.p.group.pending.fetch_sub(req.p.n, Ordering::SeqCst);
            self.load.fetch_sub(req.p.n, Ordering::SeqCst);
            return;
        }
        // Per-request cost: each job owns its slot for exactly its pass
        // count, so slot-passes per job = mean iterations — exact under
        // occupancy sizing (every pass runs a full batch), and never
        // inflated by capacity other jobs are still consuming the way a
        // running schedule-wide ratio would be.
        let iters: usize = req.results.iter().flatten().map(|r| r.iterations).sum();
        let calls_per_job = iters as f64 / req.p.n.max(1) as f64;
        let calls_pct = scheduler::calls_pct_of(calls_per_job, self.dim);
        // Wall time is this request's serving latency (queue + schedule),
        // not the whole schedule's age — a request absorbed mid-flight
        // must not inherit the time before it arrived.
        let wall = req.p.admitted.elapsed().as_secs_f64();
        let mut fields = sample_fields(&self.key.0, self.key.1, stats.passes, calls_per_job, calls_pct, wall, req.p.n);
        let xs: Vec<Vec<i32>> = if req.p.return_samples || router.is_some() {
            req.results.iter().flatten().map(|r| r.x.clone()).collect()
        } else {
            Vec::new()
        };
        let framed = req.p.return_samples && req.p.reply.frame;
        if req.p.return_samples {
            if framed {
                // The payload rides as a binary frame after the JSON
                // line; the header only marks its presence.
                fields.push(("frame", Value::Bool(true)));
            } else {
                fields.push(("samples", protocol::samples_value(&xs)));
            }
        }
        let mut ok = true;
        let resp = match router {
            Some(router) => match router.engine(&self.key.0).and_then(|e| e.decode(&xs)) {
                Ok(imgs) => {
                    fields.push(("images", images_value(&imgs)));
                    protocol::ok(fields)
                }
                Err(e) => {
                    ok = false;
                    protocol::err(&format!("decode: {e:#}"))
                }
            },
            None => protocol::ok(fields),
        };
        // An error reply never carries the frame: its header lost the
        // "frame" marker, and a stray binary payload would desync the
        // wire.
        if framed && ok {
            let _ = req.p.reply.send_framed(resp, protocol::encode_frame(&xs));
        } else {
            let _ = req.p.reply.send(resp);
        }
        req.replied = true;
        // Drop the sample payloads now: a live schedule can absorb for a
        // long time, and only the small routing stub must outlive the
        // reply (tags index `reqs` for the schedule's whole lifetime).
        req.results = Vec::new();
        req.p.group.pending.fetch_sub(req.p.n, Ordering::SeqCst);
        self.load.fetch_sub(req.p.n, Ordering::SeqCst);
    }

    /// Schedule finished cleanly: answer deferred decode requests, then
    /// fail anything that somehow never completed (accounting safety net).
    fn finish(&mut self, router: &mut Router) {
        let stats = self.last_stats.unwrap_or(LiveStats { passes: 0, slot_passes: 0, completed: 0, upshifts: 0, downshifts: 0 });
        for ri in std::mem::take(&mut self.deferred) {
            self.reply_request(ri, &stats, Some(&mut *router));
        }
        self.fail_rest("schedule ended with jobs outstanding");
    }

    /// Fail every request that has not been answered yet.
    fn fail_rest(&mut self, why: &str) {
        for req in self.reqs.iter_mut().filter(|r| !r.replied) {
            let _ = req.p.reply.send(protocol::err(why));
            req.replied = true;
            req.p.group.pending.fetch_sub(req.p.n, Ordering::SeqCst);
            self.load.fetch_sub(req.p.n, Ordering::SeqCst);
        }
    }
}

impl JobFeed for ServeFeed<'_> {
    fn poll(&mut self) -> Vec<LiveJob> {
        // Stop absorbing — letting the schedule drain and end — once (a)
        // a completed decode request is waiting on the router (deferred
        // replies can only be sent after the schedule ends, when the
        // router is borrowable again), or (b) the request table hit its
        // hygiene cap. Queued arrivals just form the next window.
        if !self.deferred.is_empty() || self.reqs.len() >= self.absorb_cap {
            return Vec::new();
        }
        let mut fresh: Vec<PendingSample> = Vec::new();
        let mut denied = false;
        {
            let mut st = self.pool.state.lock().unwrap_or_else(|e| e.into_inner());
            // The oldest admission among work of *other* groups queued on
            // this worker — whatever absorption would starve. Evals count
            // too: without them, an endlessly-absorbing group could hold
            // a queued eval past any bound (no budget caps the schedule
            // any more).
            let oldest_other = st.queues[self.widx]
                .iter()
                .filter_map(|it| match it {
                    Work::Sample(p) if !(p.model == self.key.0 && p.method == self.key.1) => Some(p.admitted),
                    Work::Sample(_) => None,
                    Work::Eval { admitted, .. } => Some(*admitted),
                })
                .min();
            let oldest_other_age = oldest_other.map(|t| t.elapsed());
            // Take this group's arrivals, oldest first, while the
            // admission policy accepts them. The first denial stops the
            // sweep — later arrivals are younger still — and leaves the
            // denied requests queued in place for the next window (or a
            // thief), preserving arrival order.
            let q = &mut st.queues[self.widx];
            let mut i = 0;
            while i < q.len() {
                let decision = match &q[i] {
                    Work::Sample(p) if p.model == self.key.0 && p.method == self.key.1 => {
                        let ctx = AdmissionCtx { jobs: p.n, absorbed: self.absorbed_jobs, age: p.admitted.elapsed(), oldest_other_age };
                        Some(self.admission.admit(&ctx))
                    }
                    _ => None,
                };
                match decision {
                    Some(true) => {
                        let Some(Work::Sample(p)) = q.remove(i) else { unreachable!("just matched") };
                        self.absorbed_jobs += p.n;
                        fresh.push(p);
                        if self.reqs.len() + fresh.len() >= self.absorb_cap {
                            break;
                        }
                    }
                    Some(false) => {
                        denied = true;
                        break;
                    }
                    None => i += 1,
                }
            }
        }
        if !fresh.is_empty() || denied {
            let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
            for p in &fresh {
                m.record_absorbed(p.n);
                m.record_admission_age(p.admitted.elapsed());
            }
            if denied {
                m.record_absorb_denial();
            }
        }
        let mut jobs = Vec::new();
        for p in fresh {
            jobs.extend(self.admit_request(p));
        }
        jobs
    }

    fn complete(&mut self, tag: u64, result: JobResult, stats: &LiveStats) {
        self.completed_jobs += 1;
        self.total_iters += result.iterations;
        self.last_stats = Some(*stats);
        let (ri, j) = ((tag >> 32) as usize, (tag & 0xffff_ffff) as usize);
        let req = &mut self.reqs[ri];
        if req.p.reply.stream {
            // Streaming delivery: push this job's sample the moment it
            // converges, ahead of the request's closing summary. Sent
            // before the result is stored so the row needs no re-borrow.
            let row = &result.x;
            let frame = if req.p.reply.frame { Some(protocol::encode_frame(std::slice::from_ref(row))) } else { None };
            let framed = frame.is_some();
            let _ = req.p.reply.send_event(protocol::stream_event(j, row, framed), frame);
        }
        req.results[j] = Some(result);
        req.remaining -= 1;
        if req.remaining == 0 {
            if req.p.decode {
                self.deferred.push(ri);
            } else {
                self.reply_request(ri, stats, None);
            }
        }
        if self.completed_jobs - self.flushed_jobs >= self.flush_every {
            self.flush_window(stats);
        }
    }
}

/// Execute a group as a **live** schedule: the initial window plus every
/// mid-flight arrival the feed absorbs (gated by the configured
/// [`AdmissionPolicy`]), sized per pass by the configured
/// [`policy::SizingPolicy`] — its convergence EWMAs seeded from the
/// server-level history for this workload — with per-request replies as
/// they complete. A finished schedule observes its mean passes/job and
/// pass wall-time back into the history.
pub(crate) fn execute_elastic_group(
    router: &mut Router,
    shared: &WorkerShared,
    group: Vec<PendingSample>,
    pool: &Pool,
    widx: usize,
    cfg: &ServeConfig,
) {
    if group.is_empty() {
        return;
    }
    let key = (group[0].model.clone(), group[0].method);
    let shape = router.engine(&key.0).map(|e| (e.info.dim, e.info.categories));
    let (dim, categories) = match shape {
        Ok(s) => s,
        Err(e) => {
            shared.metrics.lock().unwrap_or_else(|e| e.into_inner()).record_error();
            let msg = format!("{e:#}");
            for p in group {
                fail_request(p, &shared.load, &msg);
            }
            return;
        }
    };
    let method = key.1;
    let sizing = policy::sizing_for(cfg.policy, cfg.slo);
    let workload = book_key(&key.0, method);
    let prior = shared.book.prior(&workload);
    let mut feed = ServeFeed {
        pool,
        widx,
        key: key.clone(),
        dim,
        categories,
        load: &shared.load,
        admission: policy::admission_for(cfg.admission, cfg.max_wait),
        absorbed_jobs: 0,
        metrics: &shared.metrics,
        policy_label: sizing.name(),
        flush_every: cfg.max_batch.max(1) * 8,
        flushed_jobs: 0,
        flushed_slot_passes: 0,
        flushed_passes: 0,
        window_timer: Timer::start(),
        absorb_cap: cfg.max_batch.max(1) * 64,
        reqs: Vec::new(),
        deferred: Vec::new(),
        completed_jobs: 0,
        total_iters: 0,
        last_stats: None,
    };
    let mut initial = Vec::new();
    for p in group {
        initial.extend(feed.admit_request(p));
    }
    let rep = router.engine(&key.0).and_then(|e| e.sample_elastic_primed(method, initial, &mut feed, sizing.as_ref(), prior));
    match rep {
        Ok(rep) => {
            feed.flush_final();
            feed.finish(router);
            if rep.total_passes > 0 && feed.completed_jobs > 0 {
                let obs = ConvergencePrior {
                    passes_per_job: feed.total_iters as f64 / feed.completed_jobs as f64,
                    pass_secs: rep.wall_secs / rep.total_passes as f64,
                };
                shared.book.observe(&workload, obs);
            }
        }
        Err(e) => {
            shared.metrics.lock().unwrap_or_else(|e| e.into_inner()).record_error();
            feed.fail_rest(&format!("{e:#}"));
        }
    }
}
