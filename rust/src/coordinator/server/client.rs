//! Minimal blocking client for examples, benches and tests.

use crate::substrate::json::Value;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

/// One line-delimited-JSON connection to a predsamp server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, wait for the response.
    pub fn call(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            // A clean EOF is not a malformed response: say what happened.
            anyhow::bail!("connection closed by server");
        }
        Ok(crate::substrate::json::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }
}
