//! Minimal blocking client for examples, benches and tests. Speaks the
//! full wire surface: plain request/response, pipelining by `id` (via
//! [`Client::send_line`] + [`Client::read_message`]), streamed per-job
//! events, and length-prefixed binary sample frames — which it decodes
//! and splices back into the message, so callers see the same `Value`
//! shape whether or not the payload rode as binary.

use crate::coordinator::protocol;
use crate::substrate::json::Value;
use anyhow::Result;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

/// One line-delimited-JSON connection to a predsamp server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line without waiting for anything back — the
    /// pipelining half; pair with [`Client::read_message`].
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Read one message off the wire: a JSON line, plus — when the line
    /// carries `"frame": true` — the binary frame that follows it, decoded
    /// and spliced back in (`"sample"` on a stream event, `"samples"` on a
    /// final response), so framed and unframed replies look identical.
    pub fn read_message(&mut self) -> Result<Value> {
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            // A clean EOF is not a malformed response: say what happened.
            anyhow::bail!("connection closed by server");
        }
        let msg = crate::substrate::json::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if msg.get("frame").as_bool() != Some(true) {
            return Ok(msg);
        }
        let mut len4 = [0u8; 4];
        self.reader.read_exact(&mut len4)?;
        // Validate the declared length before allocating for it.
        let len = protocol::frame_payload_len(len4).map_err(|e| anyhow::anyhow!(e))?;
        let mut payload = vec![0u8; len];
        self.reader.read_exact(&mut payload)?;
        let rows = protocol::decode_frame(&payload).map_err(|e| anyhow::anyhow!("bad frame: {e}"))?;
        let Value::Obj(mut obj) = msg else {
            anyhow::bail!("framed message is not an object");
        };
        if obj.get("stream").and_then(Value::as_bool) == Some(true) {
            let row = rows.into_iter().next().unwrap_or_default();
            obj.insert("sample".into(), Value::Arr(row.into_iter().map(|v| Value::num(v as f64)).collect()));
        } else {
            obj.insert("samples".into(), protocol::samples_value(&rows));
        }
        Ok(Value::Obj(obj))
    }

    /// Send one request line, wait for its closing response — skipping
    /// (discarding) any streamed per-job events along the way, so callers
    /// that never opted into streaming are unaffected by it.
    pub fn call(&mut self, line: &str) -> Result<Value> {
        self.send_line(line)?;
        loop {
            let msg = self.read_message()?;
            if msg.get("stream").as_bool() != Some(true) {
                return Ok(msg);
            }
        }
    }

    /// Send one request line, hand each streamed per-job event to
    /// `on_event` as it arrives, and return the closing response.
    pub fn call_streamed(&mut self, line: &str, on_event: &mut dyn FnMut(&Value)) -> Result<Value> {
        self.send_line(line)?;
        loop {
            let msg = self.read_message()?;
            if msg.get("stream").as_bool() == Some(true) {
                on_event(&msg);
            } else {
                return Ok(msg);
            }
        }
    }
}
