//! Federation: a front-tier router that fans the serving protocol
//! across N backend coordinator *processes*.
//!
//! This is the placement plane generalized one level up — process =
//! worker. The router accepts the existing wire protocol on its own
//! sharded connection plane (the same `conn.rs` event loops the server
//! uses), maps each model namespace to a backend via [`FleetPlacement`]
//! (reusing [`PlacementKind`] semantics: replicate / pinned /
//! capacity-capped), and proxies requests over one persistent pipelined
//! client connection per backend:
//!
//! ```text
//! clients ──TCP──▶ router connection plane (conn.rs shards)
//!                      │ (Request, Reply)            ▲ completions
//!                      ▼                             │
//!                route loop (single thread, owns all fleet state):
//!                  hop guard → FleetPlacement (sticky per model)
//!                  → re-striped upstream ids → PendingProxy table
//!                      │ one pipelined TCP conn     ▲ reader thread
//!                      │ per backend                │ per backend
//!        ┌─────────────┼─────────────┐              │
//!        ▼             ▼             ▼              │
//!   coordinator 0  coordinator 1  coordinator 2   (predsamp serve)
//!        ▲  periodic `info` probes (prober thread) ─┘
//! ```
//!
//! Requests are forwarded verbatim apart from the envelope: the router
//! re-stripes correlation ids per backend (each tier owns its own id
//! space), advances the `hop` count, and forwards streamed events and
//! binary frames byte-for-byte. Backends are health-checked two ways —
//! a periodic `info` probe (healthy → suspect → dead after
//! `probe_fails` misses) and connection-error detection on the
//! forwarding link itself. When a backend dies, every model namespace
//! it owned is re-homed to an eligible live backend and its in-flight
//! requests are re-submitted from their stored job manifests — the same
//! dead-worker re-homing `server/pool.rs` does inside one process,
//! lifted across a socket. Streamed events the client already received
//! are deduplicated by job index on replay.
//!
//! Exactness survives federation: job noise is keyed by `(seed, job
//! index)` — never by process, backend, or arrival — so a federated
//! fleet produces bitwise-identical samples to a single process, even
//! with a backend killed mid-stream (`rust/tests/federation_test.rs`).

use crate::coordinator::config::ServeConfig;
use crate::coordinator::placement::PlacementKind;
use crate::coordinator::protocol::{self, Request, RequestMeta};
use crate::coordinator::server::conn::EdgeStats;
use crate::coordinator::server::pool::Reply;
use crate::coordinator::server::{conn, Msg};
use crate::substrate::json::{self, Value};
use crate::substrate::readiness::{ReadinessKind, Waker};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Front-tier router configuration (`predsamp route`). Every knob is
/// documented in `docs/ARCHITECTURE.md`'s federation table; the
/// doc-parity lint pass keeps that table and the CLI in sync with this
/// struct.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Listen address for the router's own connection plane (`--addr`;
    /// port 0 binds ephemeral).
    pub addr: String,
    /// Backend coordinator addresses (`--backend host:port`, repeatable;
    /// at least one). Backend index = position in this list.
    pub backends: Vec<String>,
    /// Fleet placement policy (`--fleet-placement`, `--fleet-pin`,
    /// `--fleet-max-backends`): which backends may own which model
    /// namespaces, with [`PlacementKind`] semantics one level up
    /// (process = worker).
    pub fleet_placement: PlacementKind,
    /// Delay between health-probe rounds (`--probe-interval-ms`).
    pub probe_interval: Duration,
    /// Per-probe connect/read deadline, also used when dialing a
    /// forwarding link (`--probe-timeout-ms`).
    pub probe_timeout: Duration,
    /// Consecutive failed probes before a backend is declared dead and
    /// its namespaces re-homed (`--probe-fails`). Connection errors on
    /// the forwarding link kill immediately regardless.
    pub probe_fails: u32,
    /// Requests whose envelope `hop` count has reached this limit are
    /// rejected instead of forwarded (`--max-hops`) — a routing cycle
    /// dies with an error, not a forwarding storm.
    pub max_hops: u32,
    /// Connection-plane shards for the router's own edge
    /// (`--conn-threads`), exactly as on `predsamp serve`.
    pub conn_threads: usize,
    /// Readiness backend for those shards (`--readiness`).
    pub readiness: ReadinessKind,
    /// Maximum client request line length (`--max-line-len`).
    pub max_line_len: usize,
    /// Per-connection outbound buffer cap (`--outbound-cap`).
    pub outbound_cap: usize,
    /// Per-connection request rate limit, 0 = unlimited (`--rate-limit`).
    pub rate_limit: u32,
    /// Maximum simultaneously open client connections (`--max-conns`).
    pub max_conns: usize,
    /// How long a client request may stay unanswered before the edge
    /// fails it (`--reply-timeout-ms`) — covers the full proxied round
    /// trip, re-homing included.
    pub reply_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        let edge = ServeConfig::default();
        RouterConfig {
            addr: "127.0.0.1:7190".into(),
            backends: Vec::new(),
            fleet_placement: PlacementKind::ReplicateAll,
            probe_interval: Duration::from_millis(200),
            probe_timeout: Duration::from_secs(1),
            probe_fails: 3,
            max_hops: 4,
            conn_threads: 1,
            readiness: ReadinessKind::Auto,
            max_line_len: edge.max_line_len,
            outbound_cap: edge.outbound_cap,
            rate_limit: edge.rate_limit,
            max_conns: edge.max_conns,
            reply_timeout: edge.reply_timeout,
        }
    }
}

impl RouterConfig {
    /// Sanity-check knob ranges before spinning up threads. Edge knobs
    /// ride the [`ServeConfig`] rules via [`RouterConfig::serve_cfg`];
    /// fleet-placement pins are checked by [`FleetPlacement::new`].
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.backends.is_empty(), "router config: at least one --backend is required");
        ensure!(self.backends.len() <= 64, "router config: more than 64 backends is not a front tier");
        for (i, a) in self.backends.iter().enumerate() {
            ensure!(!a.is_empty(), "router config: backend {i} has an empty address");
            ensure!(
                self.backends[..i].iter().all(|b| b != a),
                "router config: duplicate backend address {a:?} (each backend is one process)"
            );
        }
        ensure!((1..=16).contains(&self.max_hops), "router config: max_hops must be in [1, 16]");
        ensure!((1..=100).contains(&self.probe_fails), "router config: probe_fails must be in [1, 100]");
        ensure!(
            self.probe_interval >= Duration::from_millis(10) && self.probe_interval <= Duration::from_secs(60),
            "router config: probe_interval must be in [10ms, 60s]"
        );
        ensure!(
            self.probe_timeout >= Duration::from_millis(10) && self.probe_timeout <= Duration::from_secs(60),
            "router config: probe_timeout must be in [10ms, 60s]"
        );
        FleetPlacement::new(self.fleet_placement.clone(), self.backends.len())?;
        self.serve_cfg().validate()
    }

    /// The [`ServeConfig`] the router's own connection plane runs under:
    /// the shared edge knobs carried over, engine knobs left at their
    /// defaults (the router has no engines), streaming and framing
    /// always on (delivery modes are the backend's call to honor and the
    /// router's job to forward).
    pub fn serve_cfg(&self) -> ServeConfig {
        ServeConfig {
            addr: self.addr.clone(),
            conn_threads: self.conn_threads,
            readiness: self.readiness,
            max_line_len: self.max_line_len,
            outbound_cap: self.outbound_cap,
            rate_limit: self.rate_limit,
            max_conns: self.max_conns,
            reply_timeout: self.reply_timeout,
            streaming: true,
            framing: true,
            ..ServeConfig::default()
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet placement
// ---------------------------------------------------------------------------

/// The placement plane one level up: which backend *process* owns which
/// model namespace. Reuses [`PlacementKind`] semantics with backend
/// index in place of worker index — replicate-all (any live backend),
/// pinned (explicit backend subsets per model), capacity-capped (a soft
/// per-backend namespace budget). Routing is sticky per model: once a
/// namespace lands on a backend it stays until that backend dies, and a
/// re-admitted backend does not pull its old namespaces back (stability
/// over perfect balance). Fresh picks use rendezvous hashing over the
/// model name, so they are deterministic and stable under backend
/// removal: only the dead backend's namespaces move.
#[derive(Clone, Debug)]
pub struct FleetPlacement {
    kind: PlacementKind,
    n: usize,
}

impl FleetPlacement {
    /// Resolve a placement kind against the backend count, rejecting
    /// out-of-range pins and a zero capacity budget up front.
    pub fn new(kind: PlacementKind, n: usize) -> Result<FleetPlacement> {
        ensure!(n >= 1, "fleet placement: at least one backend");
        match &kind {
            PlacementKind::ReplicateAll => {}
            PlacementKind::Pinned(pins) => {
                for (model, backends) in pins {
                    ensure!(!backends.is_empty(), "fleet placement: model {model:?} is pinned to no backend");
                    for &b in backends {
                        ensure!(b < n, "fleet placement: model {model:?} pinned to backend {b}, but only {n} configured");
                    }
                }
            }
            PlacementKind::CapacityCapped(cap) => {
                ensure!(*cap >= 1, "fleet placement: --fleet-max-backends capacity must be >= 1");
            }
        }
        Ok(FleetPlacement { kind, n })
    }

    /// Number of backends this placement routes over.
    pub fn backends(&self) -> usize {
        self.n
    }

    /// The canonical `--fleet-placement` spelling.
    pub fn label(&self) -> &'static str {
        self.kind.label()
    }

    /// May backend `b` own model namespace `model`? Pinned models are
    /// restricted to their pin set; everything else (and every model
    /// under replicate/capped) is eligible anywhere.
    pub fn eligible(&self, model: &str, b: usize) -> bool {
        if b >= self.n {
            return false;
        }
        match &self.kind {
            PlacementKind::Pinned(pins) => pins
                .iter()
                .find(|(m, _)| m == model)
                .map(|(_, backends)| backends.contains(&b))
                .unwrap_or(true),
            _ => true,
        }
    }

    /// Pick the backend for `model`: the sticky owner if it is still
    /// live and eligible, otherwise a fresh rendezvous-hash pick over
    /// the live eligible backends (capacity-capped placements prefer
    /// backends under their namespace budget, falling back to all
    /// eligible when every one is at capacity — a soft cap, so routing
    /// stays total). `None` only when no live backend is eligible.
    pub fn route(&self, model: &str, live: &[bool], owned: &BTreeMap<String, usize>) -> Option<usize> {
        if let Some(&b) = owned.get(model) {
            if b < live.len() && live[b] && self.eligible(model, b) {
                return Some(b);
            }
        }
        let candidates: Vec<usize> = (0..self.n).filter(|&b| live.get(b) == Some(&true) && self.eligible(model, b)).collect();
        if candidates.is_empty() {
            return None;
        }
        let pool = match self.kind {
            PlacementKind::CapacityCapped(cap) => {
                let within: Vec<usize> = candidates
                    .iter()
                    .copied()
                    .filter(|&b| owned.values().filter(|&&o| o == b).count() < cap)
                    .collect();
                if within.is_empty() {
                    candidates
                } else {
                    within
                }
            }
            _ => candidates,
        };
        pool.into_iter().max_by_key(|&b| rendezvous_weight(model, b))
    }
}

/// FNV-1a rendezvous weight for `(model, backend)` — deterministic (no
/// ambient RNG) and independent across backends, which is exactly what
/// makes highest-random-weight routing stable under removal.
fn rendezvous_weight(model: &str, b: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &byte in model.as_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    for byte in (b as u64).to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Probe state machine
// ---------------------------------------------------------------------------

/// A backend's health as the prober sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Last probe succeeded.
    Healthy,
    /// Probes are failing but the miss budget is not exhausted; the
    /// backend keeps receiving traffic.
    Suspect,
    /// Probe budget exhausted or a connection error on the forwarding
    /// link: namespaces re-homed, no traffic until a probe succeeds.
    Dead,
}

impl Health {
    /// Metrics label.
    pub fn label(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Suspect => "suspect",
            Health::Dead => "dead",
        }
    }
}

/// Pure per-backend probe state machine: healthy → suspect → dead after
/// `threshold` consecutive misses → re-admitted on the next successful
/// probe. Connection errors on the forwarding link skip straight to
/// dead — a peer that actively refuses bytes needs no second opinion.
/// Re-admission makes the backend eligible for *fresh* namespaces only;
/// the router never moves re-homed namespaces back (stability).
#[derive(Clone, Debug)]
pub struct ProbeState {
    health: Health,
    fails: u32,
    threshold: u32,
}

impl ProbeState {
    /// A healthy backend with a miss budget of `threshold` probes.
    pub fn new(threshold: u32) -> ProbeState {
        ProbeState { health: Health::Healthy, fails: 0, threshold: threshold.max(1) }
    }

    /// A probe succeeded. Returns true when this re-admitted a dead
    /// backend.
    pub fn on_ok(&mut self) -> bool {
        let readmitted = self.health == Health::Dead;
        self.health = Health::Healthy;
        self.fails = 0;
        readmitted
    }

    /// A probe failed. Returns true when this crossed the miss budget
    /// and killed the backend.
    pub fn on_err(&mut self) -> bool {
        if self.health == Health::Dead {
            return false;
        }
        self.fails += 1;
        if self.fails >= self.threshold {
            self.health = Health::Dead;
            true
        } else {
            self.health = Health::Suspect;
            false
        }
    }

    /// The forwarding link itself errored: immediately dead. Returns
    /// true when the backend was not already dead.
    pub fn on_conn_error(&mut self) -> bool {
        let killed = self.health != Health::Dead;
        self.health = Health::Dead;
        self.fails = self.fails.max(self.threshold);
        killed
    }

    /// Current health.
    pub fn health(&self) -> Health {
        self.health
    }

    /// Live = not dead: suspect backends keep their traffic until the
    /// miss budget runs out.
    pub fn is_live(&self) -> bool {
        self.health != Health::Dead
    }
}

// ---------------------------------------------------------------------------
// Router runtime
// ---------------------------------------------------------------------------

/// Everything that can wake the route loop.
enum RouterMsg {
    /// A client request off the router's own connection plane.
    Client(Request, Reply),
    /// One response line (plus optional binary frame, prefix included)
    /// read from a backend link. `gen` guards against a stale reader
    /// racing a reconnect.
    Upstream { backend: usize, gen: u64, line: String, frame: Option<Vec<u8>> },
    /// A backend link hit EOF or a read error.
    BackendDown { backend: usize, gen: u64 },
    /// One health-probe result from the prober thread.
    Probe { backend: usize, ok: bool, latency_s: f64 },
    /// Stop routing.
    Shutdown,
}

/// One client request in flight on a backend: the reply handle back to
/// the client's connection shard, the serialized request line (no id —
/// re-submission splices a fresh one), the model namespace (`None` for
/// forwarded `info`, which cannot be re-homed), and the job indices
/// already streamed to the client (replayed events deduplicate against
/// this after a re-home; exactness makes the replayed bytes identical).
struct PendingProxy {
    reply: Reply,
    wire: String,
    model: Option<String>,
    delivered: BTreeSet<u64>,
}

/// A live forwarding link to one backend: the write half plus the
/// reader thread draining the read half. `gen` increments per
/// (re)connect so messages from a replaced reader are discarded.
struct Link {
    gen: u64,
    writer: TcpStream,
    reader: Option<std::thread::JoinHandle<()>>,
}

/// Per-backend routing state.
struct Backend {
    addr: String,
    probe: ProbeState,
    link: Option<Link>,
    gen: u64,
    in_flight: BTreeMap<u64, PendingProxy>,
    forwarded: u64,
    probe_latency_s: f64,
}

/// The route loop's single-threaded state: one thread owns the fleet
/// table, the sticky namespace map, and the upstream id counter
/// outright, so the router adds no locks (see lock-discipline in
/// `docs/ANALYSIS.md`).
struct RouteState {
    cfg: RouterConfig,
    placement: FleetPlacement,
    backends: Vec<Backend>,
    /// Sticky namespace ownership: model → backend index.
    owned: BTreeMap<String, usize>,
    /// Monotonic upstream correlation ids, re-striped across every
    /// backend (each tier owns its own id space).
    next_uid: u64,
    forwards: u64,
    re_homes: u64,
    hop_rejections: u64,
    orphaned: u64,
    rtx: mpsc::Sender<RouterMsg>,
    edge: Arc<EdgeStats>,
    started: Instant,
}

impl RouteState {
    fn handle_client(&mut self, req: Request, reply: Reply) {
        if reply.hop >= self.cfg.max_hops {
            self.hop_rejections += 1;
            let _ = reply.send(protocol::err(&format!("federation hop limit reached ({} hops)", self.cfg.max_hops)));
            return;
        }
        match &req {
            Request::Ping => {
                let _ = reply.send(protocol::ok(vec![("pong", Value::Bool(true))]));
                return;
            }
            Request::Metrics => {
                let line = router_metrics_response(self, self.started.elapsed().as_secs_f64());
                let _ = reply.send(line);
                return;
            }
            _ => {}
        }
        let model = match &req {
            Request::Eval { model } => Some(model.clone()),
            Request::Sample { model, .. } => Some(model.clone()),
            _ => None,
        };
        let meta = RequestMeta { id: None, stream: reply.stream, frame: reply.frame, hop: reply.hop + 1 };
        let wire = protocol::request_line(&req, &meta);
        self.submit(PendingProxy { reply, wire, model, delivered: BTreeSet::new() });
    }

    /// Route and forward one pending request, marking backends dead and
    /// retrying until it lands on a live backend or none remains. The
    /// `fail_backend` recursion inside the retry loop is bounded by the
    /// backend count: every iteration kills one.
    fn submit(&mut self, mut pending: PendingProxy) {
        loop {
            let live: Vec<bool> = self.backends.iter().map(|b| b.probe.is_live()).collect();
            let target = match &pending.model {
                Some(m) => self.placement.route(m, &live, &self.owned),
                // Model-less forwards (info) go to the healthiest
                // backend available; they are not namespace-sticky.
                None => self
                    .backends
                    .iter()
                    .position(|b| b.probe.health() == Health::Healthy)
                    .or_else(|| live.iter().position(|&l| l)),
            };
            let Some(b) = target else {
                let _ = pending.reply.send(protocol::err("no live backend is eligible for this request"));
                return;
            };
            if let Some(m) = &pending.model {
                self.owned.insert(m.clone(), b);
            }
            match self.forward_to(b, pending) {
                Ok(()) => return,
                Err(p) => {
                    pending = p;
                    self.fail_backend(b);
                }
            }
        }
    }

    /// Write one pending request to backend `b` with a fresh upstream
    /// id, dialing the link first if needed. On failure the pending is
    /// handed back so the caller can re-route it.
    fn forward_to(&mut self, b: usize, pending: PendingProxy) -> Result<(), PendingProxy> {
        if self.backends[b].link.is_none() {
            let gen = self.backends[b].gen + 1;
            match open_link(&self.backends[b].addr, b, gen, self.cfg.probe_timeout, &self.rtx) {
                Ok(link) => {
                    self.backends[b].gen = gen;
                    self.backends[b].link = Some(link);
                }
                Err(e) => {
                    log::warn!("federation: dialing backend {b} ({}): {e}", self.backends[b].addr);
                    return Err(pending);
                }
            }
        }
        let uid = self.next_uid;
        let line = protocol::with_id(&pending.wire, uid);
        let Some(link) = self.backends[b].link.as_mut() else {
            return Err(pending);
        };
        if let Err(e) = write_line(&mut link.writer, &line) {
            log::warn!("federation: writing to backend {b} ({}): {e}", self.backends[b].addr);
            return Err(pending);
        }
        self.next_uid += 1;
        self.forwards += 1;
        self.backends[b].forwarded += 1;
        self.backends[b].in_flight.insert(uid, pending);
        Ok(())
    }

    /// Declare backend `b` dead, tear down its link, and re-home its
    /// in-flight requests: each is re-routed and re-submitted from its
    /// stored manifest line with a fresh upstream id. Already-streamed
    /// jobs replay on the new backend and deduplicate against
    /// `delivered` — exactness makes the replayed bytes identical, so
    /// the client sees every job exactly once. Model-less forwards
    /// (info) cannot be re-homed and fail to the client.
    fn fail_backend(&mut self, b: usize) {
        let newly = self.backends[b].probe.on_conn_error();
        drop_link(&mut self.backends[b]);
        let pendings: Vec<PendingProxy> = std::mem::take(&mut self.backends[b].in_flight).into_values().collect();
        if newly || !pendings.is_empty() {
            log::warn!("federation: backend {b} ({}) is dead; re-homing {} in-flight request(s)", self.backends[b].addr, pendings.len());
        }
        for p in pendings {
            if p.model.is_some() {
                self.re_homes += 1;
                self.submit(p);
            } else {
                let _ = p.reply.send(protocol::err("backend connection lost while forwarding"));
            }
        }
    }

    /// One line (and optional frame) read off a backend link: match it
    /// to its pending proxy by upstream id and forward it to the client
    /// verbatim — stream events via `send_event` (deduplicated by job
    /// index after a re-home replay), finals via `send`/`send_framed`,
    /// which also retires the pending entry.
    fn handle_upstream(&mut self, backend: usize, gen: u64, line: String, frame: Option<Vec<u8>>) {
        if self.backends[backend].link.as_ref().map(|l| l.gen) != Some(gen) {
            return; // stale reader from before a reconnect
        }
        let (uid, tail) = protocol::strip_id(&line);
        let Some(uid) = uid else {
            self.orphaned += 1;
            log::warn!("federation: unmatched line from backend {backend}: {line}");
            return;
        };
        let body = protocol::reopen(tail);
        let parsed = json::parse(&body).unwrap_or(Value::Null);
        if parsed.get("stream").as_bool() == Some(true) {
            let Some(p) = self.backends[backend].in_flight.get_mut(&uid) else {
                self.orphaned += 1;
                return;
            };
            let fresh = match parsed.get("job").as_i64().filter(|&j| j >= 0) {
                Some(j) => p.delivered.insert(j as u64),
                None => true,
            };
            if fresh {
                let _ = p.reply.send_event(body, frame);
            }
        } else {
            let Some(p) = self.backends[backend].in_flight.remove(&uid) else {
                self.orphaned += 1;
                return;
            };
            match frame {
                Some(f) => {
                    let _ = p.reply.send_framed(body, f);
                }
                None => {
                    let _ = p.reply.send(body);
                }
            }
        }
    }

    fn handle_down(&mut self, backend: usize, gen: u64) {
        if self.backends[backend].link.as_ref().map(|l| l.gen) != Some(gen) {
            return; // a reconnect already replaced this link
        }
        self.fail_backend(backend);
    }

    fn handle_probe(&mut self, backend: usize, ok: bool, latency_s: f64) {
        self.backends[backend].probe_latency_s = latency_s;
        if ok {
            if self.backends[backend].probe.on_ok() {
                log::info!("federation: backend {backend} ({}) re-admitted after a successful probe", self.backends[backend].addr);
            }
        } else if self.backends[backend].probe.on_err() {
            log::warn!("federation: backend {backend} ({}) exhausted its probe budget", self.backends[backend].addr);
            self.fail_backend(backend);
        }
    }

    /// Tear everything down: links closed, readers joined, any still
    /// in-flight request failed to its client.
    fn shutdown(mut self) {
        for b in &mut self.backends {
            drop_link(b);
            for (_, p) in std::mem::take(&mut b.in_flight) {
                let _ = p.reply.send(protocol::err("router shutting down"));
            }
        }
    }
}

/// The `fleet` metrics section: per-backend health gauges plus the
/// router-level counters. (The doc-parity lint pass scans this function
/// — every key here must be documented in `docs/PROTOCOL.md`.)
fn fleet_value(st: &RouteState, uptime_s: f64) -> Value {
    let backends: Vec<Value> = st
        .backends
        .iter()
        .enumerate()
        .map(|(i, b)| {
            Value::obj(vec![
                ("id", Value::num(i as f64)),
                ("addr", Value::str(b.addr.clone())),
                ("health", Value::str(b.probe.health().label())),
                ("in_flight", Value::num(b.in_flight.len() as f64)),
                ("forwarded", Value::num(b.forwarded as f64)),
                ("probe_latency_s", Value::num(b.probe_latency_s)),
            ])
        })
        .collect();
    Value::obj(vec![
        ("backends", Value::Arr(backends)),
        ("fleet_placement", Value::str(st.placement.label())),
        ("live_backends", Value::num(st.backends.iter().filter(|b| b.probe.is_live()).count() as f64)),
        ("forwards", Value::num(st.forwards as f64)),
        ("re_homes", Value::num(st.re_homes as f64)),
        ("hop_rejections", Value::num(st.hop_rejections as f64)),
        ("orphaned", Value::num(st.orphaned as f64)),
        ("uptime_s", Value::num(uptime_s)),
    ])
}

/// The router's local `metrics` answer: its own edge section plus the
/// `fleet` section. Backend engine metrics stay one hop away — ask a
/// backend directly (or via `info`) for engine-level gauges.
fn router_metrics_response(st: &RouteState, uptime_s: f64) -> String {
    protocol::ok(vec![(
        "metrics",
        Value::obj(vec![("edge", st.edge.value()), ("fleet", fleet_value(st, uptime_s))]),
    )])
}

fn route_loop(cfg: RouterConfig, placement: FleetPlacement, rrx: mpsc::Receiver<RouterMsg>, rtx: mpsc::Sender<RouterMsg>, edge: Arc<EdgeStats>) {
    let backends = cfg
        .backends
        .iter()
        .map(|addr| Backend {
            addr: addr.clone(),
            probe: ProbeState::new(cfg.probe_fails),
            link: None,
            gen: 0,
            in_flight: BTreeMap::new(),
            forwarded: 0,
            probe_latency_s: 0.0,
        })
        .collect();
    let mut st = RouteState {
        cfg,
        placement,
        backends,
        owned: BTreeMap::new(),
        next_uid: 1,
        forwards: 0,
        re_homes: 0,
        hop_rejections: 0,
        orphaned: 0,
        rtx,
        edge,
        started: Instant::now(),
    };
    loop {
        match rrx.recv() {
            Err(_) | Ok(RouterMsg::Shutdown) => break,
            Ok(RouterMsg::Client(req, reply)) => st.handle_client(req, reply),
            Ok(RouterMsg::Upstream { backend, gen, line, frame }) => st.handle_upstream(backend, gen, line, frame),
            Ok(RouterMsg::BackendDown { backend, gen }) => st.handle_down(backend, gen),
            Ok(RouterMsg::Probe { backend, ok, latency_s }) => st.handle_probe(backend, ok, latency_s),
        }
    }
    st.shutdown();
}

// ---------------------------------------------------------------------------
// Backend links, reader threads, prober
// ---------------------------------------------------------------------------

/// Resolve and dial `addr` with a connect deadline; tries each resolved
/// address in order.
fn connect(addr: &str, timeout: Duration) -> std::io::Result<TcpStream> {
    let mut last = std::io::Error::new(std::io::ErrorKind::NotFound, format!("no address resolves for {addr}"));
    for a in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&a, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = e,
        }
    }
    Err(last)
}

fn write_line(w: &mut TcpStream, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Dial one backend and start its reader thread.
fn open_link(addr: &str, backend: usize, gen: u64, timeout: Duration, rtx: &mpsc::Sender<RouterMsg>) -> std::io::Result<Link> {
    let writer = connect(addr, timeout)?;
    let _ = writer.set_nodelay(true);
    let read_half = writer.try_clone()?;
    let reader_rtx = rtx.clone();
    let reader = std::thread::Builder::new()
        .name(format!("predsamp-fed-read-{backend}"))
        .spawn(move || backend_reader(read_half, backend, gen, reader_rtx))?;
    Ok(Link { gen, writer, reader: Some(reader) })
}

/// Close a backend link (shutting the socket down unblocks the reader)
/// and join its reader thread.
fn drop_link(b: &mut Backend) {
    if let Some(mut link) = b.link.take() {
        let _ = link.writer.shutdown(Shutdown::Both);
        if let Some(j) = link.reader.take() {
            let _ = j.join();
        }
    }
}

/// Reader half of a backend link: one response line per iteration, with
/// the binary frame (length prefix included, validated before
/// allocation) slurped off the same stream when the line declares one.
/// EOF or a read error reports `BackendDown` and exits.
fn backend_reader(stream: TcpStream, backend: usize, gen: u64, rtx: mpsc::Sender<RouterMsg>) {
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let text = line.trim_end_matches(['\r', '\n']);
        if text.is_empty() {
            continue;
        }
        let framed = json::parse(text).map(|v| v.get("frame").as_bool() == Some(true)).unwrap_or(false);
        let frame = if framed {
            match read_frame(&mut r) {
                Ok(f) => Some(f),
                Err(e) => {
                    log::warn!("federation: bad frame from backend {backend}: {e}");
                    break;
                }
            }
        } else {
            None
        };
        if rtx.send(RouterMsg::Upstream { backend, gen, line: text.to_string(), frame }).is_err() {
            return; // router gone; no point reporting the link down
        }
    }
    let _ = rtx.send(RouterMsg::BackendDown { backend, gen });
}

/// Read one length-prefixed binary frame, returning prefix + payload
/// verbatim (the client-forwarding path appends these bytes as-is). The
/// prefix is validated via [`protocol::frame_payload_len`] before any
/// payload allocation.
fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, String> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix).map_err(|e| e.to_string())?;
    let len = protocol::frame_payload_len(prefix)?;
    let mut buf = vec![0u8; 4 + len];
    buf[..4].copy_from_slice(&prefix);
    r.read_exact(&mut buf[4..]).map_err(|e| e.to_string())?;
    Ok(buf)
}

/// Health prober: rounds of one `info` call per backend over a fresh
/// short-lived connection (never the pipelined forwarding link, so a
/// wedged link cannot mask itself), each under `timeout`. Results go to
/// the route loop as messages — the prober holds no fleet state.
fn probe_loop(backends: Vec<String>, interval: Duration, timeout: Duration, stop: Arc<AtomicBool>, rtx: mpsc::Sender<RouterMsg>) {
    while !stop.load(Ordering::SeqCst) {
        for (i, addr) in backends.iter().enumerate() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let t0 = Instant::now();
            let ok = probe_once(addr, timeout).is_ok();
            if rtx.send(RouterMsg::Probe { backend: i, ok, latency_s: t0.elapsed().as_secs_f64() }).is_err() {
                return;
            }
        }
        std::thread::sleep(interval);
    }
}

/// One `info` round trip with connect/read/write deadlines.
fn probe_once(addr: &str, timeout: Duration) -> Result<(), String> {
    let stream = connect(addr, timeout).map_err(|e| e.to_string())?;
    stream.set_read_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    let mut w = stream.try_clone().map_err(|e| e.to_string())?;
    w.write_all(b"{\"op\":\"info\"}\n").map_err(|e| e.to_string())?;
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).map_err(|e| e.to_string())?;
    let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
    if v.get("ok").as_bool() == Some(true) {
        Ok(())
    } else {
        Err("probe answered not-ok".into())
    }
}

// ---------------------------------------------------------------------------
// Spawning
// ---------------------------------------------------------------------------

/// Handle to a running router (tests, benches, and the `route` CLI).
pub struct RouterHandle {
    /// Bound listen address (ephemeral ports resolved).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conn_tx: mpsc::Sender<Msg>,
    rtx: mpsc::Sender<RouterMsg>,
    route_join: Option<std::thread::JoinHandle<()>>,
    pipe_join: Option<std::thread::JoinHandle<()>>,
    probe_join: Option<std::thread::JoinHandle<()>>,
    conn_joins: Vec<std::thread::JoinHandle<()>>,
    conn_wakers: Vec<Arc<dyn Waker>>,
}

impl RouterHandle {
    /// Stop the router and join every thread it spawned.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.conn_tx.send(Msg::Shutdown);
        let _ = self.rtx.send(RouterMsg::Shutdown);
        for w in &self.conn_wakers {
            w.wake();
        }
        if let Some(j) = self.route_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.pipe_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.probe_join.take() {
            let _ = j.join();
        }
        for j in self.conn_joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.conn_tx.send(Msg::Shutdown);
        let _ = self.rtx.send(RouterMsg::Shutdown);
        for w in &self.conn_wakers {
            w.wake();
        }
    }
}

/// Bind `cfg.addr` (port 0 for ephemeral) and route in background
/// threads: the sharded connection plane, a pipe thread feeding its
/// requests to the single-threaded route loop, and the health prober.
/// Fails fast on an invalid config. Backends are dialed lazily on first
/// forward, so the fleet may come up in any order.
pub fn spawn_router(cfg: RouterConfig) -> Result<RouterHandle> {
    cfg.validate().context("validating router config")?;
    let placement = FleetPlacement::new(cfg.fleet_placement.clone(), cfg.backends.len())?;
    let serve_cfg = cfg.serve_cfg();
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let edge = Arc::new(EdgeStats::new(serve_cfg.readiness.resolve().label(), serve_cfg.conn_threads));
    let (conn_tx, conn_rx) = mpsc::channel::<Msg>();
    let (rtx, rrx) = mpsc::channel::<RouterMsg>();

    // Pipe: adapts the connection plane's Msg channel onto the route
    // loop's own message type (readers and the prober share the latter).
    let pipe_rtx = rtx.clone();
    let pipe_join = std::thread::Builder::new().name("predsamp-fed-pipe".into()).spawn(move || loop {
        match conn_rx.recv() {
            Ok(Msg::Req(req, reply)) => {
                if pipe_rtx.send(RouterMsg::Client(req, reply)).is_err() {
                    break;
                }
            }
            Ok(Msg::Shutdown) | Err(_) => {
                let _ = pipe_rtx.send(RouterMsg::Shutdown);
                break;
            }
        }
    })?;

    let probe_rtx = rtx.clone();
    let probe_stop = Arc::clone(&stop);
    let (probe_backends, probe_interval, probe_timeout) = (cfg.backends.clone(), cfg.probe_interval, cfg.probe_timeout);
    let probe_join = std::thread::Builder::new()
        .name("predsamp-fed-probe".into())
        .spawn(move || probe_loop(probe_backends, probe_interval, probe_timeout, probe_stop, probe_rtx))?;

    let route_rtx = rtx.clone();
    let route_edge = Arc::clone(&edge);
    let route_cfg = cfg.clone();
    let route_join = std::thread::Builder::new()
        .name("predsamp-fed-route".into())
        .spawn(move || route_loop(route_cfg, placement, rrx, route_rtx, route_edge))?;

    let (conn_joins, conn_wakers) = conn::spawn_shards(listener, &serve_cfg, &conn_tx, &stop, &edge).context("spawning router connection shards")?;

    Ok(RouterHandle {
        addr,
        stop,
        conn_tx,
        rtx,
        route_join: Some(route_join),
        pipe_join: Some(pipe_join),
        probe_join: Some(probe_join),
        conn_joins,
        conn_wakers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proptest_lite::{check, Gen};

    #[test]
    fn probe_state_walks_healthy_suspect_dead_readmitted() {
        let mut p = ProbeState::new(3);
        assert_eq!(p.health(), Health::Healthy);
        assert!(p.is_live());
        assert!(!p.on_err());
        assert_eq!(p.health(), Health::Suspect);
        assert!(p.is_live(), "suspect backends keep their traffic");
        assert!(!p.on_err());
        assert!(p.on_err(), "third miss crosses the threshold");
        assert_eq!(p.health(), Health::Dead);
        assert!(!p.is_live());
        assert!(!p.on_err(), "a dead backend cannot die again");
        assert!(p.on_ok(), "a successful probe re-admits");
        assert_eq!(p.health(), Health::Healthy);
        assert!(!p.on_ok(), "re-admission reports only on the transition");
    }

    #[test]
    fn probe_ok_resets_the_miss_budget() {
        let mut p = ProbeState::new(2);
        assert!(!p.on_err());
        assert!(!p.on_ok());
        assert_eq!(p.health(), Health::Healthy);
        // The budget is consecutive misses: it takes two fresh ones.
        assert!(!p.on_err());
        assert!(p.on_err());
    }

    #[test]
    fn conn_error_kills_immediately() {
        let mut p = ProbeState::new(5);
        assert!(p.on_conn_error());
        assert_eq!(p.health(), Health::Dead);
        assert!(!p.on_conn_error(), "already dead");
        assert!(p.on_ok());
        assert!(p.is_live());
    }

    #[test]
    fn placement_validates_pins_and_caps() {
        assert!(FleetPlacement::new(PlacementKind::ReplicateAll, 3).is_ok());
        assert!(FleetPlacement::new(PlacementKind::ReplicateAll, 0).is_err());
        assert!(FleetPlacement::new(PlacementKind::CapacityCapped(0), 3).is_err());
        assert!(FleetPlacement::new(PlacementKind::CapacityCapped(1), 3).is_ok());
        let pin = |ws: Vec<usize>| PlacementKind::Pinned(vec![("m".into(), ws)]);
        assert!(FleetPlacement::new(pin(vec![0, 2]), 3).is_ok());
        assert!(FleetPlacement::new(pin(vec![3]), 3).is_err(), "pin out of range");
        assert!(FleetPlacement::new(pin(vec![]), 3).is_err(), "pin to nothing");
    }

    #[test]
    fn pinned_models_route_inside_their_pin_set() {
        let fp = FleetPlacement::new(PlacementKind::Pinned(vec![("a".into(), vec![1])]), 3).unwrap();
        let live = vec![true, true, true];
        let owned = BTreeMap::new();
        assert_eq!(fp.route("a", &live, &owned), Some(1));
        assert!(fp.eligible("unpinned", 0) && fp.eligible("unpinned", 2), "unpinned models go anywhere");
        // Pinned backend dead: routing is total only over eligible live
        // backends, so the pinned model has nowhere to go.
        let live = vec![true, false, true];
        assert_eq!(fp.route("a", &live, &owned), None);
    }

    #[test]
    fn sticky_owner_holds_until_death_and_does_not_return() {
        let fp = FleetPlacement::new(PlacementKind::ReplicateAll, 3).unwrap();
        let mut owned = BTreeMap::new();
        let all = vec![true, true, true];
        let first = fp.route("m", &all, &owned).unwrap();
        owned.insert("m".to_string(), first);
        assert_eq!(fp.route("m", &all, &owned), Some(first), "sticky while live");
        // Owner dies: the namespace moves to a survivor...
        let mut live = all.clone();
        live[first] = false;
        let rehomed = fp.route("m", &live, &owned).unwrap();
        assert_ne!(rehomed, first);
        owned.insert("m".to_string(), rehomed);
        // ...and stays there after the old owner is re-admitted.
        assert_eq!(fp.route("m", &all, &owned), Some(rehomed), "re-admission does not pull namespaces back");
    }

    #[test]
    fn capacity_cap_is_soft() {
        let fp = FleetPlacement::new(PlacementKind::CapacityCapped(1), 2).unwrap();
        let live = vec![true, true];
        let mut owned = BTreeMap::new();
        let a = fp.route("a", &live, &owned).unwrap();
        owned.insert("a".to_string(), a);
        let b = fp.route("b", &live, &owned).unwrap();
        assert_ne!(a, b, "under-budget backend preferred");
        owned.insert("b".to_string(), b);
        // Both at capacity: the cap is soft, routing stays total.
        assert!(fp.route("c", &live, &owned).is_some());
    }

    fn gen_placement(g: &mut Gen, n: usize) -> FleetPlacement {
        let kind = match g.usize_in(0, 3) {
            0 => PlacementKind::ReplicateAll,
            1 => PlacementKind::CapacityCapped(g.usize_in(1, 4)),
            _ => {
                let pins = (0..g.usize_in(0, 4))
                    .map(|k| {
                        let mut ws: Vec<usize> = (0..n).filter(|_| g.bool()).collect();
                        if ws.is_empty() {
                            ws.push(g.usize_in(0, n));
                        }
                        (format!("m{k}"), ws)
                    })
                    .collect();
                PlacementKind::Pinned(pins)
            }
        };
        FleetPlacement::new(kind, n).unwrap()
    }

    #[test]
    fn prop_route_is_total_deterministic_and_stable_under_removal() {
        check("fleet_route_properties", 300, |g| {
            let n = g.usize_in(1, 9);
            let fp = gen_placement(g, n);
            let mut live = vec![false; n];
            for slot in live.iter_mut() {
                *slot = g.bool();
            }
            if !live.iter().any(|&l| l) {
                live[g.usize_in(0, n)] = true;
            }
            let mut owned = BTreeMap::new();
            for k in 0..g.usize_in(0, 6) {
                owned.insert(format!("m{k}"), g.usize_in(0, n));
            }
            let model = format!("m{}", g.usize_in(0, 8));
            let r1 = fp.route(&model, &live, &owned);
            // Deterministic: same inputs, same pick.
            crate::prop_assert_eq!(r1, fp.route(&model, &live, &owned));
            // Total: a pick exists iff some live backend is eligible,
            // and the pick itself is live and eligible.
            let any = (0..n).any(|b| live[b] && fp.eligible(&model, b));
            crate::prop_assert_eq!(r1.is_some(), any);
            if let Some(b) = r1 {
                crate::prop_assert!(live[b] && fp.eligible(&model, b));
            }
            // Stable under removal: killing any backend other than the
            // pick leaves the pick unchanged — only the dead backend's
            // namespaces move.
            let others: Vec<usize> = (0..n).filter(|&i| live[i] && Some(i) != r1).collect();
            if let (Some(pick), false) = (r1, others.is_empty()) {
                let dead = others[g.usize_in(0, others.len())];
                let mut live2 = live.clone();
                live2[dead] = false;
                crate::prop_assert_eq!(fp.route(&model, &live2, &owned), Some(pick));
            }
            Ok(())
        });
    }

    #[test]
    fn router_config_validation() {
        let base = RouterConfig { backends: vec!["127.0.0.1:1".into()], ..RouterConfig::default() };
        assert!(base.validate().is_ok());
        assert!(RouterConfig::default().validate().is_err(), "no backends");
        assert!(RouterConfig { backends: vec!["a:1".into(), "a:1".into()], ..base.clone() }.validate().is_err(), "duplicate backend");
        assert!(RouterConfig { max_hops: 0, ..base.clone() }.validate().is_err());
        assert!(RouterConfig { max_hops: 17, ..base.clone() }.validate().is_err());
        assert!(RouterConfig { probe_fails: 0, ..base.clone() }.validate().is_err());
        assert!(RouterConfig { probe_interval: Duration::from_millis(1), ..base.clone() }.validate().is_err());
        assert!(RouterConfig { probe_timeout: Duration::from_secs(120), ..base.clone() }.validate().is_err());
        assert!(RouterConfig { fleet_placement: PlacementKind::Pinned(vec![("m".into(), vec![5])]), ..base.clone() }.validate().is_err());
        assert!(RouterConfig { max_line_len: 1, ..base.clone() }.validate().is_err(), "edge knobs ride ServeConfig rules");
        let sc = base.serve_cfg();
        assert!(sc.streaming && sc.framing, "the router always honors delivery opt-ins");
        assert_eq!(sc.addr, base.addr);
    }

    #[test]
    fn rendezvous_weight_is_deterministic_and_spreads() {
        assert_eq!(rendezvous_weight("m", 0), rendezvous_weight("m", 0));
        assert_ne!(rendezvous_weight("m", 0), rendezvous_weight("m", 1));
        assert_ne!(rendezvous_weight("a", 0), rendezvous_weight("b", 0));
    }
}
