//! Line-delimited JSON serving protocol.
//!
//! Requests (one JSON object per line):
//!   {"op":"ping"}
//!   {"op":"info"}
//!   {"op":"metrics"}
//!   {"op":"eval","model":"cifar8"}
//!   {"op":"sample","model":"cifar8","method":"fpi","n":4,"seed":0,
//!    "t_use":1,"return_samples":true,"decode":false}
//!
//! Responses: {"ok":true, ...} or {"ok":false,"error":"..."}.
//!
//! Envelope fields on any request ([`RequestMeta`]): `"id"` (echoed on
//! every response line; required to correlate pipelined requests),
//! `"stream": true` (one NDJSON event per completed job before the final
//! reply), and `"frame": true` (sample payloads as length-prefixed
//! binary frames after the header line — see [`encode_frame`]).
//!
//! `info` and `metrics` report the engine-worker pool: `engine_workers`
//! (shard count) and a `workers` array of per-worker gauges — queue depth,
//! occupancy, loaded engines, batch/sample/error counters, and the
//! policy-layer gauges (per-policy schedule counters, absorption
//! counters, queue-age histogram). `sample` responses carry `arm_calls`
//! (batched ARM invocations for the whole group), `calls_per_job`
//! (passes × batch / jobs — the batched cost model) and `calls_pct`
//! (`calls_per_job` as % of the baseline's d).
//!
//! The full wire contract — field tables, error and EOF semantics, and a
//! worked request/response example per method — lives in
//! `docs/PROTOCOL.md`.

use crate::coordinator::config::Method;
use crate::substrate::json::{self, Value};

/// Parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Info,
    Metrics,
    Eval { model: String },
    Sample {
        model: String,
        method: Method,
        n: usize,
        seed: u64,
        return_samples: bool,
        decode: bool,
    },
}

/// Connection-plane envelope fields of a request, parsed alongside the
/// operation itself: the client-chosen correlation `id` (echoed on every
/// response line, required for pipelining), the per-job streaming opt-in,
/// the binary-frame opt-in, and the federation `hop` count (0 for a
/// direct client; each router tier forwards `hop + 1` and rejects lines
/// whose hop count reached its `max_hops`, so a routing cycle dies with
/// an error instead of a forwarding storm).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestMeta {
    pub id: Option<u64>,
    pub stream: bool,
    pub frame: bool,
    pub hop: u32,
}

/// Parse a request line together with its [`RequestMeta`] envelope.
pub fn parse_with_meta(line: &str) -> Result<(Request, RequestMeta), String> {
    let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
    let meta = RequestMeta {
        id: v.get("id").as_i64().filter(|&i| i >= 0).map(|i| i as u64),
        stream: v.get("stream").as_bool().unwrap_or(false),
        frame: v.get("frame").as_bool().unwrap_or(false),
        hop: v.get("hop").as_i64().filter(|&h| h >= 0).map(|h| h as u32).unwrap_or(0),
    };
    Ok((Request::from_value(&v)?, meta))
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
        Request::from_value(&v)
    }

    fn from_value(v: &Value) -> Result<Request, String> {
        let op = v.get("op").as_str().ok_or("missing op")?;
        match op {
            "ping" => Ok(Request::Ping),
            "info" => Ok(Request::Info),
            "metrics" => Ok(Request::Metrics),
            "eval" => Ok(Request::Eval {
                model: v.get("model").as_str().ok_or("eval: missing model")?.to_string(),
            }),
            "sample" => {
                let model = v.get("model").as_str().ok_or("sample: missing model")?.to_string();
                let method_name = v.get("method").as_str().unwrap_or("fpi");
                let t_use = v.get("t_use").as_usize().unwrap_or(1);
                let method = Method::parse(method_name, t_use).ok_or_else(|| format!("unknown method {method_name}"))?;
                Ok(Request::Sample {
                    model,
                    method,
                    n: v.get("n").as_usize().unwrap_or(1).max(1),
                    seed: v.get("seed").as_i64().unwrap_or(0) as u64,
                    return_samples: v.get("return_samples").as_bool().unwrap_or(true),
                    decode: v.get("decode").as_bool().unwrap_or(false),
                })
            }
            other => Err(format!("unknown op {other}")),
        }
    }
}

/// Build the wire form of a response value.
pub fn ok(fields: Vec<(&str, Value)>) -> String {
    let mut all = vec![("ok", Value::Bool(true))];
    all.extend(fields);
    Value::obj(all).to_string()
}

pub fn err(msg: &str) -> String {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::str(msg))]).to_string()
}

/// Echo a client correlation id into an already-serialized response line.
/// Splicing after the opening brace keeps the hot path from re-parsing
/// the line; every response is a non-empty JSON object, so the inserted
/// field always lands before an existing one.
pub fn with_id(line: &str, id: u64) -> String {
    debug_assert!(line.starts_with('{') && line.len() > 2, "responses are non-empty objects: {line}");
    format!("{{\"id\":{id},{}", &line[1..])
}

/// Inverse of [`with_id`] for the federation proxy: pull a spliced-first
/// `"id"` field off a response line, returning the id (if present) and
/// the line without it. Because [`with_id`] always lands the id as the
/// first field, a prefix scan suffices — no JSON re-parse on the proxy
/// hot path. Lines whose first field is not `"id"` come back unchanged.
pub fn strip_id(line: &str) -> (Option<u64>, &str) {
    let Some(rest) = line.strip_prefix("{\"id\":") else {
        return (None, line);
    };
    let digits: usize = rest.bytes().take_while(|b| b.is_ascii_digit()).count();
    if digits == 0 || !rest[digits..].starts_with(',') {
        return (None, line);
    }
    match rest[..digits].parse::<u64>() {
        Ok(id) => (Some(id), &rest[digits..]),
        Err(_) => (None, line),
    }
}

/// Re-open a stripped tail from [`strip_id`] as a standalone object line
/// (the tail starts at the `,` after the removed id field).
pub fn reopen(tail: &str) -> String {
    debug_assert!(tail.starts_with(','), "strip_id tails start at the comma: {tail}");
    format!("{{{}", &tail[1..])
}

/// Serialize a request plus its envelope back to one wire line — the
/// federation router re-emits client requests to backends through this
/// (with its own upstream `id` spliced via [`with_id`] and the hop count
/// advanced), and re-submits a dead backend's in-flight manifests from
/// the same serialization. The correlation id is deliberately *not*
/// serialized here: each tier owns its own id space.
pub fn request_line(req: &Request, meta: &RequestMeta) -> String {
    let mut fields: Vec<(&str, Value)> = Vec::new();
    match req {
        Request::Ping => fields.push(("op", Value::str("ping"))),
        Request::Info => fields.push(("op", Value::str("info"))),
        Request::Metrics => fields.push(("op", Value::str("metrics"))),
        Request::Eval { model } => {
            fields.push(("op", Value::str("eval")));
            fields.push(("model", Value::str(model)));
        }
        Request::Sample { model, method, n, seed, return_samples, decode } => {
            fields.push(("op", Value::str("sample")));
            fields.push(("model", Value::str(model)));
            let (name, t_use) = method.wire_name();
            fields.push(("method", Value::str(name)));
            fields.push(("t_use", Value::num(t_use as f64)));
            fields.push(("n", Value::num(*n as f64)));
            fields.push(("seed", Value::num(*seed as f64)));
            fields.push(("return_samples", Value::Bool(*return_samples)));
            fields.push(("decode", Value::Bool(*decode)));
        }
    }
    if meta.stream {
        fields.push(("stream", Value::Bool(true)));
    }
    if meta.frame {
        fields.push(("frame", Value::Bool(true)));
    }
    if meta.hop > 0 {
        fields.push(("hop", Value::num(meta.hop as f64)));
    }
    Value::obj(fields).to_string()
}

/// One streamed per-job delivery event (requests with `"stream": true`):
/// emitted the moment the job completes, before the final reply. With
/// `framed`, the sample row travels as a one-row binary frame after the
/// line instead of inline JSON.
pub fn stream_event(job: usize, sample: &[i32], framed: bool) -> String {
    let mut fields = vec![("job", Value::num(job as f64)), ("stream", Value::Bool(true))];
    if framed {
        fields.push(("frame", Value::Bool(true)));
    } else {
        fields.push(("sample", Value::Arr(sample.iter().map(|&v| Value::num(v as f64)).collect())));
    }
    Value::obj(fields).to_string()
}

/// Magic bytes opening every binary sample frame.
pub const FRAME_MAGIC: &[u8; 4] = b"PSMP";
/// Frame format version emitted by [`encode_frame`].
pub const FRAME_VERSION: u8 = 1;
/// Frame payload kind: row-major i32 sample rows.
pub const FRAME_KIND_SAMPLES: u8 = 1;
/// Upper bound on a declared frame payload (decode hardening).
pub const FRAME_MAX_BYTES: usize = 256 << 20;

/// Encode sample rows as a length-prefixed binary frame (the byte-level
/// layout is documented in `docs/PROTOCOL.md`):
///
/// ```text
/// u32 LE   payload length (bytes after this prefix)
/// 4 bytes  magic "PSMP"
/// u8       version (1)
/// u8       kind (1 = i32 sample rows)
/// u16 LE   reserved (0)
/// u32 LE   rows
/// u32 LE   cols
/// rows × cols × i32 LE  row-major sample values
/// ```
pub fn encode_frame(samples: &[Vec<i32>]) -> Vec<u8> {
    let cols = samples.first().map(|r| r.len()).unwrap_or(0);
    debug_assert!(samples.iter().all(|r| r.len() == cols), "sample rows must be rectangular");
    let payload_len = 16 + 4 * samples.len() * cols;
    let mut out = Vec::with_capacity(4 + payload_len);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(FRAME_MAGIC);
    out.push(FRAME_VERSION);
    out.push(FRAME_KIND_SAMPLES);
    out.extend_from_slice(&[0, 0]);
    out.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    for row in samples {
        for &v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Validate a frame's u32 length prefix *before* any payload allocation:
/// both transport ends call this on the raw 4-byte prefix so an absurd
/// declared length is rejected without reserving a buffer for it. The
/// [`FRAME_MAX_BYTES`] cap itself is accepted — the boundary is inclusive.
pub fn frame_payload_len(prefix: [u8; 4]) -> Result<usize, String> {
    let len = u32::from_le_bytes(prefix) as usize;
    if len > FRAME_MAX_BYTES {
        return Err(format!("frame length {len} exceeds the {FRAME_MAX_BYTES} byte cap"));
    }
    Ok(len)
}

/// Decode a binary sample frame's payload (the bytes *after* the u32
/// length prefix, which the transport strips while framing).
pub fn decode_frame(payload: &[u8]) -> Result<Vec<Vec<i32>>, String> {
    let u32_at = |off: usize| u32::from_le_bytes(payload[off..off + 4].try_into().expect("4 bytes")) as usize;
    if payload.len() < 16 {
        return Err(format!("frame too short: {} bytes", payload.len()));
    }
    if &payload[0..4] != FRAME_MAGIC {
        return Err("bad frame magic".into());
    }
    if payload[4] != FRAME_VERSION {
        return Err(format!("unsupported frame version {}", payload[4]));
    }
    if payload[5] != FRAME_KIND_SAMPLES {
        return Err(format!("unsupported frame kind {}", payload[5]));
    }
    let (rows, cols) = (u32_at(8), u32_at(12));
    let expect = rows.checked_mul(cols).and_then(|c| c.checked_mul(4)).and_then(|b| b.checked_add(16));
    if expect != Some(payload.len()) {
        return Err(format!("frame length mismatch: {rows}x{cols} rows/cols vs {} payload bytes", payload.len()));
    }
    let mut out = Vec::with_capacity(rows);
    let mut off = 16;
    for _ in 0..rows {
        let mut row = Vec::with_capacity(cols);
        for _ in 0..cols {
            row.push(i32::from_le_bytes(payload[off..off + 4].try_into().expect("4 bytes")));
            off += 4;
        }
        out.push(row);
    }
    Ok(out)
}

/// Encode a batch of integer samples.
pub fn samples_value(samples: &[Vec<i32>]) -> Value {
    Value::Arr(
        samples
            .iter()
            .map(|row| Value::Arr(row.iter().map(|&v| Value::num(v as f64)).collect()))
            .collect(),
    )
}

/// Decode a samples array from a response.
pub fn parse_samples(v: &Value) -> Option<Vec<Vec<i32>>> {
    v.as_arr().map(|rows| {
        rows.iter()
            .map(|r| r.as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_request() {
        let r = Request::parse(r#"{"op":"sample","model":"cifar8","method":"forecast","t_use":5,"n":3,"seed":9}"#).unwrap();
        assert_eq!(
            r,
            Request::Sample {
                model: "cifar8".into(),
                method: Method::Forecast { t_use: 5 },
                n: 3,
                seed: 9,
                return_samples: true,
                decode: false,
            }
        );
    }

    #[test]
    fn defaults_applied() {
        let r = Request::parse(r#"{"op":"sample","model":"m"}"#).unwrap();
        match r {
            Request::Sample { method, n, seed, .. } => {
                assert_eq!(method, Method::Fpi);
                assert_eq!(n, 1);
                assert_eq!(seed, 0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"sample"}"#).is_err());
        assert!(Request::parse(r#"{"op":"frobnicate"}"#).is_err());
        assert!(Request::parse(r#"{"op":"sample","model":"m","method":"nope"}"#).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = ok(vec![("arm_calls", Value::num(42.0)), ("samples", samples_value(&[vec![1, 2], vec![3, 4]]))]);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(parse_samples(v.get("samples")).unwrap(), vec![vec![1, 2], vec![3, 4]]);
        let e = err("boom");
        let v = json::parse(&e).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("error").as_str(), Some("boom"));
    }

    #[test]
    fn meta_parsed_alongside_request() {
        let (r, m) = parse_with_meta(r#"{"op":"ping","id":7,"stream":true,"frame":true}"#).unwrap();
        assert_eq!(r, Request::Ping);
        assert_eq!(m, RequestMeta { id: Some(7), stream: true, frame: true, hop: 0 });
        let (_, m) = parse_with_meta(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(m, RequestMeta::default());
        // A negative id cannot be echoed as u64: treated as absent.
        let (_, m) = parse_with_meta(r#"{"op":"ping","id":-3}"#).unwrap();
        assert_eq!(m.id, None);
        assert!(parse_with_meta(r#"{"op":"bogus","id":1}"#).is_err());
    }

    #[test]
    fn hop_count_rides_the_envelope() {
        let (_, m) = parse_with_meta(r#"{"op":"ping","hop":2}"#).unwrap();
        assert_eq!(m.hop, 2);
        // Absent or negative hops are a direct client (hop 0).
        let (_, m) = parse_with_meta(r#"{"op":"ping","hop":-1}"#).unwrap();
        assert_eq!(m.hop, 0);
        let line = request_line(&Request::Ping, &RequestMeta { hop: 3, ..RequestMeta::default() });
        let (_, m) = parse_with_meta(&line).unwrap();
        assert_eq!(m.hop, 3);
        // hop 0 is the wire default and is not serialized.
        assert!(!request_line(&Request::Ping, &RequestMeta::default()).contains("hop"));
    }

    #[test]
    fn strip_id_inverts_with_id() {
        let line = ok(vec![("pong", Value::Bool(true))]);
        let tagged = with_id(&line, 42);
        let (id, tail) = strip_id(&tagged);
        assert_eq!(id, Some(42));
        assert_eq!(reopen(tail), line);
        // Untagged lines come back whole with no id.
        let (id, tail) = strip_id(&line);
        assert_eq!(id, None);
        assert_eq!(tail, line);
        // A non-numeric or malformed id field is not stripped.
        let odd = r#"{"id":"x","ok":true}"#;
        assert_eq!(strip_id(odd), (None, odd));
    }

    #[test]
    fn request_line_roundtrips_every_op() {
        let metas = [
            RequestMeta::default(),
            RequestMeta { id: Some(9), stream: true, frame: true, hop: 1 },
        ];
        let reqs = [
            Request::Ping,
            Request::Info,
            Request::Metrics,
            Request::Eval { model: "mock_a".into() },
            Request::Sample {
                model: "mock_b".into(),
                method: Method::Forecast { t_use: 5 },
                n: 4,
                seed: 77,
                return_samples: false,
                decode: true,
            },
        ];
        for req in &reqs {
            for meta in &metas {
                let line = request_line(req, meta);
                let (parsed, pm) = parse_with_meta(&line).unwrap();
                assert_eq!(&parsed, req, "roundtrip {line}");
                // The id never travels in the body: each tier re-stripes.
                assert_eq!(pm.id, None, "ids are per-tier: {line}");
                assert_eq!((pm.stream, pm.frame, pm.hop), (meta.stream, meta.frame, meta.hop), "envelope roundtrip {line}");
            }
        }
    }

    #[test]
    fn with_id_splices_before_existing_fields() {
        let line = with_id(&ok(vec![("pong", Value::Bool(true))]), 42);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("id").as_i64(), Some(42));
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("pong").as_bool(), Some(true));
    }

    #[test]
    fn stream_event_shapes() {
        let v = json::parse(&stream_event(3, &[7, -1], false)).unwrap();
        assert_eq!(v.get("job").as_i64(), Some(3));
        assert_eq!(v.get("stream").as_bool(), Some(true));
        assert_eq!(v.get("sample").as_arr().unwrap().len(), 2);
        let v = json::parse(&stream_event(0, &[7, -1], true)).unwrap();
        assert_eq!(v.get("frame").as_bool(), Some(true), "framed events defer the row to the binary frame");
        assert_eq!(v.get("sample"), &Value::Null);
    }

    #[test]
    fn frame_roundtrip() {
        let samples = vec![vec![1, -2, 300], vec![i32::MAX, 0, i32::MIN]];
        let wire = encode_frame(&samples);
        let len = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        assert_eq!(len, wire.len() - 4, "length prefix covers the payload exactly");
        assert_eq!(decode_frame(&wire[4..]).unwrap(), samples);
        // Empty batch: a legal 16-byte header-only frame.
        let empty = encode_frame(&[]);
        assert_eq!(decode_frame(&empty[4..]).unwrap(), Vec::<Vec<i32>>::new());
    }

    #[test]
    fn frame_length_cap_is_inclusive() {
        // Exactly at the cap: accepted. One byte over: rejected from the
        // 4-byte prefix alone — no 256 MiB buffer is ever allocated.
        assert_eq!(frame_payload_len((FRAME_MAX_BYTES as u32).to_le_bytes()), Ok(FRAME_MAX_BYTES));
        let over = frame_payload_len((FRAME_MAX_BYTES as u32 + 1).to_le_bytes());
        assert!(over.is_err(), "cap + 1 must be rejected");
        assert!(over.unwrap_err().contains("cap"));
        assert_eq!(frame_payload_len(0u32.to_le_bytes()), Ok(0));
    }

    #[test]
    fn zero_row_frames_decode_cleanly() {
        // rows=0 with nonzero cols is a legal header-only frame: a batch
        // that produced no sample rows still frames without special-casing.
        let mut payload = Vec::new();
        payload.extend_from_slice(FRAME_MAGIC);
        payload.push(FRAME_VERSION);
        payload.push(FRAME_KIND_SAMPLES);
        payload.extend_from_slice(&[0, 0]);
        payload.extend_from_slice(&0u32.to_le_bytes()); // rows
        payload.extend_from_slice(&5u32.to_le_bytes()); // cols
        assert_eq!(decode_frame(&payload).unwrap(), Vec::<Vec<i32>>::new());
        // And the encoder's own zero-row form agrees with the decoder.
        let empty = encode_frame(&[]);
        assert_eq!(frame_payload_len(empty[0..4].try_into().unwrap()), Ok(16));
        assert_eq!(decode_frame(&empty[4..]).unwrap(), Vec::<Vec<i32>>::new());
    }

    #[test]
    fn frame_decode_rejects_corruption() {
        let wire = encode_frame(&[vec![1, 2]]);
        let payload = &wire[4..];
        assert!(decode_frame(&payload[..8]).is_err(), "truncated header");
        let mut bad = payload.to_vec();
        bad[0] = b'X';
        assert!(decode_frame(&bad).is_err(), "bad magic");
        let mut bad = payload.to_vec();
        bad[4] = 99;
        assert!(decode_frame(&bad).is_err(), "unknown version");
        let mut bad = payload.to_vec();
        bad[8] = 200; // declares 200 rows the payload does not carry
        assert!(decode_frame(&bad).is_err(), "row-count mismatch");
    }
}
